"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).
Usage: PYTHONPATH=src python -m benchmarks.run
       [--only ann|kde|kernels|ingest|pipeline|cluster|rpc|sharded|query|serve|tenant]
(``query`` additionally writes BENCH_query.json — see bench_query.py;
``ingest``, ``pipeline``, ``cluster``, ``rpc`` and ``tenant`` share
BENCH_ingest.json — see bench_ingest.py, bench_pipeline.py,
bench_cluster.py and bench_tenant.py; ``serve`` writes BENCH_serve.json —
the micro-batching load test, see bench_serve.py.  ``rpc`` is the
multi-process network cluster variant of ``cluster`` and spawns worker
processes — it is not part of the default all-suites run.)
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "ann", "kde", "kernels", "ingest",
                             "pipeline", "cluster", "rpc", "sharded",
                             "query", "serve", "tenant"])
    args = ap.parse_args()

    from . import (bench_ann, bench_cluster, bench_ingest, bench_kde,
                   bench_kernels, bench_pipeline, bench_query, bench_serve,
                   bench_sharded, bench_tenant)
    rows: list[tuple] = []
    suites = {"ann": bench_ann.run, "kde": bench_kde.run,
              "kernels": bench_kernels.run, "ingest": bench_ingest.run,
              "pipeline": bench_pipeline.run, "cluster": bench_cluster.run,
              "rpc": bench_cluster.run_rpc, "sharded": bench_sharded.run,
              "query": bench_query.run, "serve": bench_serve.run,
              "tenant": bench_tenant.run}
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        if args.only is None and name == "rpc":
            continue        # spawns worker processes; opt-in via --only rpc
        print(f"# suite: {name}", file=sys.stderr, flush=True)
        fn(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
