"""Pipelined vs sequential two-phase ingest (the serve-layer engine).

`repro.serve.engine.SketchEngine` splits every ingest chunk into a pure
*prepare* phase (hashing + per-chunk precomputation) and a sequential
*commit* phase, and overlaps prepare of chunk k+1 with commit of chunk k
on a dedicated prepare thread (double-buffering).  This suite measures the
end-to-end service ingest throughput both ways — identical work, identical
final state (tests/test_engine.py pins bit-identity); the only difference
is the overlap:

  pipeline.sann.*    — RetrievalService.  The chunk's packed sort (prepare)
                       and the table segment scatter (commit) are both
                       serial ops on XLA CPU, so the two phases genuinely
                       run on separate cores (~1.05-1.2x overlap on 2
                       cores now that the commit half is cheap).
  pipeline.swakde.*  — KDEService.  The commit is the closed-form
                       segment-reduce pass (kernels.ops.swakde_segment_pass,
                       DESIGN.md §12) — the old per-add EH replay that
                       capped this sketch at ~8k pps is gone.

Every variant row carries a ``prepare_us`` / ``commit_us`` breakdown
(measured directly on the service's jitted phase fns, steady state, no
engine threads), and two derived rows make the kernel win visible:

  pipeline.<s>.kernel  — the raw two-phase path per chunk (prepare_us +
                         commit_us), i.e. the ingest-kernel cost the
                         engine overlaps.
  pipeline.swakde.hotcell — commit time of a 100%-one-(row,cell) chunk
                         over the uniform-chunk commit time (the skew
                         cliff guard; acceptance: ratio ≤ 3).

Steady-state methodology: the service is built and fully ingested once
(compiles every jit, fills the ring), then the same stream is re-ingested
``repeats`` times and the median wall time is reported.  Emits
``name,us_per_call,derived`` CSV rows; results merge into
``BENCH_ingest.json`` (same artifact as bench_ingest.py; override with
REPRO_BENCH_INGEST_OUT).  REPRO_BENCH_TINY=1 shrinks sizes for CI.
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
OUT_PATH = os.environ.get("REPRO_BENCH_INGEST_OUT", "BENCH_ingest.json")
REPEATS = 3 if TINY else 5

_json_rows: list[dict] = []


def _ingest_time(svc, data, repeats: int) -> float:
    """Median wall µs of a steady-state re-ingest (jits warm, ring full)."""
    svc.ingest(data)                      # compile + fill
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        svc.ingest(data)                  # ingest == ingest_async + flush
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _time_us(fn, repeats: int) -> float:
    fn()                                  # warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _phase_breakdown(svc, chunk_data) -> tuple[float, float]:
    """Steady-state per-chunk (prepare_us, commit_us), measured directly on
    the service's jitted phase fns against its committed state — the
    engine's threads and queue are out of the picture, so this is the pure
    two-phase kernel cost the pipeline overlaps."""
    import jax
    import jax.numpy as jnp
    item = svc._make_chunk_item(jnp.asarray(chunk_data, jnp.float32), 0)
    state, _ = svc.snapshot()
    prep = jax.block_until_ready(svc._prepare(*item))
    p_us = _time_us(
        lambda: jax.block_until_ready(svc._prepare(*item)), REPEATS)
    c_us = _time_us(
        lambda: jax.block_until_ready(svc._commit(state, prep)), REPEATS)
    return p_us, c_us


def _pair(rows, name, data, make_service):
    n_points = data.shape[0]
    us, brk = {}, {}
    svc = None
    for pipelined in (False, True):
        svc = make_service(pipelined)
        us[pipelined] = _ingest_time(svc, data, REPEATS)
        brk[pipelined] = _phase_breakdown(svc, data[:svc._chunk])
        if not pipelined:
            svc.close()
    for pipelined, variant in ((False, "sequential"), (True, "pipelined")):
        u = us[pipelined]
        p_us, c_us = brk[pipelined]
        pps = n_points * 1e6 / u
        derived = (f"pps={pps:.0f};prepare_us={p_us:.0f};"
                   f"commit_us={c_us:.0f}")
        speedup = us[False] / u
        if variant == "pipelined":
            derived += f";speedup={speedup:.2f}"
        rows.append((f"pipeline.{name}.{variant}", u, derived))
        _json_rows.append({
            "name": f"pipeline.{name}.{variant}", "sketch": name,
            "variant": variant, "n_points": n_points, "us_per_call": u,
            "pps": pps, "speedup": speedup,
            "prepare_us": p_us, "commit_us": c_us,
        })
    # The raw two-phase kernel path per chunk (no engine threads): what the
    # ingest kernels cost, and what the pipeline overlaps.
    p_us, c_us = brk[True]
    chunk_rows = svc._chunk
    u = p_us + c_us
    pps = chunk_rows * 1e6 / u
    rows.append((f"pipeline.{name}.kernel", u,
                 f"pps={pps:.0f};prepare_us={p_us:.0f};commit_us={c_us:.0f}"))
    _json_rows.append({
        "name": f"pipeline.{name}.kernel", "sketch": name,
        "variant": "kernel", "n_points": chunk_rows, "us_per_call": u,
        "pps": pps, "prepare_us": p_us, "commit_us": c_us,
    })
    return svc


def bench_sann(rows):
    from repro.serve.retrieval import RetrievalConfig, RetrievalService
    N = 4096 if TINY else 32768
    d, L, k, eta, chunk, cap = ((16, 8, 3, 0.5, 512, 8) if TINY
                                else (32, 32, 4, 0.6, 4096, 8))
    data = np.random.default_rng(0).uniform(0, 1, (N, d)).astype(np.float32)
    svc = _pair(rows, "sann", data, lambda pipelined: RetrievalService(
        RetrievalConfig(dim=d, n_max=N, eta=eta, r=0.5, c=2.0, w=1.0, L=L,
                        k=k, bucket_cap=cap, ingest_chunk=chunk,
                        pipelined=pipelined)))
    svc.close()


def bench_swakde(rows):
    import jax
    import jax.numpy as jnp
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    N = 2048 if TINY else 16384
    d, L, W, chunk, window = ((8, 4, 32, 256, 512) if TINY
                              else (32, 8, 64, 1024, 8192))
    data = np.random.default_rng(1).normal(0, 1, (N, d)).astype(np.float32)
    svc = _pair(rows, "swakde", data, lambda pipelined: KDEService(
        KDEServiceConfig(dim=d, L=L, W=W, window=window, eh_eps=0.1,
                         ingest_chunk=chunk, pipelined=pipelined)))

    # Skew cliff guard: a chunk with 100% of points in one (row, cell) must
    # commit within ~3x the uniform-chunk commit (the closed-form pass is
    # O(levels·slots) per segment regardless of run length; acceptance §12).
    _, uni_c = _phase_breakdown(svc, data[:svc._chunk])
    hot = np.ones((svc._chunk, d), np.float32)
    item = svc._make_chunk_item(jnp.asarray(hot), 0)
    state, _ = svc.snapshot()
    prep = jax.block_until_ready(svc._prepare(*item))
    hot_c = _time_us(
        lambda: jax.block_until_ready(svc._commit(state, prep)), REPEATS)
    ratio = hot_c / uni_c
    rows.append(("pipeline.swakde.hotcell", hot_c,
                 f"uniform_commit_us={uni_c:.0f};ratio={ratio:.2f}"))
    _json_rows.append({
        "name": "pipeline.swakde.hotcell", "sketch": "swakde",
        "variant": "hotcell", "n_points": svc._chunk, "us_per_call": hot_c,
        "commit_us": hot_c, "uniform_commit_us": uni_c, "ratio": ratio,
    })
    svc.close()


def run(rows):
    _json_rows.clear()
    bench_sann(rows)
    bench_swakde(rows)
    update_bench_json(OUT_PATH, "ingest", _json_rows, tiny=TINY)
