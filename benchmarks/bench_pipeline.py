"""Pipelined vs sequential two-phase ingest (the serve-layer engine).

`repro.serve.engine.SketchEngine` splits every ingest chunk into a pure
*prepare* phase (hashing + per-chunk precomputation) and a sequential
*commit* phase, and overlaps prepare of chunk k+1 with commit of chunk k
on a dedicated prepare thread (double-buffering).  This suite measures the
end-to-end service ingest throughput both ways — identical work, identical
final state (tests/test_engine.py pins bit-identity); the only difference
is the overlap:

  pipeline.sann.*    — RetrievalService.  The headline regime: the chunk's
                       packed sort (prepare) and the table segment scatter
                       (commit) are both serial ops on XLA CPU, so the two
                       phases genuinely run on separate cores.  On the
                       2-core CI shape this measures ~1.2-1.3x.
  pipeline.swakde.*  — KDEService.  The EH replay loop dominates commit and
                       is internally parallel, so overlap buys little on
                       2 cores (~1.0x) — reported for honesty; the gap is
                       the motivation for the TPU-side ingest kernels on
                       the roadmap.

Steady-state methodology: the service is built and fully ingested once
(compiles every jit, fills the ring), then the same stream is re-ingested
``repeats`` times and the median wall time is reported.  Emits
``name,us_per_call,derived`` CSV rows; results merge into
``BENCH_ingest.json`` (same artifact as bench_ingest.py; override with
REPRO_BENCH_INGEST_OUT).  REPRO_BENCH_TINY=1 shrinks sizes for CI.
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
OUT_PATH = os.environ.get("REPRO_BENCH_INGEST_OUT", "BENCH_ingest.json")
REPEATS = 3 if TINY else 5

_json_rows: list[dict] = []


def _ingest_time(svc, data, repeats: int) -> float:
    """Median wall µs of a steady-state re-ingest (jits warm, ring full)."""
    svc.ingest(data)                      # compile + fill
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        svc.ingest(data)                  # ingest == ingest_async + flush
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _pair(rows, name, data, make_service):
    n_points = data.shape[0]
    us = {pipelined: _ingest_time(make_service(pipelined), data, REPEATS)
          for pipelined in (False, True)}
    for pipelined, variant in ((False, "sequential"), (True, "pipelined")):
        u = us[pipelined]
        pps = n_points * 1e6 / u
        derived = f"pps={pps:.0f}"
        speedup = us[False] / u
        if variant == "pipelined":
            derived += f";speedup={speedup:.2f}"
        rows.append((f"pipeline.{name}.{variant}", u, derived))
        _json_rows.append({
            "name": f"pipeline.{name}.{variant}", "sketch": name,
            "variant": variant, "n_points": n_points, "us_per_call": u,
            "pps": pps, "speedup": speedup,
        })


def bench_sann(rows):
    from repro.serve.retrieval import RetrievalConfig, RetrievalService
    N = 4096 if TINY else 32768
    d, L, k, eta, chunk, cap = ((16, 8, 3, 0.5, 512, 8) if TINY
                                else (32, 32, 4, 0.6, 4096, 8))
    data = np.random.default_rng(0).uniform(0, 1, (N, d)).astype(np.float32)
    _pair(rows, "sann", data, lambda pipelined: RetrievalService(
        RetrievalConfig(dim=d, n_max=N, eta=eta, r=0.5, c=2.0, w=1.0, L=L,
                        k=k, bucket_cap=cap, ingest_chunk=chunk,
                        pipelined=pipelined)))


def bench_swakde(rows):
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    N = 2048 if TINY else 16384
    d, L, W, chunk, window = ((8, 4, 32, 256, 512) if TINY
                              else (32, 8, 64, 1024, 8192))
    data = np.random.default_rng(1).normal(0, 1, (N, d)).astype(np.float32)
    _pair(rows, "swakde", data, lambda pipelined: KDEService(
        KDEServiceConfig(dim=d, L=L, W=W, window=window, eh_eps=0.1,
                         ingest_chunk=chunk, pipelined=pipelined)))


def run(rows):
    _json_rows.clear()
    bench_sann(rows)
    bench_swakde(rows)
    update_bench_json(OUT_PATH, "ingest", _json_rows, tiny=TINY)
