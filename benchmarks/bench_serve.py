"""Traffic-shaped load test of the query-side admission scheduler
(`repro.serve.engine.QueryBatcher`, DESIGN.md §13).

The PR-3 fused engines are far more efficient per row at B=1024 than at
B=1 (BENCH_query.json), but a serving front-end sees *independent* B=1
clients.  This suite measures how much of the fused-batch win the
cross-request micro-batcher recovers, under the two classic traffic
shapes:

  serve.sann.open.*    — open-loop: one generator submits B=1
                         ``submit_query`` futures with Poisson (exponential
                         inter-arrival) gaps at a configured offered qps;
                         arrivals never wait for completions, so queueing
                         is visible.  Offered rates are calibrated as
                         multiples of the measured direct B=1 service rate
                         (0.5x below capacity, 2x/8x above), and each row
                         reports offered vs achieved qps, end-to-end
                         p50/p95/p99 latency and the scheduler's mean
                         coalesced batch size over that window.
  serve.<s>.closed.*   — closed-loop: C worker threads each issue one sync
                         B=1 ``query()`` back-to-back for a fixed wall
                         window, batched (through the scheduler) vs direct
                         (``batch_queries=False``) at each C.  While one
                         fused tick executes, the other C-1 clients queue
                         and form the next tick — so the batched qps
                         should approach the fused-batch row rate as C
                         grows, while direct pays per-call dispatch C
                         times.  ``<s>`` covers sann and swakde (the
                         latter shares one grid-cache entry per tick).

Both shapes run the scheduler in continuous-batching mode
(``max_wait_us=0``): a tick fires the moment the executor is free, so a
lone client pays no idle latency tax and coalescing emerges from
requests that queue during the in-flight tick — the ``max_wait_us``
latency/throughput knob itself is exercised by the unit tests.

Latency is measured per request (submit → future done-callback, or around
the sync call); answers are bit-identical either way, so the suite only
times.  Steady state: every pad bucket the scheduler can emit (powers of
two up to ``query_block``) is pre-compiled before the clock starts.

Emits ``name,us_per_call,derived`` CSV rows (us_per_call = p50 latency)
and writes the full rows to ``BENCH_serve.json`` (override with
REPRO_BENCH_SERVE_OUT).  REPRO_BENCH_TINY=1 shrinks sizes for CI.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from .common import update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
OUT_PATH = os.environ.get("REPRO_BENCH_SERVE_OUT", "BENCH_serve.json")
WINDOW_S = 0.4 if TINY else 2.0       # measured wall window per cell
MAX_REQUESTS = 2_000 if TINY else 20_000

_json_rows: list[dict] = []


def _pctls(lat_us: list) -> dict:
    a = np.asarray(lat_us, np.float64)
    return {"p50_us": float(np.percentile(a, 50)),
            "p95_us": float(np.percentile(a, 95)),
            "p99_us": float(np.percentile(a, 99))}


def _stats_delta(svc, before: dict) -> dict:
    """Scheduler counters for one measurement window (stats are cumulative
    per batcher, so diff against the window start)."""
    after = svc.batcher.stats() if svc.batcher is not None else {}
    if not after:
        return {"ticks": 0, "mean_batch_queries": 0.0}
    ticks = after["ticks"] - before.get("ticks", 0)
    queries = after["queries"] - before.get("queries", 0)
    return {"ticks": ticks,
            "mean_batch_queries": queries / max(ticks, 1),
            "inline_ticks": (after.get("inline_ticks", 0)
                             - before.get("inline_ticks", 0))}


def _warm(svc, dim: int) -> None:
    """Compile every pad bucket the scheduler can emit (pow2 ≤ query_block)
    for the default kind, so the load windows never hit a jit trace."""
    b = 1
    while b <= svc._query_block:
        svc._kind_fn(svc._default_query_kind)(
            svc._query_snapshot_ctx(),
            np.zeros((b, dim), np.float32))
        b <<= 1


def _open_loop(svc, Q: np.ndarray, qps: float) -> dict:
    """Poisson arrivals at ``qps`` for WINDOW_S; every request is one
    B=1 ``submit_query`` future.  Latency = submit → done callback."""
    rng = np.random.default_rng(7)
    done: list = []
    lock = threading.Lock()

    def _cb(t0):
        def cb(fut):
            t1 = time.perf_counter()
            fut.result()                  # surface failures
            with lock:
                done.append((t1 - t0) * 1e6)
        return cb

    before = svc.batcher.stats() if svc.batcher is not None else {}
    futs = []
    t_start = time.perf_counter()
    t_next, t_end = t_start, t_start + WINDOW_S
    i = 0
    while True:
        now = time.perf_counter()
        if now >= t_end or i >= MAX_REQUESTS:
            break
        if t_next > now:
            time.sleep(t_next - now)
        t_next += rng.exponential(1.0 / qps)
        q = Q[i % Q.shape[0]][None]
        t0 = time.perf_counter()
        fut = svc.submit_query(q)
        fut.add_done_callback(_cb(t0))
        futs.append(fut)
        i += 1
    for fut in futs:                      # drain the tail before reading
        fut.result()
    span = time.perf_counter() - t_start
    out = {"offered_qps": qps, "achieved_qps": len(futs) / span,
           "n_requests": len(futs), **_pctls(done), **_stats_delta(svc, before)}
    return out


def _closed_loop(svc, Q: np.ndarray, clients: int) -> dict:
    """C threads of back-to-back sync B=1 ``query()`` for WINDOW_S."""
    before = (svc.batcher.stats()
              if getattr(svc, "batcher", None) is not None else {})
    lat: list = []
    lock = threading.Lock()
    stop = threading.Event()

    def worker(wid: int):
        mine = []
        i = wid
        while not stop.is_set():
            q = Q[i % Q.shape[0]][None]
            t0 = time.perf_counter()
            svc.query(q)
            mine.append((time.perf_counter() - t0) * 1e6)
            i += clients
        with lock:
            lat.extend(mine)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(WINDOW_S)
    stop.set()
    for t in threads:
        t.join()
    span = time.perf_counter() - t0
    return {"clients": clients, "qps": len(lat) / span,
            "n_requests": len(lat), **_pctls(lat),
            **_stats_delta(svc, before)}


def _emit(rows, name: str, r: dict, **extra):
    r = {**r, **extra}
    derived = ";".join(
        f"{k}={r[k]:.0f}" if k.endswith(("qps", "_us")) else f"{k}={r[k]:.2f}"
        for k in ("offered_qps", "achieved_qps", "qps", "p95_us", "p99_us",
                  "mean_batch_queries", "speedup") if k in r)
    rows.append((name, r["p50_us"], derived))
    _json_rows.append({"name": name, "us_per_call": r["p50_us"], **r})


def bench_sann(rows):
    from repro.serve.retrieval import RetrievalConfig, RetrievalService
    N, d, L, k = (2048, 16, 8, 3) if TINY else (16384, 32, 16, 4)
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 1, (N, d)).astype(np.float32)
    Q = rng.uniform(0, 1, (256, d)).astype(np.float32)
    kw = dict(dim=d, n_max=N, eta=0.5, r=0.5, c=2.0, w=1.0, L=L, k=k,
              bucket_cap=8, ingest_chunk=1024)

    direct = RetrievalService(RetrievalConfig(**kw))
    # max_wait_us=0: continuous batching — a tick fires the moment the
    # executor is free, and coalescing emerges from requests that queued
    # during the previous tick (no idle latency tax at low load).
    batched = RetrievalService(RetrievalConfig(
        **kw, batch_queries=True, max_wait_us=0.0))
    for svc in (direct, batched):
        svc.ingest(data)
        _warm(svc, d)

    # Calibrate: the direct B=1 service rate bounds an unbatched server.
    t0 = time.perf_counter()
    reps = 50 if TINY else 200
    for i in range(reps):
        direct.query(Q[i % Q.shape[0]][None])
    base_qps = reps / (time.perf_counter() - t0)
    _json_rows.append({"name": "serve.sann.direct_b1",
                       "us_per_call": 1e6 / base_qps, "qps": base_qps})
    rows.append(("serve.sann.direct_b1", 1e6 / base_qps,
                 f"qps={base_qps:.0f}"))

    # Open loop: below / at saturation / overload, relative to that rate.
    for mult in ((2.0,) if TINY else (0.5, 2.0, 8.0)):
        r = _open_loop(batched, Q, mult * base_qps)
        _emit(rows, f"serve.sann.open.x{mult:g}", r,
              load_multiple=mult, sketch="sann", shape="open")

    # Closed loop: batched vs direct at each client count.
    for c in ((1, 4) if TINY else (1, 2, 4, 8, 16, 32)):
        rd = _closed_loop(direct, Q, c)
        rb = _closed_loop(batched, Q, c)
        _emit(rows, f"serve.sann.closed.c{c}.direct", rd,
              clients=c, sketch="sann", shape="closed", variant="direct")
        _emit(rows, f"serve.sann.closed.c{c}.batched", rb,
              clients=c, sketch="sann", shape="closed", variant="batched",
              speedup=rb["qps"] / max(rd["qps"], 1e-9))
    direct.close()
    batched.close()


def bench_swakde(rows):
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    N, d, L, W = (2048, 8, 4, 32) if TINY else (8192, 32, 8, 64)
    rng = np.random.default_rng(1)
    data = rng.normal(0, 1, (N, d)).astype(np.float32)
    Q = rng.normal(0, 1, (256, d)).astype(np.float32)
    kw = dict(dim=d, L=L, W=W, window=N, eh_eps=0.1, ingest_chunk=1024)

    direct = KDEService(KDEServiceConfig(**kw))
    batched = KDEService(KDEServiceConfig(**kw, batch_queries=True,
                                          max_wait_us=0.0))
    for svc in (direct, batched):
        svc.ingest(data)
        _warm(svc, d)
    for c in ((4,) if TINY else (4, 16, 32)):
        rd = _closed_loop(direct, Q, c)
        rb = _closed_loop(batched, Q, c)
        _emit(rows, f"serve.swakde.closed.c{c}.direct", rd,
              clients=c, sketch="swakde", shape="closed", variant="direct")
        _emit(rows, f"serve.swakde.closed.c{c}.batched", rb,
              clients=c, sketch="swakde", shape="closed", variant="batched",
              speedup=rb["qps"] / max(rd["qps"], 1e-9))
    direct.close()
    batched.close()


def run(rows):
    _json_rows.clear()
    bench_sann(rows)
    bench_swakde(rows)
    update_bench_json(OUT_PATH, "serve", _json_rows, tiny=TINY)
