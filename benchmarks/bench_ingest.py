"""Ingest-path benchmarks: per-point `lax.scan` vs batched updates.

The paper's headline is *streaming* sketches; the serving bottleneck is how
fast a chunk of stream elements can be folded into sketch state.  This
suite measures points/sec for the per-point reference path (one scan step
per element) against the batched contract (one hash matmul + one
conflict-resolving update per chunk) for all three sketches:

  ingest.race.*    — RACE counter grid (core.race → kernels race_hist).
                     Config: sign-bit ACE rows (SRP k=1, W=2^k) — the
                     canonical compact-range RACE [CS20].
  ingest.swakde.*  — sliding-window EH grid.  The batched path is the
                     Corollary-4.2 batch model (one EH timestep per chunk,
                     closed-form multi-increment SumEH cells); the
                     `exact` row is the bit-identical per-point-timestamp
                     chunk replay (core.swakde.swakde_update_chunk).
  ingest.sann.*    — S-ANN sampled point store + hash tables
                     (core.sann.sann_insert_batch segment scatter).

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.run contract);
``derived`` carries points-per-second and the batched-over-sequential
speedup at each chunk size.  Results are also merged into
``BENCH_ingest.json`` (override with REPRO_BENCH_INGEST_OUT; shared with
bench_pipeline.py — same schema style as BENCH_query.json) so later PRs
have an ingest-perf trajectory to compare against.  REPRO_BENCH_TINY=1
shrinks every size so the suite runs in seconds on CI CPUs.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import lsh, race, sann, swakde
from .common import syn_ppp, timeit, update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
N_POINTS = 1024 if TINY else 4096
CHUNKS = (256, 1024) if TINY else (256, 1024, 4096)
WINDOW_PTS = 512 if TINY else 2048  # SW-AKDE sliding window, in stream points
OUT_PATH = os.environ.get("REPRO_BENCH_INGEST_OUT", "BENCH_ingest.json")

_json_rows: list[dict] = []


def _pps(us: float) -> float:
    return N_POINTS * 1e6 / us


def _emit(rows, name, sketch, variant, chunk, us, us_seq=None):
    """CSV row + JSON mirror for one measurement (us_seq → speedup)."""
    derived = f"pps={_pps(us):.0f}"
    speedup = 1.0
    if us_seq is not None:
        speedup = us_seq / us
        derived += f";speedup={speedup:.1f}"
    rows.append((name, us, derived))
    _json_rows.append({
        "name": name, "sketch": sketch, "variant": variant, "chunk": chunk,
        "us_per_call": us, "pps": _pps(us), "speedup": speedup,
    })


def bench_race(rows):
    d, L, W, k = 32, 48, 2, 1
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=k, n_buckets=W)
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=1))
    st0 = race.race_init(L, W)

    def seq(st, stream):
        def step(s, x):
            return race.race_update(s, params, x), None
        return jax.lax.scan(step, st, stream)[0]

    us_seq = timeit(jax.jit(seq), st0, xs, repeats=5)
    _emit(rows, f"ingest.race.seq.n{N_POINTS}", "race", "seq", 0, us_seq)

    for chunk in CHUNKS:
        def batched(st, stream, chunk=chunk):
            def step(s, c):
                return race.race_update_batch(s, params, c), None
            return jax.lax.scan(step, st, stream.reshape(-1, chunk, d))[0]

        us = timeit(jax.jit(batched), st0, xs, repeats=5)
        _emit(rows, f"ingest.race.batch{chunk}", "race", "batch", chunk,
              us, us_seq)


def bench_swakde(rows):
    d, L, W = 16, 8, 64
    params = lsh.init_srp(jax.random.PRNGKey(2), d, L=L, k=8, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(3), (N_POINTS, d))

    cfg = swakde.SWAKDEConfig(L=L, W=W, window=WINDOW_PTS, eh_eps=0.2)
    st0 = swakde.swakde_init(cfg)
    us_seq = timeit(
        jax.jit(lambda st, s: swakde.swakde_stream(st, params, s, cfg)),
        st0, xs, repeats=5)
    _emit(rows, f"ingest.swakde.seq.n{N_POINTS}", "swakde", "seq", 0, us_seq)

    # Production batched path — Corollary 4.2: one EH timestep per chunk,
    # window measured in batches at the same point horizon.
    for chunk in CHUNKS:
        bcfg = swakde.BatchSWAKDEConfig(
            L=L, W=W, window=max(1, WINDOW_PTS // chunk), eh_eps=0.2,
            batch_size=chunk)
        bst0 = swakde.batch_swakde_init(bcfg)

        def batched(st, stream, chunk=chunk, bcfg=bcfg):
            def step(s, c):
                return swakde.batch_swakde_update(s, params, c, bcfg), None
            return jax.lax.scan(step, st, stream.reshape(-1, chunk, d))[0]

        us = timeit(jax.jit(batched), bst0, xs, repeats=5)
        _emit(rows, f"ingest.swakde.batch{chunk}", "swakde", "batch", chunk,
              us, us_seq)

    # Exact chunked replay: bit-identical to the per-point path (same
    # per-point timestamps), still one grid traversal per chunk.
    us = timeit(
        jax.jit(lambda st, s: swakde.swakde_update_chunk(st, params, s, cfg)),
        st0, xs, repeats=5)
    _emit(rows, f"ingest.swakde.exact{N_POINTS}", "swakde", "exact",
          N_POINTS, us, us_seq)


def bench_sann(rows):
    d = 48
    cfg = sann.SANNConfig(dim=d, n_max=N_POINTS, eta=0.3, r=0.5, c=2.0,
                          w=1.0, L=8, k=4, bucket_cap=16)
    cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(4))
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=5))
    key = jax.random.PRNGKey(6)

    us_seq = timeit(
        jax.jit(lambda st, s, k:
                sann.sann_insert_stream(st, params, s, k, cfg)),
        st0, xs, key, repeats=5)
    _emit(rows, f"ingest.sann.seq.n{N_POINTS}", "sann", "seq", 0, us_seq)

    for chunk in CHUNKS:
        us = timeit(
            jax.jit(lambda st, s, k, chunk=chunk:
                    sann.sann_insert_chunked(st, params, s, k, cfg,
                                             chunk=chunk)),
            st0, xs, key, repeats=5)
        _emit(rows, f"ingest.sann.batch{chunk}", "sann", "batch", chunk,
              us, us_seq)


def run(rows):
    _json_rows.clear()
    bench_race(rows)
    bench_swakde(rows)
    bench_sann(rows)
    update_bench_json(OUT_PATH, "ingest", _json_rows, tiny=TINY,
                      chunk_sizes=list(CHUNKS))
