"""Ingest-path benchmarks: per-point `lax.scan` vs batched updates.

The paper's headline is *streaming* sketches; the serving bottleneck is how
fast a chunk of stream elements can be folded into sketch state.  This
suite measures points/sec for the per-point reference path (one scan step
per element) against the batched contract (one hash matmul + one
conflict-resolving update per chunk) for all three sketches:

  ingest.race.*    — RACE counter grid (core.race → kernels race_hist).
                     Config: sign-bit ACE rows (SRP k=1, W=2^k) — the
                     canonical compact-range RACE [CS20].
  ingest.swakde.*  — sliding-window EH grid.  The batched path is the
                     Corollary-4.2 batch model (one EH timestep per chunk,
                     closed-form multi-increment SumEH cells); the
                     `exact` row is the bit-identical per-point-timestamp
                     chunk replay (core.swakde.swakde_update_chunk).
  ingest.sann.*    — S-ANN sampled point store + hash tables
                     (core.sann.sann_insert_batch segment scatter).

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.run contract);
``derived`` carries points-per-second and the batched-over-sequential
speedup at each chunk size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lsh, race, sann, swakde
from .common import syn_ppp, timeit

N_POINTS = 4096
CHUNKS = (256, 1024, 4096)
WINDOW_PTS = 2048  # SW-AKDE sliding window, in stream points


def _pps(us: float) -> float:
    return N_POINTS * 1e6 / us


def bench_race(rows):
    d, L, W, k = 32, 48, 2, 1
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=k, n_buckets=W)
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=1))
    st0 = race.race_init(L, W)

    def seq(st, stream):
        def step(s, x):
            return race.race_update(s, params, x), None
        return jax.lax.scan(step, st, stream)[0]

    us_seq = timeit(jax.jit(seq), st0, xs, repeats=5)
    rows.append((f"ingest.race.seq.n{N_POINTS}", us_seq,
                 f"pps={_pps(us_seq):.0f}"))

    for chunk in CHUNKS:
        def batched(st, stream, chunk=chunk):
            def step(s, c):
                return race.race_update_batch(s, params, c), None
            return jax.lax.scan(step, st, stream.reshape(-1, chunk, d))[0]

        us = timeit(jax.jit(batched), st0, xs, repeats=5)
        rows.append((f"ingest.race.batch{chunk}", us,
                     f"pps={_pps(us):.0f};speedup={us_seq/us:.1f}"))


def bench_swakde(rows):
    d, L, W = 16, 8, 64
    params = lsh.init_srp(jax.random.PRNGKey(2), d, L=L, k=8, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(3), (N_POINTS, d))

    cfg = swakde.SWAKDEConfig(L=L, W=W, window=WINDOW_PTS, eh_eps=0.2)
    st0 = swakde.swakde_init(cfg)
    us_seq = timeit(
        jax.jit(lambda st, s: swakde.swakde_stream(st, params, s, cfg)),
        st0, xs, repeats=5)
    rows.append((f"ingest.swakde.seq.n{N_POINTS}", us_seq,
                 f"pps={_pps(us_seq):.0f}"))

    # Production batched path — Corollary 4.2: one EH timestep per chunk,
    # window measured in batches at the same point horizon.
    for chunk in CHUNKS:
        bcfg = swakde.BatchSWAKDEConfig(
            L=L, W=W, window=max(1, WINDOW_PTS // chunk), eh_eps=0.2,
            batch_size=chunk)
        bst0 = swakde.batch_swakde_init(bcfg)

        def batched(st, stream, chunk=chunk, bcfg=bcfg):
            def step(s, c):
                return swakde.batch_swakde_update(s, params, c, bcfg), None
            return jax.lax.scan(step, st, stream.reshape(-1, chunk, d))[0]

        us = timeit(jax.jit(batched), bst0, xs, repeats=5)
        rows.append((f"ingest.swakde.batch{chunk}", us,
                     f"pps={_pps(us):.0f};speedup={us_seq/us:.1f}"))

    # Exact chunked replay: bit-identical to the per-point path (same
    # per-point timestamps), still one grid traversal per chunk.
    us = timeit(
        jax.jit(lambda st, s: swakde.swakde_update_chunk(st, params, s, cfg)),
        st0, xs, repeats=5)
    rows.append((f"ingest.swakde.exact{N_POINTS}", us,
                 f"pps={_pps(us):.0f};speedup={us_seq/us:.1f}"))


def bench_sann(rows):
    d = 48
    cfg = sann.SANNConfig(dim=d, n_max=N_POINTS, eta=0.3, r=0.5, c=2.0,
                          w=1.0, L=8, k=4, bucket_cap=16)
    cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(4))
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=5))
    key = jax.random.PRNGKey(6)

    us_seq = timeit(
        jax.jit(lambda st, s, k:
                sann.sann_insert_stream(st, params, s, k, cfg)),
        st0, xs, key, repeats=5)
    rows.append((f"ingest.sann.seq.n{N_POINTS}", us_seq,
                 f"pps={_pps(us_seq):.0f}"))

    for chunk in CHUNKS:
        us = timeit(
            jax.jit(lambda st, s, k, chunk=chunk:
                    sann.sann_insert_chunked(st, params, s, k, cfg,
                                             chunk=chunk)),
            st0, xs, key, repeats=5)
        rows.append((f"ingest.sann.batch{chunk}", us,
                     f"pps={_pps(us):.0f};speedup={us_seq/us:.1f}"))


def run(rows):
    bench_race(rows)
    bench_swakde(rows)
    bench_sann(rows)
