"""Merge-based cluster ingest throughput (repro.serve.cluster).

`ClusterService` scales ingest by hash-partitioning the stream across N
worker `SketchEngine`s — each with its own commit worker + prepare thread
— and merging per-worker states on the ``merge_every`` cadence (and at
query time).  This suite measures end-to-end cluster ingest (submit every
partition + flush every worker + the cadence merge) at 1/2/4 workers:

  cluster.sann.w{N}  — ClusterRetrievalService (S-ANN): prepare (packed
                       sort) and commit (segment scatter) both serial per
                       worker, so extra workers map onto extra cores.
  cluster.race.w{N}  — ClusterRACEService: the commit is one dense add —
                       already memory-bound on CPU, so worker scaling is
                       reported for honesty, not for a headline.

Each row also reports ``merge_us`` — the cost of one coordinator merge of
the final worker states (what a query pays when the merged cache is stale,
i.e. at most every ``merge_every`` worker commits).

The ``rpc`` suite (``run_rpc``, ``--only rpc``) repeats the same series
with multi-PROCESS workers behind the network front door (repro.net):
``rpc.{sann,race}.w{N}`` rows, variant="rpc", same artifact.

On the 2-core CI shape expect w2 ≈ 1.1-1.4x for S-ANN and w4 ≈ w2 (no
spare cores); the point of the suite is the *scaling shape* and honest
merge costs, not absolute numbers.  Steady-state methodology as in
bench_pipeline.py: build + ingest once (compile), then re-ingest
``repeats`` times and take the median.  Emits ``name,us_per_call,derived``
CSV rows; results merge into ``BENCH_ingest.json`` (same artifact as
bench_ingest/bench_pipeline; override with REPRO_BENCH_INGEST_OUT).
REPRO_BENCH_TINY=1 shrinks sizes for CI.
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
OUT_PATH = os.environ.get("REPRO_BENCH_INGEST_OUT", "BENCH_ingest.json")
REPEATS = 3 if TINY else 5
WORKER_COUNTS = (1, 2, 4)

_json_rows: list[dict] = []


def _ingest_time(cluster, data, repeats: int) -> float:
    """Median wall µs of a steady-state re-ingest (jits warm)."""
    cluster.ingest(data)                  # compile + warm every worker
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        cluster.ingest(data)              # partition + submit + flush + merge
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _merge_time(cluster, repeats: int) -> float:
    """Median wall µs of one coordinator merge of the current states."""
    ts = []
    for _ in range(repeats):
        with cluster._mlock:
            cluster._merged_versions = None      # force a re-merge
        t0 = time.perf_counter()
        cluster.merged_state()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _series(rows, name, data, make_cluster, prefix="cluster",
            variant="cluster"):
    n_points = data.shape[0]
    base_us = None
    for workers in WORKER_COUNTS:
        cl = make_cluster(workers)
        us = _ingest_time(cl, data, REPEATS)
        merge_us = _merge_time(cl, REPEATS) if workers > 1 else 0.0
        cl.close()
        if base_us is None:
            base_us = us
        pps = n_points * 1e6 / us
        speedup = base_us / us
        derived = f"pps={pps:.0f};speedup={speedup:.2f};merge_us={merge_us:.0f}"
        rows.append((f"{prefix}.{name}.w{workers}", us, derived))
        _json_rows.append({
            "name": f"{prefix}.{name}.w{workers}", "sketch": name,
            "variant": variant, "workers": workers, "n_points": n_points,
            "us_per_call": us, "pps": pps, "speedup": speedup,
            "merge_us": merge_us,
        })


def bench_sann(rows):
    from repro.serve.cluster import ClusterRetrievalService
    from repro.serve.retrieval import RetrievalConfig
    N = 4096 if TINY else 32768
    d, L, k, eta, chunk, cap = ((16, 8, 3, 0.5, 512, 8) if TINY
                                else (32, 32, 4, 0.6, 4096, 8))
    data = np.random.default_rng(0).uniform(0, 1, (N, d)).astype(np.float32)
    cfg = RetrievalConfig(dim=d, n_max=N, eta=eta, r=0.5, c=2.0, w=1.0, L=L,
                          k=k, bucket_cap=cap, ingest_chunk=chunk)
    _series(rows, "sann", data,
            lambda w: ClusterRetrievalService(cfg, num_workers=w,
                                              merge_every=8))


def bench_race(rows):
    from repro.serve.cluster import ClusterRACEService
    from repro.serve.race_service import RACEServiceConfig
    N = 4096 if TINY else 65536
    d, L, W, chunk = (16, 8, 32, 512) if TINY else (32, 32, 128, 4096)
    data = np.random.default_rng(1).normal(0, 1, (N, d)).astype(np.float32)
    cfg = RACEServiceConfig(dim=d, L=L, W=W, ingest_chunk=chunk)
    _series(rows, "race", data,
            lambda w: ClusterRACEService(cfg, num_workers=w, merge_every=8))


def run(rows):
    _json_rows.clear()
    bench_sann(rows)
    bench_race(rows)
    update_bench_json(OUT_PATH, "cluster", _json_rows, tiny=TINY)


# --- network (multi-process) cluster: the `rpc` suite ------------------------
#
# Same shapes and methodology as the in-process series, but every worker
# is a separate spawned PROCESS behind the RPC front door (repro.net) —
# so the per-worker prepare/commit work runs on genuinely independent
# interpreters (no GIL sharing with the coordinator), at the price of one
# socket round trip per engine chunk.  rpc.{sann,race}.w{N} rows land in
# the same BENCH_ingest.json with variant="rpc".  On a core-starved dev
# shape the processes time-slice one core and absolute numbers are flat —
# the honest comparison is rpc.wN vs cluster.wN, not wN vs w1.

def bench_rpc_sann(rows):
    from repro.net import RPCClusterRetrievalService
    from repro.serve.retrieval import RetrievalConfig
    N = 4096 if TINY else 32768
    d, L, k, eta, chunk, cap = ((16, 8, 3, 0.5, 512, 8) if TINY
                                else (32, 32, 4, 0.6, 4096, 8))
    data = np.random.default_rng(0).uniform(0, 1, (N, d)).astype(np.float32)
    cfg = RetrievalConfig(dim=d, n_max=N, eta=eta, r=0.5, c=2.0, w=1.0, L=L,
                          k=k, bucket_cap=cap, ingest_chunk=chunk)
    _series(rows, "sann", data,
            lambda w: RPCClusterRetrievalService(cfg, num_workers=w,
                                                 merge_every=8),
            prefix="rpc", variant="rpc")


def bench_rpc_race(rows):
    from repro.net import RPCClusterRACEService
    from repro.serve.race_service import RACEServiceConfig
    N = 4096 if TINY else 65536
    d, L, W, chunk = (16, 8, 32, 512) if TINY else (32, 32, 128, 4096)
    data = np.random.default_rng(1).normal(0, 1, (N, d)).astype(np.float32)
    cfg = RACEServiceConfig(dim=d, L=L, W=W, ingest_chunk=chunk)
    _series(rows, "race", data,
            lambda w: RPCClusterRACEService(cfg, num_workers=w,
                                            merge_every=8),
            prefix="rpc", variant="rpc")


def run_rpc(rows):
    _json_rows.clear()
    bench_rpc_sann(rows)
    bench_rpc_race(rows)
    update_bench_json(OUT_PATH, "rpc", _json_rows, tiny=TINY)
