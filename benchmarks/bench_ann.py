"""ANN benchmarks — one per paper figure (§5.1).

  fig5  — sketch memory vs stream size N for eta 0.2..0.8 (sublinearity)
  fig6/7— S-ANN vs JL: approximate recall@50 + (c,r)-accuracy vs compression
          over the epsilon grid (c = 1 + eps)
  fig8  — recall + query throughput (QPS): JL k-sweep vs S-ANN eta-sweep

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.run contract).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jl, sann, theory
from .common import sift_like, syn_ppp, timeit, true_topk

N_STORE = 12_000
N_QUERY = 128


def _build_sann(data, eta, r, c, seed=0, L=12, k=6, bucket_cap=32):
    cfg = sann.SANNConfig(dim=data.shape[1], n_max=len(data), eta=eta, r=r,
                          c=c, w=2.0 * r, L=L, k=k, bucket_cap=bucket_cap)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(seed))
    state = sann.sann_insert_chunked(state, params, jnp.asarray(data),
                                     jax.random.PRNGKey(seed + 1), cfg,
                                     chunk=4096)
    return cfg, params, state


def _r_for_eta(data, queries, eta: float) -> float:
    """r sized so an r-ball holds ~3·n^eta points — the paper's Poisson
    regime m >= C·n^eta (Thm 3.1), under which sampling at rate n^-eta
    leaves the query ball non-empty w.h.p."""
    n = len(data)
    frac = min(0.5, 3.0 * n**eta / n)
    sub = queries[:32]
    qn = (sub ** 2).sum(1)[:, None]
    dn = (data ** 2).sum(1)[None, :]
    d2 = np.maximum(qn + dn - 2 * sub @ data.T, 0)
    return float(np.quantile(np.sqrt(d2), frac)) + 1e-3


def _approx_recall(ids, dists, gt_d50, eps):
    """ann-benchmarks 'approximate recall@50': retrieved points within
    (1+eps) * distance-of-true-50th-NN count as hits."""
    ok = (ids >= 0) & (dists <= (1.0 + eps) * gt_d50[:, None] + 1e-9)
    return float(ok.sum(1).mean() / ids.shape[1])


def fig5_memory_scaling(rows):
    for N in (1_000, 10_000, 40_000, 160_000):
        dense_bytes = N * 128 * 4
        for eta in (0.2, 0.5, 0.8):
            cfg = sann.SANNConfig(dim=128, n_max=N, eta=eta, r=0.5, c=1.5,
                                  L=12, k=6).resolved()
            b = sann.sann_bytes(cfg)
            rows.append((f"ann.fig5.bytes.N{N}.eta{eta}", 0.0,
                         f"{b};compression={b/dense_bytes:.4f}"))


def fig6_7_recall_accuracy(rows):
    for name, data_fn in (("syn32", lambda: syn_ppp(N_STORE, 32, 1)),
                          ("siftlike", lambda: sift_like(N_STORE, 2))):
        data = data_fn()
        rng = np.random.default_rng(3)
        queries = data[rng.choice(len(data), N_QUERY, replace=False)] \
            + 0.01 * rng.standard_normal((N_QUERY, data.shape[1])).astype(np.float32)
        gt50 = true_topk(data, queries, 50)
        gt_d50 = np.sqrt(((queries - data[gt50[:, -1]]) ** 2).sum(1))
        for eps in (0.5, 0.8):
            c = 1.0 + eps
            for eta in (0.3, 0.5, 0.7):
                r = _r_for_eta(data, queries, eta)
                cfg, params, state = _build_sann(data, eta, r, c)
                t0 = time.perf_counter()
                ids, dists = jax.block_until_ready(sann.sann_query_topk_batch(
                    state, params, jnp.asarray(queries), cfg, 50))
                dt = (time.perf_counter() - t0) * 1e6 / N_QUERY
                recall = _approx_recall(np.asarray(ids), np.asarray(dists),
                                        gt_d50, eps)
                # (c,r)-accuracy: a point within c*r returned when the true
                # NN is within r (Problem 1.1 contract)
                res = sann.sann_query_batch(state, params,
                                            jnp.asarray(queries), cfg)
                nn_dist = np.sqrt(((queries - data[gt50[:, 0]]) ** 2).sum(1))
                applicable = nn_dist <= r
                okq = np.asarray(res.distance) <= c * r + 1e-6
                acc = float((okq & applicable).sum()) / max(applicable.sum(), 1)
                comp = sann.sann_bytes(cfg) / (len(data) * data.shape[1] * 4)
                rows.append((f"ann.fig7.sann.{name}.eps{eps}.eta{eta}", dt,
                             f"recall50={recall:.3f};cr_acc={acc:.3f};"
                             f"compression={comp:.3f};r={r:.3f}"))
            # JL baseline at matched compression rates
            r_jl = _r_for_eta(data, queries, 0.5)
            for kproj in (8, 16, 32):
                jcfg = jl.JLConfig(dim=data.shape[1], k=kproj,
                                   capacity=len(data))
                st = jl.jl_init(jcfg, jax.random.PRNGKey(9))
                st = jl.jl_insert_stream(st, jnp.asarray(data), jcfg)
                t0 = time.perf_counter()
                idx, _ = jax.block_until_ready(
                    jl.jl_query_batch(st, jnp.asarray(queries), jcfg, topk=50))
                dt = (time.perf_counter() - t0) * 1e6 / N_QUERY
                idx = np.asarray(idx)
                true_d = np.sqrt(((queries[:, None] - data[idx]) ** 2).sum(-1))
                recall = _approx_recall(idx, true_d, gt_d50, eps)
                near = true_d[:, 0]
                nn_dist = np.sqrt(((queries - data[gt50[:, 0]]) ** 2).sum(1))
                applicable = nn_dist <= r_jl
                acc = float(((near <= c * r_jl) & applicable).sum()) \
                    / max(applicable.sum(), 1)
                comp = jl.jl_bytes(jcfg) / (len(data) * data.shape[1] * 4)
                rows.append((f"ann.fig7.jl.{name}.eps{eps}.k{kproj}", dt,
                             f"recall50={recall:.3f};cr_acc={acc:.3f};"
                             f"compression={comp:.3f}"))


def fig8_throughput(rows):
    data = syn_ppp(8_000, 32, 5)
    rng = np.random.default_rng(6)
    queries = jnp.asarray(
        data[rng.choice(len(data), 100, replace=False)]
        + 0.01 * rng.standard_normal((100, 32)).astype(np.float32))
    for eta in (0.2, 0.5, 0.8):
        r = _r_for_eta(data, np.asarray(queries), eta)
        cfg, params, state = _build_sann(data, eta, r, 2.0)
        q = jax.jit(lambda s, qs: sann.sann_query_batch(s, params, qs, cfg))
        us = timeit(q, state, queries)
        res = q(state, queries)
        rows.append((f"ann.fig8.sann.eta{eta}", us / 100,
                     f"qps={1e8/us:.0f};found={float(res.found.mean()):.3f}"))
    for kproj in (8, 16, 32):
        jcfg = jl.JLConfig(dim=32, k=kproj, capacity=len(data))
        st = jl.jl_init(jcfg, jax.random.PRNGKey(11))
        st = jl.jl_insert_stream(st, jnp.asarray(data), jcfg)
        q = jax.jit(lambda s, qs: jl.jl_query_batch(s, qs, jcfg, topk=1))
        us = timeit(q, st, queries)
        rows.append((f"ann.fig8.jl.k{kproj}", us / 100, f"qps={1e8/us:.0f}"))


def run(rows):
    fig5_memory_scaling(rows)
    fig6_7_recall_accuracy(rows)
    fig8_throughput(rows)
