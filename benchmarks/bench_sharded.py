"""Sharded ingest/query scaling benchmark (repro.parallel.sketch_sharding).

Measures batched-ingest and batched-query throughput for the three sketches
at shard counts 1, 2, 4, 8 (clipped to the visible device count) and
reports **per-shard** points/sec alongside the total.  On real multi-chip
hardware the rows/tables live on different chips and the total scales; on a
CPU forced to 8 virtual devices (how CI runs this) the devices share the
same cores, so the interesting output is that sharding *doesn't lose*
throughput — the combine overhead (all-gather of per-row reads) is visible
directly as pps(sharded)/pps(1).

Standalone (forces 8 host devices before jax initialises):

    PYTHONPATH=src python -m benchmarks.bench_sharded

or through the harness (uses however many devices are already visible):

    PYTHONPATH=src python -m benchmarks.run --only sharded

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.run contract);
``derived`` carries pps, per-shard pps, and the ratio vs the 1-shard run.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede any jax import in this process
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp

from repro.core import lsh, race, sann, swakde
from repro.parallel import sketch_sharding as ss

from .common import syn_ppp, timeit

N_POINTS = 4096
N_QUERIES = 256


def _shard_counts():
    n_dev = len(jax.devices())
    return [s for s in (1, 2, 4, 8) if s <= n_dev]


def _pps(us: float, n: int = N_POINTS) -> float:
    return n * 1e6 / us


def _ctx(shards: int):
    return ss.make_sketch_ctx(ss.make_sketch_mesh(shards)
                              if shards > 1 else None)


def bench_race(rows):
    d, L, W = 32, 32, 64
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=2, n_buckets=W)
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=1))
    qs = jnp.asarray(syn_ppp(N_QUERIES, d, seed=2))
    base_i = base_q = None
    for shards in _shard_counts():
        ctx = _ctx(shards)
        st, p = ss.shard_race(race.race_init(L, W), params, ctx) \
            if ctx.mesh is not None else (race.race_init(L, W), params)
        ing = jax.jit(lambda s, x: ss.sharded_race_update_batch(s, p, x, ctx))
        us = timeit(ing, st, xs, repeats=5)
        base_i = base_i or us
        rows.append((f"sharded.race.ingest.s{shards}", us,
                     f"pps={_pps(us):.0f};per_shard={_pps(us)/shards:.0f};"
                     f"vs1={base_i/us:.2f}"))
        qry = jax.jit(lambda s, q: ss.sharded_race_query_batch(s, p, q, ctx))
        us = timeit(qry, ing(st, xs), qs, repeats=5)
        base_q = base_q or us
        rows.append((f"sharded.race.query.s{shards}", us,
                     f"qps={_pps(us, N_QUERIES):.0f};vs1={base_q/us:.2f}"))


def bench_swakde(rows):
    d, L, W = 16, 16, 64
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=2048, eh_eps=0.2)
    params = lsh.init_srp(jax.random.PRNGKey(3), d, L=L, k=4, n_buckets=W)
    xs = jnp.asarray(syn_ppp(1024, d, seed=4))
    qs = jnp.asarray(syn_ppp(N_QUERIES, d, seed=5))
    base_i = base_q = None
    for shards in _shard_counts():
        ctx = _ctx(shards)
        st0 = swakde.swakde_init(cfg)
        if ctx.mesh is not None:
            st0, p = ss.shard_swakde(st0, params, ctx)
        else:
            p = params
        ing = jax.jit(lambda s, x: ss.sharded_swakde_update_chunk(
            s, p, x, cfg, ctx))
        us = timeit(ing, st0, xs, repeats=5)
        base_i = base_i or us
        rows.append((f"sharded.swakde.ingest.s{shards}", us,
                     f"pps={_pps(us, 1024):.0f};"
                     f"per_shard={_pps(us, 1024)/shards:.0f};"
                     f"vs1={base_i/us:.2f}"))
        qry = jax.jit(lambda s, q: ss.sharded_swakde_query_batch(
            s, p, q, cfg, ctx))
        us = timeit(qry, ing(st0, xs), qs, repeats=5)
        base_q = base_q or us
        rows.append((f"sharded.swakde.query.s{shards}", us,
                     f"qps={_pps(us, N_QUERIES):.0f};vs1={base_q/us:.2f}"))


def bench_sann(rows):
    d = 48
    cfg = sann.SANNConfig(dim=d, n_max=N_POINTS, eta=0.3, r=0.5, c=2.0,
                          w=1.0, L=16, k=4, bucket_cap=16)
    cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(6))
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=7))
    qs = jnp.asarray(syn_ppp(N_QUERIES, d, seed=8))
    key = jax.random.PRNGKey(9)
    base_i = base_q = None
    for shards in _shard_counts():
        ctx = _ctx(shards)
        if ctx.mesh is not None:
            st, p = ss.shard_sann(st0, params, ctx)
        else:
            st, p = st0, params
        ing = jax.jit(lambda s, x, k: ss.sharded_sann_insert_batch(
            s, p, x, k, cfg, ctx))
        us = timeit(ing, st, xs, key, repeats=5)
        base_i = base_i or us
        rows.append((f"sharded.sann.ingest.s{shards}", us,
                     f"pps={_pps(us):.0f};per_shard={_pps(us)/shards:.0f};"
                     f"vs1={base_i/us:.2f}"))
        qry = jax.jit(lambda s, q: ss.sharded_sann_query_batch(
            s, p, q, cfg, ctx))
        us = timeit(qry, ing(st, xs, key), qs, repeats=5)
        base_q = base_q or us
        rows.append((f"sharded.sann.query.s{shards}", us,
                     f"qps={_pps(us, N_QUERIES):.0f};vs1={base_q/us:.2f}"))


def run(rows):
    bench_race(rows)
    bench_swakde(rows)
    bench_sann(rows)


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
