"""Pallas kernel microbenches (interpret-mode correctness + jnp-oracle
timing on CPU; the kernels target TPU — see DESIGN.md §5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from .common import timeit


def run(rows):
    key = jax.random.PRNGKey(0)
    # srp_hash oracle throughput
    x = jax.random.normal(key, (4096, 128))
    proj = jax.random.normal(key, (128, 64))
    mix = (jax.random.randint(key, (16, 4), 1, 2**30).astype(jnp.uint32) | 1)
    f = jax.jit(lambda x: ref.srp_hash_ref(x, proj, mix, 1024))
    us = timeit(f, x)
    rows.append(("kernel.srp_hash.4096x128", us,
                 f"hashes_per_s={4096e6/us:.0f}"))

    # race histogram
    codes = jax.random.randint(key, (4096, 16), 0, 512, jnp.int32)
    f = jax.jit(lambda c: ref.race_update_ref(jnp.zeros((16, 512), jnp.int32), c))
    us = timeit(f, codes)
    rows.append(("kernel.race_hist.4096x16", us,
                 f"updates_per_s={4096e6/us:.0f}"))

    # candidate scoring
    q = jax.random.normal(key, (128,))
    c = jax.random.normal(key, (1024, 128))
    f = jax.jit(lambda q, c: ref.cand_score_ref(q, c))
    us = timeit(f, q, c)
    rows.append(("kernel.cand_score.1024x128", us,
                 f"scores_per_s={1024e6/us:.0f}"))

    # sketch decode attention: pruned vs full block visit count
    S, bs, Hkv, G, dh = 8192, 512, 2, 4, 64
    k = jax.random.normal(key, (S, Hkv, dh))
    v = jax.random.normal(key, (S, Hkv, dh))
    qq = jax.random.normal(key, (Hkv, G, dh))
    nb = S // bs
    for frac, tag in ((1.0, "full"), (0.25, "pruned4x")):
        live = jnp.arange(nb) < max(1, int(nb * frac))
        f = jax.jit(lambda q, k, v, live: ref.sketch_decode_attn_ref(
            q, k, v, live, jnp.int32(S), bs))
        us = timeit(f, qq, k, v, live)
        rows.append((f"kernel.sketch_decode.{tag}.S{S}", us,
                     f"visited_blocks={int(live.sum())}/{nb}"))
