"""Shared benchmark infrastructure: synthetic datasets + timing.

Dataset note (DESIGN.md §7): sift1m / fashion-mnist / news-headlines /
ROSIS are not available offline; these are dimension-matched synthetic
surrogates following the paper's own syn-32 protocol (PPP) and its
Monte-Carlo Gaussian-mixture protocol for KDE.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def update_bench_json(path: str, suite: str, rows: list, **meta) -> None:
    """Merge benchmark ``rows`` (dicts with a unique ``"name"``) into the
    JSON file at ``path`` — the BENCH_*.json contract established by
    bench_query.py: ``{"suite", "backend", "tiny", ..., "results": [...]}``.

    Read-modify-write keyed by row name, so suites that share one artifact
    (e.g. bench_ingest + bench_pipeline → BENCH_ingest.json) can run
    independently and re-runs replace their own rows."""
    payload: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            payload = {}
    fresh = {r["name"] for r in rows}
    kept = [r for r in payload.get("results", []) if r["name"] not in fresh]
    payload.update({"suite": suite, "backend": jax.default_backend(), **meta})
    payload["results"] = kept + rows
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def syn_ppp(n: int, d: int, seed: int = 0) -> np.ndarray:
    """The paper's syn-32 protocol: uniform samples from a Poisson point
    process over a box (ball counts ~ Poisson)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, size=(n, d)).astype(np.float32)


def sift_like(n: int, seed: int = 0) -> np.ndarray:
    """128-d clustered vectors (SIFT-like local-descriptor statistics)."""
    rng = np.random.default_rng(seed)
    n_clusters = 64
    centers = rng.normal(0, 1.0, size=(n_clusters, 128))
    which = rng.integers(0, n_clusters, n)
    return (centers[which] + 0.35 * rng.normal(size=(n, 128))).astype(np.float32)


def fashion_like(n: int, seed: int = 0) -> np.ndarray:
    """784-d low-rank 'image-like' vectors (10 classes, smooth structure)."""
    rng = np.random.default_rng(seed)
    basis = rng.normal(0, 1.0, size=(10, 32, 784))
    cls = rng.integers(0, 10, n)
    codes = rng.normal(0, 1.0, size=(n, 32))
    out = np.einsum("nk,nkd->nd", codes,
                    basis[cls]) / np.sqrt(32)
    return (out + 0.1 * rng.normal(size=(n, 784))).astype(np.float32)


def text_like(n: int, seed: int = 0) -> np.ndarray:
    """384-d normalised mixture embeddings (news-headline-like)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, size=(20, 384))
    which = rng.integers(0, 20, n)
    x = centers[which] + 0.5 * rng.normal(size=(n, 384))
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def hyperspectral_like(n: int, seed: int = 0) -> np.ndarray:
    """103-d smooth positive spectra (ROSIS-like pixels)."""
    rng = np.random.default_rng(seed)
    base = np.cumsum(rng.normal(0, 0.3, size=(8, 103)), axis=1)
    which = rng.integers(0, 8, n)
    x = base[which] + 0.15 * rng.normal(size=(n, 103))
    return x.astype(np.float32)


def gaussian_mixture_stream(n: int, d: int = 200, n_comp: int = 10,
                            seed: int = 0) -> np.ndarray:
    """The paper's Monte-Carlo protocol: every n/n_comp points switch to a
    new multivariate Gaussian."""
    rng = np.random.default_rng(seed)
    per = n // n_comp
    out = []
    for c in range(n_comp):
        mu = rng.normal(0, 2.0, size=d)
        out.append(mu + rng.normal(0, 1.0, size=(per, d)))
    return np.concatenate(out).astype(np.float32)[:n]


def true_topk(data: np.ndarray, queries: np.ndarray, k: int) -> np.ndarray:
    """Exact top-k neighbour indices by L2 (ground truth)."""
    d2 = ((queries[:, None, :] - data[None, :, :]) ** 2).sum(-1) \
        if data.shape[1] * len(queries) * len(data) < 2e9 else None
    if d2 is None:
        qn = (queries ** 2).sum(1)[:, None]
        dn = (data ** 2).sum(1)[None, :]
        d2 = qn + dn - 2 * queries @ data.T
    return np.argsort(d2, axis=1)[:, :k]
