"""Multi-tenant fleet benchmarks: ONE vmapped stacked-state dispatch vs a
per-tenant loop of single-sketch dispatches (repro.core.fleet).

The tentpole claim: serving T small tenants from one stacked ``[T]``-leading
pytree costs ~one big-sketch dispatch, while the per-tenant loop pays T
dispatches of tiny kernels — so fleet pps holds roughly flat in T and the
loop degrades ~linearly.  Measured here for RACE and SW-AKDE ingest (one
mixed chunk, ``PER_TENANT`` points per tenant) and RACE mixed-batch
queries (per-request tenant routing vs a per-tenant loop of
`race_query_batch`).

Both sides time the SAME committed math — the fleet paths are pinned
bit-identical to the loop in tests/test_tenant_fleet.py — and the loop side
reuses one jitted per-tenant function (equal-shaped blocks, one trace), so
the measured gap is pure dispatch/launch amortization plus kernel fusion,
not a trace-count artifact.

Emits ``name,us_per_call,derived`` CSV rows; mirrored into the ``tenant``
suite of ``BENCH_ingest.json`` (REPRO_BENCH_INGEST_OUT overrides; CI
bench-smoke asserts the vmapped fleet beats the loop for T >= 8).
REPRO_BENCH_TINY=1 shrinks T and the per-tenant chunk for CI.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fleet, lsh, race, swakde
from .common import syn_ppp, timeit, update_bench_json

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
TS = (1, 8, 64) if TINY else (1, 8, 64, 256)
# Points per tenant per mixed chunk.  Deliberately SMALL: the fleet's
# target regime is many trickle-rate tenants, where the per-tenant loop is
# dispatch-bound (T tiny kernel launches) — exactly what one vmapped
# dispatch amortizes.  Larger per-tenant chunks shift both sides toward
# compute-bound and the gap closes (see DESIGN.md §15).
PER_TENANT = 16
OUT_PATH = os.environ.get("REPRO_BENCH_INGEST_OUT", "BENCH_ingest.json")

_json_rows: list[dict] = []


def _emit(rows, name, sketch, variant, T, B, us, us_loop=None):
    pps = B * 1e6 / us
    derived = f"pps={pps:.0f};T={T}"
    speedup = 1.0
    if us_loop is not None:
        speedup = us_loop / us
        derived += f";speedup={speedup:.1f}"
    rows.append((name, us, derived))
    _json_rows.append({
        "name": name, "sketch": sketch, "variant": variant, "tenants": T,
        "points": B, "us_per_call": us, "pps": pps,
        "us_per_point": us / B, "speedup": speedup,
    })


def _mixed(T, d, seed):
    """One mixed chunk: PER_TENANT points per tenant, round-robin tags
    (the routed sort sees maximal interleaving)."""
    B = T * PER_TENANT
    xs = jnp.asarray(syn_ppp(B, d, seed=seed))
    tids = jnp.asarray(np.arange(B) % T, jnp.int32)
    return B, xs, tids


def bench_race_fleet(rows):
    d, L, W, k = 32, 8, 64, 4
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=k, n_buckets=W)
    empty = race.race_init(L, W)

    loop_fn = jax.jit(lambda st, x: race.race_commit_chunk(
        st, race.race_prepare_chunk(params, x, W)))
    fleet_fn = jax.jit(lambda st, x, t: fleet.race_fleet_ingest(
        st, params, x, t))
    qloop_fn = jax.jit(lambda st, q: race.race_query_batch(st, params, q))
    qfleet_fn = jax.jit(lambda st, q, t: fleet.race_fleet_query(
        st, params, q, t))

    for T in TS:
        B, xs, tids = _mixed(T, d, seed=T)
        stacked = fleet.fleet_broadcast(empty, T)
        per = [xs[np.asarray(tids) == t] for t in range(T)]

        us_loop = timeit(lambda: [loop_fn(empty, p) for p in per],
                         repeats=5)
        _emit(rows, f"tenant.race.ingest.loop.T{T}", "race", "loop", T, B,
              us_loop)
        us = timeit(fleet_fn, stacked, xs, tids, repeats=5)
        _emit(rows, f"tenant.race.ingest.fleet.T{T}", "race", "fleet", T, B,
              us, us_loop)

        # mixed-batch queries: per-request tenant rows, one fused call
        filled = fleet_fn(stacked, xs, tids)
        rows_t = [fleet.fleet_row(filled, t) for t in range(T)]
        us_loop = timeit(
            lambda: [qloop_fn(s, p) for s, p in zip(rows_t, per)],
            repeats=5)
        _emit(rows, f"tenant.race.query.loop.T{T}", "race", "query_loop",
              T, B, us_loop)
        us = timeit(qfleet_fn, filled, xs, tids, repeats=5)
        _emit(rows, f"tenant.race.query.fleet.T{T}", "race", "query_fleet",
              T, B, us, us_loop)


def bench_swakde_fleet(rows):
    d, L, W = 16, 8, 64
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=64 * PER_TENANT, eh_eps=0.2)
    params = lsh.init_pstable(jax.random.PRNGKey(1), d, L, 2, 1.0, W)
    empty = swakde.swakde_init(cfg)

    loop_fn = jax.jit(lambda st, x: swakde.swakde_update_chunk(
        st, params, x, cfg))
    fleet_fn = jax.jit(lambda st, x, t: fleet.swakde_fleet_ingest(
        st, params, x, t, cfg, PER_TENANT))

    for T in TS:
        B, xs, tids = _mixed(T, d, seed=100 + T)
        stacked = fleet.fleet_broadcast(empty, T)
        per = [xs[np.asarray(tids) == t] for t in range(T)]

        us_loop = timeit(lambda: [loop_fn(empty, p) for p in per],
                         repeats=5)
        _emit(rows, f"tenant.swakde.ingest.loop.T{T}", "swakde", "loop", T,
              B, us_loop)
        us = timeit(fleet_fn, stacked, xs, tids, repeats=5)
        _emit(rows, f"tenant.swakde.ingest.fleet.T{T}", "swakde", "fleet",
              T, B, us, us_loop)


def run(rows):
    _json_rows.clear()
    bench_race_fleet(rows)
    bench_swakde_fleet(rows)
    update_bench_json(OUT_PATH, "tenant", _json_rows, tiny=TINY,
                      tenant_counts=list(TS), per_tenant=PER_TENANT)
