"""Query-path benchmarks: fused batched engine vs the vmapped per-query
baseline (paper §3.3 / Corollary 3.2 and Theorem 4.3 — the *query*-side
guarantees are the headline; this suite is their throughput counterpart).

For each sketch the same batch of queries is answered two ways:

  query.<sketch>.B<B>.vmap   — `jax.vmap` over the per-query oracle
                               (`sann_query` / `sann_query_topk` /
                               `race_query` / `swakde_query`), the pre-PR-3
                               implementation of the *_batch entry points;
  query.<sketch>.B<B>.fused  — the batch-level fused engine (one hash
                               matmul + one gather + batch-wide truncation
                               / dedup / fused scorer or grid-precomputed
                               EH reads).

Both are jitted; ``derived`` carries queries-per-second and the fused-over-
vmapped speedup.  Results are also written to ``BENCH_query.json``
(override with REPRO_BENCH_OUT) so later PRs have a perf trajectory to
compare against.  REPRO_BENCH_TINY=1 shrinks every size so the suite runs
in seconds on CI CPUs (the bench-smoke job).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import lsh, race, sann, swakde
from .common import syn_ppp, timeit

TINY = os.environ.get("REPRO_BENCH_TINY") == "1"
BATCHES = (1, 8) if TINY else (1, 64, 1024)
N_POINTS = 512 if TINY else 4096
OUT_PATH = os.environ.get("REPRO_BENCH_OUT", "BENCH_query.json")

_json_rows: list[dict] = []


def _pair(rows, name, B, us_vmap, us_fused):
    """Emit the vmap/fused row pair (+ JSON mirror) for one batch size."""
    for variant, us in (("vmap", us_vmap), ("fused", us_fused)):
        qps = B * 1e6 / us
        derived = f"qps={qps:.0f}"
        if variant == "fused":
            derived += f";speedup={us_vmap / us:.2f}"
        rows.append((f"query.{name}.B{B}.{variant}", us, derived))
        _json_rows.append({
            "name": f"query.{name}.B{B}.{variant}", "sketch": name,
            "batch": B, "variant": variant, "us_per_call": us,
            "qps": qps, "speedup": (us_vmap / us) if variant == "fused"
            else 1.0,
        })


def _build_sann(bucket_cap: int):
    d = 16 if TINY else 48
    cfg = sann.SANNConfig(dim=d, n_max=N_POINTS, eta=0.3, r=0.5, c=2.0,
                          w=1.0, L=4 if TINY else 16, k=4,
                          bucket_cap=bucket_cap)
    cfg, params, st = sann.sann_init(cfg, jax.random.PRNGKey(0))
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=1))
    st = sann.sann_insert_chunked(st, params, xs, jax.random.PRNGKey(2), cfg)
    return cfg, params, st, xs


def bench_sann(rows):
    # (c, r) queries: dense-bucket regime (the paper's early-exit shines when
    # buckets hold many colliding points — 3L of L*cap candidates scored).
    cfg, params, st, xs = _build_sann(bucket_cap=8 if TINY else 64)
    vmap_cr = jax.jit(lambda s, qs: jax.vmap(
        lambda q: sann.sann_query(s, params, q, cfg))(qs))
    fused_cr = jax.jit(lambda s, qs: sann.sann_query_batch(s, params, qs, cfg))
    for B in BATCHES:
        qs = xs[jnp.arange(B) % N_POINTS] + 0.01
        _pair(rows, "sann.cr", B,
              timeit(vmap_cr, st, qs, repeats=5),
              timeit(fused_cr, st, qs, repeats=5))

    # top-k recall queries score the *full* bucket union (no 3L truncation),
    # so the (B, L*cap, d) gather dominates both engines — moderate caps.
    cfg, params, st, xs = _build_sann(bucket_cap=8 if TINY else 16)
    topk = 10
    vmap_tk = jax.jit(lambda s, qs: jax.vmap(
        lambda q: sann.sann_query_topk(s, params, q, cfg, topk))(qs))
    fused_tk = jax.jit(
        lambda s, qs: sann.sann_query_topk_batch(s, params, qs, cfg, topk))
    for B in BATCHES:
        qs = xs[jnp.arange(B) % N_POINTS] + 0.01
        _pair(rows, "sann.topk", B,
              timeit(vmap_tk, st, qs, repeats=5),
              timeit(fused_tk, st, qs, repeats=5))


def bench_race(rows):
    d, L, W, k = 32, 8 if TINY else 48, 64, 2
    params = lsh.init_srp(jax.random.PRNGKey(3), d, L=L, k=k, n_buckets=W)
    xs = jnp.asarray(syn_ppp(N_POINTS, d, seed=4))
    st = race.race_update_batch(race.race_init(L, W), params, xs)

    vmapped = jax.jit(lambda s, qs: jax.vmap(
        lambda q: race.race_query(s, params, q))(qs))
    fused = jax.jit(lambda s, qs: race.race_query_batch(s, params, qs))
    for B in BATCHES:
        qs = xs[jnp.arange(B) % N_POINTS] + 0.01
        _pair(rows, "race", B,
              timeit(vmapped, st, qs, repeats=5),
              timeit(fused, st, qs, repeats=5))


def bench_swakde(rows):
    # Long-window, tight-eps serving point (the sublinear-space headline
    # regime: window ≫ stream chunk, eps' = 0.05 → ~12 slots × 18 levels
    # per cell) with a compact-range row hash (W = 16, the canonical
    # RACE/ACE small-range regime — cf. bench_ingest's W = 2 sign-bit
    # rows): per-query reads cost B·L cell queries, the fused engine
    # precomputes the L·W grid once B ≥ W.
    d = 16
    L, W = (4, 8) if TINY else (32, 16)
    window = 256 if TINY else 65536
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=window,
                              eh_eps=0.1 if TINY else 0.05)
    params = lsh.init_srp(jax.random.PRNGKey(5), d, L=L, k=2, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(6), (N_POINTS, d))
    st = swakde.swakde_stream_batched(swakde.swakde_init(cfg), params, xs,
                                      cfg, chunk=min(1024, N_POINTS))

    vmapped = jax.jit(lambda s, qs: jax.vmap(
        lambda q: swakde.swakde_query(s, params, q, cfg))(qs))
    fused = jax.jit(lambda s, qs: swakde.swakde_query_batch(s, params, qs, cfg))
    for B in BATCHES:
        qs = xs[jnp.arange(B) % N_POINTS] + 0.01
        _pair(rows, "swakde", B,
              timeit(vmapped, st, qs, repeats=5),
              timeit(fused, st, qs, repeats=5))


def run(rows):
    _json_rows.clear()
    bench_sann(rows)
    bench_race(rows)
    bench_swakde(rows)
    payload = {
        "suite": "query",
        "backend": jax.default_backend(),
        "tiny": TINY,
        "batch_sizes": list(BATCHES),
        "results": _json_rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
