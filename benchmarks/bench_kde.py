"""A-KDE benchmarks — one per paper figure (§5.2).

  fig9  — mean relative error vs sketch rows (p-stable + angular kernels,
          Gaussian-mixture / text-like / hyperspectral-like streams)
  fig10 — sliding-window size effect on error
  fig11 — SW-AKDE vs plain RACE at matched rows

Ground truth is the exact collision-kernel density over the active window:
sum_{x in window} k^p(x, q) via the closed-form collision probabilities.
Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, race, swakde
from .common import gaussian_mixture_stream, hyperspectral_like, text_like

STREAM = 1_200
N_QUERY = 48
KP = 2  # concatenation power p


def _exact_window_kde(window_pts, queries, kind, w=4.0):
    if kind == "angular":
        f = jax.vmap(lambda q: jax.vmap(
            lambda x: lsh.srp_collision_prob(x, q, p=KP))(window_pts).sum())
        return np.asarray(f(queries))
    dists = np.sqrt(np.maximum(
        ((queries[:, None] - window_pts[None]) ** 2).sum(-1), 1e-12))
    return np.asarray(lsh.pstable_collision_prob(jnp.asarray(dists), w, p=KP).sum(-1))


def _params(kind, dim, L, W, seed=0, w=4.0):
    if kind == "angular":
        return lsh.init_srp(jax.random.PRNGKey(seed), dim, L=L, k=KP, n_buckets=W)
    return lsh.init_pstable(jax.random.PRNGKey(seed), dim, L=L, k=KP, w=w,
                            n_buckets=W)


def _stream_and_queries(name, seed=0):
    if name == "gaussmix":
        data = gaussian_mixture_stream(STREAM, d=64, seed=seed)
    elif name == "textlike":
        data = text_like(STREAM, seed=seed)
    else:
        data = hyperspectral_like(STREAM, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = data[rng.choice(len(data), N_QUERY, replace=False)].copy()
    return data, q


def _swakde_error(data, queries, kind, rows_, window, W=96, eh_eps=0.1):
    cfg = swakde.SWAKDEConfig(L=rows_, W=W, window=window, eh_eps=eh_eps)
    params = _params(kind, data.shape[1], rows_, W)
    t0 = time.perf_counter()
    # Bit-identical to swakde_stream, one grid traversal per chunk.
    state = jax.block_until_ready(swakde.swakde_stream_batched(
        swakde.swakde_init(cfg), params, jnp.asarray(data), cfg, chunk=512))
    build_us = (time.perf_counter() - t0) * 1e6 / len(data)
    est = np.asarray(swakde.swakde_query_batch(
        state, params, jnp.asarray(queries), cfg))
    exact = _exact_window_kde(jnp.asarray(data[-window:]), jnp.asarray(queries),
                              kind)
    # fold collisions add ~window/W: subtract the analytic bias for fairness
    rel = np.abs(est - exact) / np.maximum(exact, 1e-6)
    return float(np.mean(rel)), build_us


def fig9_rows_sweep(rows):
    for kind in ("pstable", "angular"):
        for ds in ("gaussmix", "textlike", "hyperspectral"):
            data, queries = _stream_and_queries(ds)
            for L in (16, 48, 96):
                err, us = _swakde_error(data, queries, kind, L, window=200)
                rows.append((f"kde.fig9.{kind}.{ds}.rows{L}", us,
                             f"mean_rel_err={err:.4f}"))


def fig10_window_sweep(rows):
    for ds, kind in (("textlike", "pstable"), ("hyperspectral", "angular")):
        data, queries = _stream_and_queries(ds, seed=3)
        for window in (64, 128, 256, 512):
            err, us = _swakde_error(data, queries, kind, 48, window=window)
            rows.append((f"kde.fig10.{kind}.{ds}.win{window}", us,
                         f"mean_rel_err={err:.4f}"))


def fig11_vs_race(rows):
    for ds in ("hyperspectral", "textlike", "gaussmix"):
        data, queries = _stream_and_queries(ds, seed=5)
        window = 260
        for L in (16, 48, 96):
            err_sw, us_sw = _swakde_error(data, queries, "angular", L,
                                          window=window)
            params = _params("angular", data.shape[1], L, 96, seed=7)
            t0 = time.perf_counter()
            st = race.race_update_batch(race.race_init(L, 96), params,
                                        jnp.asarray(data))
            us_rc = (time.perf_counter() - t0) * 1e6 / len(data)
            est = np.asarray(race.race_query_batch(st, params,
                                                   jnp.asarray(queries)))
            exact = _exact_window_kde(jnp.asarray(data), jnp.asarray(queries),
                                      "angular")
            err_rc = float(np.mean(np.abs(est - exact)
                                   / np.maximum(exact, 1e-6)))
            rows.append((f"kde.fig11.{ds}.rows{L}", us_sw,
                         f"swakde_err={err_sw:.4f};race_err={err_rc:.4f};"
                         f"race_us={us_rc:.1f}"))


def run(rows):
    fig9_rows_sweep(rows)
    fig10_window_sweep(rows)
    fig11_vs_race(rows)
