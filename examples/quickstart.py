"""Quickstart: the paper's two sketches in ~40 lines each.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, sann, swakde


def streaming_ann():
    print("== Streaming (c,r)-ANN (paper §3) ==")
    n, d = 5_000, 16
    rng = np.random.default_rng(0)
    stream = rng.uniform(0, 1, (n, d)).astype(np.float32)  # Poisson-ish cloud

    cfg = sann.SANNConfig(dim=d, n_max=n, eta=0.4, r=0.8, c=2.0, w=1.6,
                          L=10, k=4)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(0))
    # batched ingest: one hash matmul + one segment scatter per chunk,
    # bit-identical to the per-point sann_insert_stream under the same key
    state = sann.sann_insert_batch(state, params, jnp.asarray(stream),
                                   jax.random.PRNGKey(1), cfg)
    print(f"  stream={n}  stored={int(state.n_stored)} "
          f"(keep prob n^-eta = {cfg.keep_prob:.3f})")

    queries = jnp.asarray(stream[rng.choice(n, 8)] + 0.01)
    res = sann.sann_query_batch(state, params, queries, cfg)
    for i in range(8):
        print(f"  q{i}: found={bool(res.found[i])} "
              f"dist={float(res.distance[i]):.3f} "
              f"candidates={int(res.n_candidates[i])}")

    # turnstile deletion (§3.4)
    state = sann.sann_delete(state, params, jnp.asarray(stream[0]), cfg)
    print(f"  after delete: stored={int(state.n_stored)}")


def sliding_window_kde():
    print("== Sliding-window A-KDE (paper §4) ==")
    d, window = 8, 100
    cfg = swakde.SWAKDEConfig(L=16, W=64, window=window, eh_eps=0.1)
    params = lsh.init_srp(jax.random.PRNGKey(2), d, L=16, k=2, n_buckets=64)
    state = swakde.swakde_init(cfg)

    rng = np.random.default_rng(3)
    cluster_a = rng.normal(+2, 0.3, (150, d)).astype(np.float32)
    cluster_b = rng.normal(-2, 0.3, (150, d)).astype(np.float32)
    state = swakde.swakde_stream(state, params,
                                 jnp.asarray(np.concatenate([cluster_a,
                                                             cluster_b])), cfg)
    qa = jnp.full((d,), +2.0)
    qb = jnp.full((d,), -2.0)
    da = float(swakde.swakde_query(state, params, qa, cfg))
    db = float(swakde.swakde_query(state, params, qb, cfg))
    print(f"  window holds the last {window} points (cluster B)")
    print(f"  density at A-center: {da:.2f}   density at B-center: {db:.2f}")
    print(f"  -> expired cluster A correctly forgotten: {da < 0.2 * db}")
    print(f"  sketch bytes: {swakde.swakde_bytes(cfg):,} "
          f"(eps={cfg.kde_eps:.2f} guarantee)")


if __name__ == "__main__":
    streaming_ann()
    sliding_window_kde()
