"""Sliding-window A-KDE on a drifting stream + batch-update variant
(paper §4 + Corollary 4.2), with the error measured against the exact
collision-kernel density.

Run: PYTHONPATH=src python examples/sliding_window_kde.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, swakde


def main():
    d, window, L, W = 24, 150, 32, 96
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=window, eh_eps=0.1)
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=2, n_buckets=W)

    rng = np.random.default_rng(1)
    phases = [rng.normal(m, 0.5, (200, d)).astype(np.float32)
              for m in (2.0, -2.0, 0.0)]
    stream = np.concatenate(phases)

    state = swakde.swakde_init(cfg)
    # chunked batched ingest — bit-identical to the per-point swakde_stream
    state = swakde.swakde_stream_batched(state, params, jnp.asarray(stream),
                                         cfg, chunk=128)

    q = jnp.asarray(phases[2][:8])   # query near the current phase
    est = np.asarray(swakde.swakde_query_batch(state, params, q, cfg))
    win = jnp.asarray(stream[-window:])
    exact = np.asarray(jax.vmap(
        lambda qq: jax.vmap(lambda x: lsh.srp_collision_prob(x, qq, p=2))(win).sum())(q))
    rel = np.abs(est - exact) / np.maximum(exact, 1e-6)
    print(f"single-update sketch: mean rel err {rel.mean():.3f} "
          f"(theory bound {cfg.kde_eps:.2f})")
    print(f"sketch bytes: {swakde.swakde_bytes(cfg):,}")

    # batch updates (Corollary 4.2): window counts the last N *batches*
    R = 10
    bcfg = swakde.BatchSWAKDEConfig(L=L, W=W, window=window // R, eh_eps=0.1,
                                    batch_size=R)
    bstate = swakde.batch_swakde_init(bcfg)
    for i in range(len(stream) // R):
        bstate = swakde.batch_swakde_update(
            bstate, params, jnp.asarray(stream[i * R:(i + 1) * R]), bcfg)
    best = np.asarray(jax.vmap(
        lambda qq: swakde.batch_swakde_query(bstate, params, qq, bcfg))(q))
    brel = np.abs(best - exact) / np.maximum(exact, 1e-6)
    print(f"batch-update sketch (R={R}): mean rel err {brel.mean():.3f}")


if __name__ == "__main__":
    main()
