"""End-to-end serving driver: a small LM decodes with batched requests while
an S-ANN retrieval service indexes the stream of its hidden states — the
paper's sketch as first-class serving infrastructure.

Run: PYTHONPATH=src python examples/serve_retrieval.py [--steps 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model as model_lib
from repro.serve import kv_cache, serve_step as serve_lib
from repro.serve.retrieval import RetrievalConfig, RetrievalService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get_smoke_config("qwen3-4b")
    params = model_lib.init_model(cfg, jax.random.PRNGKey(0))
    B, S_max = args.batch, args.steps + 8
    cache = kv_cache.init_cache(cfg, B=B, s_max=S_max)
    step = jax.jit(serve_lib.make_serve_step(cfg))

    retr = RetrievalService(RetrievalConfig(dim=cfg.d_model, n_max=10_000,
                                            eta=0.3, r=0.35, c=2.0))

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    t0 = time.time()
    for t in range(args.steps):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # stream the step's hidden summary into the ANN index
        # (here: the logits' top activations as a cheap embedding surrogate)
        emb = np.array(logits[:, 0, : cfg.d_model], np.float32)  # writable copy
        emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-6
        retr.ingest(emb)
    dt = time.time() - t0
    print(f"decoded {args.steps} steps x batch {B} "
          f"({args.steps * B / dt:.1f} tok/s on CPU)")
    print(f"retrieval index: {retr.stored} stored vectors, "
          f"{retr.sketch_bytes:,} sketch bytes")

    # batched queries against the decode-time stream (Corollary 3.2)
    res = retr.query(emb)
    print(f"batched query: found={np.asarray(res.found).mean():.2f} "
          f"mean_dist={np.asarray(res.distance)[np.asarray(res.found)].mean():.3f}")


if __name__ == "__main__":
    main()
