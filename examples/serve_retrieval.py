"""End-to-end serving driver: a small LM decodes with batched requests while
an S-ANN retrieval service indexes the stream of its hidden states — the
paper's sketch as first-class serving infrastructure.

The service ingests through the two-phase pipelined path (`serve.engine
.SketchEngine`: prepare — hash matmul + sort — of chunk k+1 overlaps the
commit of chunk k): the synthetic document corpus is submitted with
``ingest_async`` and the decode loop starts immediately; queries during
the bulk load see a committed prefix of the corpus, and ``flush()`` later
guarantees the full corpus is in — the state is bit-identical to a
synchronous ``ingest``.

``--num-shards N`` demos the sharded service (`repro.parallel
.sketch_sharding`): the L hash tables are split across N devices — on a
CPU-only box the devices are virtual (forced via XLA_FLAGS before jax
initialises), and results are bit-identical to the single-device service.

``--num-workers K`` demos the merge-based cluster (`repro.serve.cluster
.ClusterRetrievalService`): the corpus is hash-partitioned across K worker
engines ingesting concurrently, and queries run against the coordinator's
`sann_merge`-combined sketch.

``--snapshot-dir DIR`` demos durability (`repro.persist`): every ingest
chunk is WAL-logged at enqueue time and the engine snapshots its state in
the background; re-running with the same DIR recovers the previous run's
index bit-identically (snapshot + WAL-tail replay) before ingesting more.

``--rpc`` demos the network cluster (`repro.net`, DESIGN.md §16) — the
workers as separate PROCESSES behind the RPC front door:

  * ``--rpc spawn`` — the coordinator spawns ``--num-workers`` worker
    processes itself (the common one-box case);
  * ``--rpc worker [--port P] [--worker-index W]`` — run THIS process as
    worker W: it builds worker W's engine config (per-worker salt +
    durability subdir, exactly what the coordinator would build) and
    serves RPC until shutdown — e.g. in a second terminal;
  * ``--rpc connect --peers host:port[,host:port...]`` — the coordinator
    connects to externally-started workers.  List peers in worker-index
    order and start every worker with the same flags as the coordinator:
    the cluster's exactness rests on worker W running the config the
    coordinator assumes.

Run: PYTHONPATH=src python examples/serve_retrieval.py [--steps 24]
     PYTHONPATH=src python examples/serve_retrieval.py --num-shards 4
     PYTHONPATH=src python examples/serve_retrieval.py --num-workers 2
     PYTHONPATH=src python examples/serve_retrieval.py \
         --snapshot-dir /tmp/retr_snap   # run twice: 2nd run recovers
     PYTHONPATH=src python examples/serve_retrieval.py --rpc spawn \
         --num-workers 2                 # multi-process cluster
     # two-terminal form:
     PYTHONPATH=src python examples/serve_retrieval.py --rpc worker \
         --port 7461                     # terminal 1: worker 0
     PYTHONPATH=src python examples/serve_retrieval.py --rpc connect \
         --peers 127.0.0.1:7461          # terminal 2: coordinator
"""
import argparse
import os
import time


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--corpus", type=int, default=3000,
                    help="synthetic documents pre-ingested in chunks")
    ap.add_argument("--ingest-chunk", type=int, default=512)
    ap.add_argument("--num-shards", type=int, default=0,
                    help="shard the L hash tables across this many devices "
                         "(0/1 = single-device)")
    ap.add_argument("--num-workers", type=int, default=0,
                    help="hash-partition ingest across this many worker "
                         "engines with a merge-based coordinator "
                         "(0/1 = single engine)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="durability directory (WAL + snapshots); an "
                         "existing one is recovered before ingesting")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="admission control: bound queued-but-uncommitted "
                         "rows (0 = unbounded)")
    ap.add_argument("--rpc", default=None,
                    choices=["spawn", "worker", "connect"],
                    help="network cluster mode: 'spawn' worker processes, "
                         "run as a 'worker', or 'connect' to --peers")
    ap.add_argument("--port", type=int, default=0,
                    help="--rpc worker: listen port (0 = ephemeral, "
                         "announced on stdout)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--rpc worker: listen address")
    ap.add_argument("--worker-index", type=int, default=0,
                    help="--rpc worker: which cluster slot this worker is")
    ap.add_argument("--peers", default=None,
                    help="--rpc connect: comma-separated host:port list, "
                         "in worker-index order")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.num_shards > 1:
        # must be set before jax initialises its backends; append to any
        # pre-existing XLA_FLAGS rather than losing either side
        existing = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in existing:
            os.environ["XLA_FLAGS"] = (
                existing + " --xla_force_host_platform_device_count="
                f"{args.num_shards}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import registry
    from repro.models import model as model_lib
    from repro.serve import kv_cache, serve_step as serve_lib
    from repro.serve.cluster import ClusterRetrievalService
    from repro.serve.retrieval import RetrievalConfig, RetrievalService

    cfg = registry.get_smoke_config("qwen3-4b")
    retr_cfg = RetrievalConfig(
        dim=cfg.d_model, n_max=10_000, eta=0.3, r=0.35, c=2.0,
        ingest_chunk=args.ingest_chunk, num_shards=args.num_shards,
        max_pending=args.max_pending or None,
        snapshot_dir=args.snapshot_dir)

    if args.rpc == "worker":
        # This process IS worker --worker-index: build that slot's engine
        # config (per-worker salt + durability subdir — exactly what the
        # coordinator's in-process cluster would build) and serve RPC
        # until a coordinator sends shutdown.
        import dataclasses

        from repro.net import run_worker
        from repro.serve.cluster import _worker_cfg
        wcfg = _worker_cfg(retr_cfg, args.worker_index,
                           ingest_salt=args.worker_index,
                           batch_queries=False)
        run_worker("retrieval", dataclasses.asdict(wcfg), host=args.host,
                   port=args.port)
        return

    params = model_lib.init_model(cfg, jax.random.PRNGKey(0))
    B, S_max = args.batch, args.steps + 8
    cache = kv_cache.init_cache(cfg, B=B, s_max=S_max)
    step = jax.jit(serve_lib.make_serve_step(cfg))

    if args.rpc in ("spawn", "connect"):
        from repro.net import RPCClusterRetrievalService, RPCConfig
        peers = None
        if args.rpc == "connect":
            if not args.peers:
                raise SystemExit("--rpc connect requires --peers")
            peers = tuple(
                (h, int(p)) for h, p in
                (s.rsplit(":", 1) for s in args.peers.split(",")))
            nw = len(peers)
        else:
            nw = max(args.num_workers, 2)
        retr = RPCClusterRetrievalService(retr_cfg, num_workers=nw,
                                          rpc=RPCConfig(peers=peers))
        mode = (f"connected to {args.peers}" if peers
                else f"spawned {nw} worker processes")
        print(f"network cluster retrieval service: {mode} "
              f"(RPC scatter-gather, merge-based coordinator)")
    elif args.num_workers > 1:
        retr = ClusterRetrievalService(retr_cfg,
                                       num_workers=args.num_workers)
        print(f"cluster retrieval service: {args.num_workers} workers "
              f"(hash-partitioned ingest, merge-based coordinator)")
    else:
        retr = RetrievalService(retr_cfg)
        print(f"retrieval service: {retr.num_shards} shard(s), "
              f"ingest_chunk={args.ingest_chunk}")
    if args.snapshot_dir:
        replayed = retr.recover()   # no-op on an empty directory
        print(f"durability at {args.snapshot_dir}: recovered "
              f"{replayed} WAL record(s), "
              f"{getattr(retr, 'stored', 0)} vectors live")

    # Submit the document corpus asynchronously: the engine's prepare
    # thread hashes chunk k+1 while chunk k commits, and the decode loop
    # below starts while the tail of the corpus is still loading.
    rng = np.random.default_rng(7)
    corpus = rng.normal(0, 1, (args.corpus, cfg.d_model)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True) + 1e-6
    t0 = time.time()
    retr.ingest_async(corpus)
    print(f"bulk load submitted: {args.corpus} docs in "
          f"{-(-args.corpus // args.ingest_chunk)} chunks "
          f"(committed so far: {retr.version})")
    retr.flush()                        # wait for every chunk to commit
    dt = time.time() - t0
    print(f"bulk ingest flushed: {args.corpus / dt:.0f} docs/s, "
          f"stored={retr.stored}")

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    t0 = time.time()
    for t in range(args.steps):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # stream the step's hidden summary into the ANN index
        # (here: the logits' top activations as a cheap embedding surrogate)
        emb = np.array(logits[:, 0, : cfg.d_model], np.float32)  # writable copy
        emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-6
        retr.ingest(emb)
    dt = time.time() - t0
    print(f"decoded {args.steps} steps x batch {B} "
          f"({args.steps * B / dt:.1f} tok/s on CPU)")
    print(f"retrieval index: {retr.stored} stored vectors, "
          f"{retr.sketch_bytes:,} sketch bytes")

    # batched queries against the decode-time stream (Corollary 3.2)
    res = retr.query(emb)
    found = np.asarray(res.found)
    mean_d = (np.asarray(res.distance)[found].mean()
              if found.any() else float("nan"))
    print(f"batched query: found={found.mean():.2f} mean_dist={mean_d:.3f}")
    retr.close()     # --rpc: shuts workers down (incl. external --peers)


if __name__ == "__main__":
    main()
