"""End-to-end training driver: a ~100M-parameter qwen3-family model on the
streaming pipeline with the SW-AKDE drift monitor, checkpointing, and
resume — the full trainer stack at laptop scale.

Run: PYTHONPATH=src python examples/train_lm.py --steps 200
(CPU note: ~100M params trains a few steps/min; --d-model 128 for a fast demo)
"""
import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "adam8bit", "adafactor"))
    args = ap.parse_args()

    cfg = dataclasses.replace(
        registry.get_config("qwen3-4b"),
        n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=4 * args.d_model, vocab=32_000, seq_parallel=False)
    n_params = cfg.param_count()
    print(f"model: {args.layers}L d={args.d_model} ~{n_params/1e6:.0f}M params "
          f"(qwen3 family: GQA + qk-norm)")

    data_cfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, optimizer=args.optimizer,
                         lr=3e-4, log_every=10, monitor_drift=True)
    out = Trainer(cfg, data_cfg, tcfg).run(jax.random.PRNGKey(0))
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over {len(h)} steps")
    drifts = [r for r in h if r.get("drift", {}).get("drift")]
    print(f"drift flags: {len(drifts)}; stragglers: "
          f"{sum(r['straggler'] for r in h)}")


if __name__ == "__main__":
    main()
