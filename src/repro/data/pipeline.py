"""Streaming data pipeline with the paper's SW-AKDE drift monitor.

The pipeline yields fixed-shape token batches from a (synthetic, seeded)
document stream, with background prefetch.  Every batch's embedding sketch
is fed to a **sliding-window A-KDE** (paper §4): the density of incoming
examples under the recent window flags distribution shift (density at the
new batch collapses) and near-duplicate floods (density spikes) — the
paper's own motivating streaming application, wired into training.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, swakde


@dataclasses.dataclass
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    n_shards: int = 1          # simulated hosts
    shard_id: int = 0
    drift_window: int = 256    # SW-AKDE window (batches are the stream unit)
    drift_rows: int = 8
    drift_width: int = 128
    drift_dim: int = 32        # hashed token-histogram embedding dim
    prefetch: int = 2


class DriftMonitor:
    """SW-AKDE over per-example embeddings; z-scored density flags drift."""

    def __init__(self, cfg: DataConfig):
        self.cfg = swakde.SWAKDEConfig(
            L=cfg.drift_rows, W=cfg.drift_width, window=cfg.drift_window,
            eh_eps=0.2)
        self.params = lsh.init_srp(
            jax.random.PRNGKey(cfg.seed + 7), cfg.drift_dim,
            L=cfg.drift_rows, k=4, n_buckets=cfg.drift_width)
        self.state = swakde.swakde_init(self.cfg)
        self._proj = np.random.default_rng(cfg.seed + 13).standard_normal(
            (cfg.vocab, cfg.drift_dim)).astype(np.float32) / np.sqrt(cfg.drift_dim)
        self._hist: list[float] = []
        self._update = jax.jit(
            lambda s, x: swakde.swakde_update(s, self.params, x, self.cfg))
        self._query = jax.jit(
            lambda s, x: swakde.swakde_query(s, self.params, x, self.cfg))

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        """Hashed bag-of-tokens embedding (B, drift_dim)."""
        out = self._proj[tokens.reshape(tokens.shape[0], -1)].mean(axis=1)
        return out / np.maximum(np.linalg.norm(out, axis=-1, keepdims=True), 1e-6)

    def observe(self, tokens: np.ndarray) -> dict:
        emb = self.embed(tokens)
        mean_x = jnp.asarray(emb.mean(axis=0))
        density = float(self._query(self.state, mean_x))
        for e in emb:  # window unit = examples
            self.state = self._update(self.state, jnp.asarray(e))
        warm = len(self._hist) >= 8
        recent = float(np.median(self._hist[-16:])) if warm else density
        self._hist.append(density)
        hist = np.asarray(self._hist[-64:])
        mu, sd = float(hist.mean()), float(hist.std() + 1e-6)
        return {"density": density, "z": (density - mu) / sd,
                # relative drop/spike vs the recent plateau — robust to the
                # warmup ramp of the sliding window
                "drift": bool(warm and density < 0.4 * max(recent, 1e-6)),
                "dup_flood": bool(warm and density > 2.5 * max(recent, 1e-6))}


class TokenStream:
    """Deterministic synthetic document stream, shardable by host."""

    def __init__(self, cfg: DataConfig, drift_at: Optional[int] = None):
        self.cfg = cfg
        self.drift_at = drift_at
        self._i = 0

    def next_batch(self) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng(
            hash((c.seed, c.shard_id, self._i)) % 2**32)
        # zipf-ish unigram stream; optional distribution shift for tests
        alpha = 1.3 if (self.drift_at is None or self._i < self.drift_at) else 2.5
        toks = rng.zipf(alpha, size=(c.batch, c.seq)).astype(np.int64)
        offset = 0 if (self.drift_at is None or self._i < self.drift_at) \
            else c.vocab // 2
        toks = (toks + offset) % c.vocab
        self._i += 1
        return toks.astype(np.int32)

    def state(self) -> dict:
        return {"i": self._i}

    def restore(self, state: dict) -> None:
        self._i = int(state["i"])  # may arrive as a restored jax scalar


def batches(cfg: DataConfig, monitor: Optional[DriftMonitor] = None,
            drift_at: Optional[int] = None) -> Iterator[dict]:
    """Prefetching iterator of {'tokens': (B, S) int32, 'drift': metrics}."""
    stream = TokenStream(cfg, drift_at=drift_at)
    q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)

    def producer():
        while True:
            q.put(stream.next_batch())

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        toks = q.get()
        out = {"tokens": jnp.asarray(toks)}
        if monitor is not None:
            out["drift"] = monitor.observe(toks)
        yield out
