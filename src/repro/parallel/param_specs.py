"""Parameter sharding rules: path-aware TP/EP + FSDP spec assignment.

Every weight gets (a) a tensor-parallel "model" axis on the dimension its
matmul is split over (column-parallel for up-projections, row-parallel for
down-projections, expert dim for MoE, vocab for embeddings), and (b) an FSDP
"data" axis on another dimension when divisible (ZeRO-3: XLA all-gathers
just-in-time inside the step and reduce-scatters gradients).

Layer-stacked leaves (under */blocks*) never shard their leading (layer)
dim — lax.scan slices it every iteration.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name → (model-parallel dim, fsdp-preference dims), negative = from end
_TP_RULES = {
    # attention
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    # mlp
    "w_up": -1, "w_gate": -1, "w_down": -2,
    # embeddings
    "embed": 0, "unembed": -1,
    # mla
    "w_uk": -1, "w_uv": -1, "w_uq": -1, "w_q": -1, "w_o": -2,
    # ssm / xlstm projections: FSDP only (feature dims are split into
    # heterogeneous segments downstream)
    "w_in": None, "w_out": None, "w_x": None, "w_r": None,
    "w_if": None, "w_down_x": None, "w_dq": None, "w_dkv": None,
    "patch_proj": None, "proj": None, "router": None,
    "conv_w": None, "conv_b": None,
}

_MOE_EXPERT_LEAVES = ("w_gate", "w_up", "w_down")


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return names


def spec_for_param(path, shape, mesh: Mesh,
                   fsdp_over_pod: bool = False) -> P:
    names = _path_names(path)
    leaf = names[-1] if names else ""
    msize = mesh.shape.get("model", 1)
    fsdp_ax = ("data", "pod") if (fsdp_over_pod and "pod" in mesh.axis_names) \
        else "data"
    dsize = mesh.shape.get("data", 1) * (
        mesh.shape.get("pod", 1) if isinstance(fsdp_ax, tuple) else 1)
    ndim = len(shape)
    axes: list[Optional[str]] = [None] * ndim

    stacked = any("blocks" in n for n in names)
    lo = 1 if (stacked and ndim >= 2) else 0   # never shard the layer dim

    in_moe = "moe" in names
    if in_moe and leaf in _MOE_EXPERT_LEAVES and ndim - lo >= 3:
        # (L?, E, d, f): EP — experts on "model" (required by moe_ep shard_map)
        if shape[lo] % msize == 0:
            axes[lo] = "model"
        # FSDP the largest remaining dim
        rest = sorted(range(lo + 1, ndim), key=lambda i: -shape[i])
        for i in rest:
            if shape[i] % dsize == 0:
                axes[i] = fsdp_ax
                break
        return P(*axes)

    tp_dim = _TP_RULES.get(leaf, None)
    if leaf in ("unembed", "embed") and ndim - lo >= 2:
        # vocab dim carries BOTH model and fsdp axes: gathering the (small)
        # weight beats psum-ing fp32 (B,S,V) logits over the contraction
        # (§Perf hillclimb 2)
        vdim = (0 if leaf == "embed" else ndim - 1)
        both = ("model",) + (fsdp_ax if isinstance(fsdp_ax, tuple) else (fsdp_ax,))
        sz = msize * dsize
        if shape[vdim] % sz == 0:
            axes[vdim] = both
            return P(*axes)
        if shape[vdim] % msize == 0:
            axes[vdim] = "model"
            return P(*axes)
    if tp_dim is not None and ndim - lo >= 2:
        i = tp_dim % ndim
        if i >= lo and shape[i] % msize == 0:
            axes[i] = "model"
    # FSDP: largest unassigned dim divisible by the data axis
    rest = sorted(range(lo, ndim), key=lambda i: -shape[i])
    for i in rest:
        if axes[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize * 2:
            axes[i] = fsdp_ax
            break
    return P(*axes)


def param_shardings(params_shapes, mesh: Mesh, fsdp_over_pod: bool = False):
    """Pytree of ShapeDtypeStructs (or arrays) → pytree of NamedShardings."""
    def f(path, leaf):
        return NamedSharding(mesh, spec_for_param(
            path, leaf.shape, mesh, fsdp_over_pod=fsdp_over_pod))
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def batch_shardings(batch_shapes, mesh: Mesh):
    """Inputs: batch dim over all DP axes."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def f(leaf):
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(f, batch_shapes)


def opt_state_shardings(opt_shapes, params_shardings, mesh: Mesh):
    """Optimizer moments inherit their param's sharding when shapes match;
    quantised/factored states fall back to a flat FSDP split."""
    flat_params = {tuple(_path_names(p)): s.spec for p, s in
                   jax.tree_util.tree_flatten_with_path(params_shardings)[0]}

    def f(path, leaf):
        names = tuple(_path_names(path))
        # match on the param-path suffix inside the optimizer-state tree
        for pnames, spec in flat_params.items():
            if names[-len(pnames):] == pnames and len(spec) == len(leaf.shape):
                # strip axes that no longer divide (e.g. per-block scale dims)
                fixed = []
                for dim, ax in zip(leaf.shape, spec):
                    sz = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        if a is not None:
                            sz *= mesh.shape[a]
                    fixed.append(ax if ax is not None and dim % sz == 0 else None)
                return NamedSharding(mesh, P(*fixed))
        # fallback (8-bit codes/scales, factored stats): shard dim0 as hard
        # as divisibility allows — jointly over (data, model) if possible.
        dsize = mesh.shape.get("data", 1)
        msize = mesh.shape.get("model", 1)
        if leaf.ndim >= 1:
            n0 = leaf.shape[0]
            for axes in ((("data", "model"),), ("data",), ("model",)):
                sz = 1
                for a in (axes[0] if isinstance(axes[0], tuple) else (axes[0],)):
                    sz *= mesh.shape.get(a, 1)
                if n0 % sz == 0 and n0 >= 2 * sz:
                    return NamedSharding(
                        mesh, P(*([axes[0]] + [None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(f, opt_shapes)
