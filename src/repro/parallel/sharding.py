"""Logical-axis sharding rules (MaxText-style) for the (pod, data, model) mesh.

Model code annotates activations/params with *logical* axis names; this module
maps them onto mesh axes.  With no mesh in scope every annotation is a no-op,
so the same model code runs on 1 CPU device and on the 512-chip dry-run mesh.

Parallelism mapping (DESIGN.md §4):
  DP  — "batch"  → ("pod", "data")   gradient all-reduce crosses pods once
  FSDP— "fsdp"   → "data"            params/optimizer sharded over data too
  TP  — "heads"/"ffn"/"vocab"/"experts" → "model"
  SP  — "kv_seq" → "data"            long-context decode shards the cache
  EP  — "experts" → "model"          routed experts, all-to-all dispatch
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name → mesh axis (or tuple of axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "embed": None,          # d_model stays replicated (activations)
    "seq": None,
    "seq_sp": "model",      # Megatron-SP residual stream between layers
    "kv_seq": "data",       # sequence parallelism for long-context decode
    "conv": None,
    "state": None,
    # sketch-state axes (repro.parallel.sketch_sharding): RACE / SW-AKDE
    # rows and S-ANN tables live on the 1-D "shard" mesh axis; on meshes
    # without that axis (the training meshes above) they stay replicated.
    "sketch_rows": "shard",
    "sketch_tables": "shard",
    # stacked tenant-fleet states (repro.core.fleet): the leading [T]
    # tenant axis splits across the same 1-D "shard" mesh.
    "tenants": "shard",
}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: dict

    def spec(self, *names: Optional[str]) -> P:
        axes = []
        for n in names:
            ax = self.rules.get(n) if n else None
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if self.mesh and a in self.mesh.axis_names)
                ax = ax or None
            elif ax is not None and (not self.mesh or ax not in self.mesh.axis_names):
                ax = None
            axes.append(ax)
        return P(*axes)

    def constrain(self, x: jax.Array, *names: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*names)))

    def named(self, *names: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*names))


def make_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None) -> ShardingCtx:
    return ShardingCtx(mesh=mesh, rules=dict(DEFAULT_RULES, **(rules or {})))


NULL_CTX = ShardingCtx(mesh=None, rules=DEFAULT_RULES)
