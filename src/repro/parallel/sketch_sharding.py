"""Multi-device sharded ingest & query for the three sketches (shard_map).

All three sketches are mergeable histograms, which is exactly what makes
them shardable (the property RACE [CS20] exploits for distributed
sketching, and the batch/Turnstile model of the paper's Corollary 4.2
assumes for partitioned updates):

  * **RACE** — ``counts (L, W)`` sharded along the **L (rows)** axis.  Each
    device hashes the chunk with its own row block of the LSH params and
    updates its rows; a query all-gathers the per-row counter reads and
    applies `core.race.estimate_from_vals` — the psum-style combine is the
    row mean, and the result is *bit-identical* to single-device.
  * **SW-AKDE** — the EH grid ``(L, W, levels, slots)`` sharded along L,
    same scheme: rows are untouched by sharding, queries all-gather the
    per-row EH estimates (`core.swakde.swakde_row_estimates`).  Cross-
    *worker* combine (different sub-streams, same clock) is the exact EH
    merge `core.swakde.swakde_merge`.
  * **S-ANN** — sharded **by table**: ``tables (L, n_buckets, cap)`` and
    ``table_ptr`` split along L; the point store / valid mask / counters
    are replicated (every device makes the same keep decisions from the
    same key, so the replicas stay bit-identical by construction).  Queries
    either all-gather the candidate blocks and reuse the single-device
    truncate-and-score (`sharded_sann_query_batch`, bit-identical), or
    merge per-shard top-ks (`sharded_sann_query_topk_batch` — exact, since
    the global top-k is contained in the union of per-shard top-ks).

Every sharded ingest entry point reuses the PR-1 batched kernel per shard
(`race_update_batch`, `swakde_update_chunk`, `sann_insert_batch`): the
per-shard computation *is* the single-device computation on a row/table
block, so sharded state equals single-device state block-for-block
(tests/test_distributed.py asserts this bitwise on 8 host devices).

The two-phase ingest contract (DESIGN.md §10) is sharded the same way:
``sharded_*_prepare_chunk`` runs the pure prepare per shard (prep pytrees
stay row/table-sharded; S-ANN's keep decisions are replicated by
construction) and ``sharded_*_commit_chunk`` folds them in —
``sharded_commit(sharded_prepare(...))`` is bit-identical to the fused
sharded ingest call, which is what lets `repro.serve.engine` overlap
preparing chunk k+1 with committing chunk k on a mesh too.

The mesh is a 1-D ``("shard",)`` mesh built with the existing
`ShardingCtx`/`make_ctx` machinery (`make_sketch_ctx`); ``ctx.mesh is
None`` short-circuits every function here back to the plain single-device
call, so services can hold one code path.

Works on any backend; CPU CI forces 8 host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding

from repro.core import fleet, lsh, race, sann, swakde
from repro.core.race import race_merge  # noqa: F401  (re-export: merge API)
from repro.core.sann import sann_merge  # noqa: F401  (re-export)
from repro.core.swakde import swakde_merge  # noqa: F401  (re-export)

from .sharding import ShardingCtx, make_ctx

SHARD_AXIS = "shard"

try:  # jax >= 0.6: top-level shard_map, replication check kw is check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def _smap(fn, mesh, in_specs, out_specs):
    # Replication checking is disabled: outputs marked P() are replicated by
    # construction (identical per-device computation), which the static
    # checker cannot see through all_gather + gather chains.
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})


# ---------------------------------------------------------------------------
# Mesh / ctx / placement helpers
# ---------------------------------------------------------------------------

def make_sketch_mesh(num_shards: int) -> Mesh:
    """1-D ``("shard",)`` mesh over the first ``num_shards`` local devices."""
    devs = jax.devices()
    if len(devs) < num_shards:
        raise ValueError(
            f"num_shards={num_shards} but only {len(devs)} devices visible "
            "(CPU CI: set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.asarray(devs[:num_shards]).reshape(num_shards),
                (SHARD_AXIS,))


def make_sketch_ctx(mesh: Optional[Mesh]) -> ShardingCtx:
    """ShardingCtx over ``mesh`` with the sketch logical-axis rules
    (``sketch_rows``/``sketch_tables`` → the "shard" mesh axis).

    A mesh without a "shard" axis would silently replicate everything and
    crash later inside ingest — reject it up front."""
    if mesh is not None and SHARD_AXIS not in mesh.axis_names:
        raise ValueError(
            f"sketch sharding needs a ('{SHARD_AXIS}',) mesh axis, got "
            f"{mesh.axis_names} (build one with make_sketch_mesh)")
    return make_ctx(mesh)


def make_service_ctx(mesh: Optional[Mesh], num_shards: int) -> ShardingCtx:
    """The services' config contract: an explicit ``mesh`` wins, else
    ``num_shards > 1`` builds one, else single-device (``ctx.mesh=None``)."""
    if mesh is None and num_shards > 1:
        mesh = make_sketch_mesh(num_shards)
    return make_sketch_ctx(mesh)


def ctx_num_shards(ctx: ShardingCtx) -> int:
    """Shard count of a sketch ctx (1 for the single-device path)."""
    return 1 if ctx.mesh is None else ctx.mesh.shape[SHARD_AXIS]


_num_shards = ctx_num_shards


def _check_rows(L: int, n: int, what: str) -> int:
    if L % n:
        raise ValueError(f"{what}: L={L} not divisible by num_shards={n}")
    return L // n


def _local_params(params, L_local: int):
    """Rebind the static row count on a row-block of LSH params (the arrays
    arrive already sliced by shard_map; only the static L must follow)."""
    return dataclasses.replace(params, L=L_local)


def _param_specs(params, ctx: ShardingCtx):
    """Spec pytree for LSH params, row-sharded: ``proj (d, L*k)`` splits its
    column axis (L-major, so contiguous blocks are whole rows), ``bias
    (L*k,)`` and ``mix (L, k)`` split their L axis."""
    col = ctx.spec(None, "sketch_rows")
    row = ctx.spec("sketch_rows")
    rowk = ctx.spec("sketch_rows", None)
    if isinstance(params, lsh.SRPParams):
        return dataclasses.replace(params, proj=col, mix=rowk)
    return dataclasses.replace(params, proj=col, bias=row, mix=rowk)


def _put(tree, spec_tree, mesh: Mesh):
    """device_put ``tree`` with a matching pytree of PartitionSpecs."""
    leaves, treedef = jax.tree.flatten(tree)
    specs = treedef.flatten_up_to(spec_tree)
    return treedef.unflatten(
        jax.device_put(x, NamedSharding(mesh, s))
        for x, s in zip(leaves, specs))


def _race_state_specs(ctx: ShardingCtx):
    return race.RACEState(counts=ctx.spec("sketch_rows", None), n=ctx.spec())


def _swakde_state_specs(ctx: ShardingCtx):
    return swakde.SWAKDEState(
        ts=ctx.spec("sketch_rows", None, None, None),
        num=ctx.spec("sketch_rows", None, None),
        t=ctx.spec())


def _sann_state_specs(ctx: ShardingCtx):
    r = ctx.spec()
    return sann.SANNState(
        points=r, valid=r, write_ptr=r, n_seen=r, n_stored=r,
        tables=ctx.spec("sketch_tables", None, None),
        table_ptr=ctx.spec("sketch_tables", None),
        stamps=r)


def shard_race(state: race.RACEState, params, ctx: ShardingCtx):
    """Place a RACE sketch onto the mesh (rows split, ``n`` replicated)."""
    return (_put(state, _race_state_specs(ctx), ctx.mesh),
            _put(params, _param_specs(params, ctx), ctx.mesh))


def shard_swakde(state: swakde.SWAKDEState, params, ctx: ShardingCtx):
    """Place an SW-AKDE sketch onto the mesh (rows split, ``t`` replicated)."""
    return (_put(state, _swakde_state_specs(ctx), ctx.mesh),
            _put(params, _param_specs(params, ctx), ctx.mesh))


def shard_sann(state: sann.SANNState, params, ctx: ShardingCtx):
    """Place an S-ANN sketch onto the mesh (tables split, points/counters
    replicated)."""
    return (_put(state, _sann_state_specs(ctx), ctx.mesh),
            _put(params, _param_specs(params, ctx), ctx.mesh))


# ---------------------------------------------------------------------------
# RACE
# ---------------------------------------------------------------------------

def sharded_race_update_batch(state: race.RACEState, params, xs: jax.Array,
                              ctx: ShardingCtx, sign: int = 1) -> race.RACEState:
    """Sharded turnstile batch update: ``xs (B, d)`` replicated to every
    device, each device running `race_update_batch` on its row block.
    Counters are bit-identical to the single-device call."""
    if ctx.mesh is None:
        return race.race_update_batch(state, params, xs, sign)
    Lsh = _check_rows(params.L, _num_shards(ctx), "RACE")

    def body(st, p, xs):
        return race.race_update_batch(st, _local_params(p, Lsh), xs, sign)

    return _smap(
        body, ctx.mesh,
        in_specs=(_race_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=_race_state_specs(ctx))(state, params, xs)


def _race_prep_specs(ctx: ShardingCtx):
    return race.RACEPrep(hist=ctx.spec("sketch_rows", None), count=ctx.spec())


def sharded_race_prepare_chunk(params, xs: jax.Array, n_buckets: int,
                               ctx: ShardingCtx) -> race.RACEPrep:
    """Sharded prepare phase: each device hashes the (replicated) chunk with
    its row block of the LSH params and histograms its own rows — pure, no
    state input, so it can run ahead of pending commits.  The prep pytree
    stays row-sharded, ready for `sharded_race_commit_chunk`."""
    if ctx.mesh is None:
        return race.race_prepare_chunk(params, xs, n_buckets)
    Lsh = _check_rows(params.L, _num_shards(ctx), "RACE")

    def body(p, xs):
        return race.race_prepare_chunk(_local_params(p, Lsh), xs, n_buckets)

    return _smap(
        body, ctx.mesh,
        in_specs=(_param_specs(params, ctx), ctx.spec()),
        out_specs=_race_prep_specs(ctx))(params, xs)


def sharded_race_commit_chunk(state: race.RACEState, prep: race.RACEPrep,
                              ctx: ShardingCtx, sign: int = 1) -> race.RACEState:
    """Sharded commit phase: fold a row-sharded prep into the row-sharded
    counters.  ``sharded_commit(sharded_prepare(...))`` is bit-identical to
    `sharded_race_update_batch` (same per-shard ops)."""
    if ctx.mesh is None:
        return race.race_commit_chunk(state, prep, sign)

    def body(st, pr):
        return race.race_commit_chunk(st, pr, sign)

    return _smap(
        body, ctx.mesh,
        in_specs=(_race_state_specs(ctx), _race_prep_specs(ctx)),
        out_specs=_race_state_specs(ctx))(state, prep)


def sharded_race_query_batch(state: race.RACEState, params, qs: jax.Array,
                             ctx: ShardingCtx,
                             median_of_means: int = 0) -> jax.Array:
    """Sharded batched query ``qs (B, d)`` → (B,) float32.

    Each device runs the fused batched read (`race.race_row_reads` — one
    hash matmul + one gather) on its row block, the (B, L_local) blocks are
    all-gathered into the full (B, L) value matrix in row order, and the
    single-device reduction `estimate_from_vals` runs replicated —
    bit-identical to `race_query_batch`."""
    if ctx.mesh is None:
        return race.race_query_batch(state, params, qs, median_of_means)
    Lsh = _check_rows(params.L, _num_shards(ctx), "RACE")

    def body(st, p, qs):
        vals = race.race_row_reads(st, _local_params(p, Lsh), qs)  # (B, Lsh)
        vals = lax.all_gather(vals, SHARD_AXIS, axis=1, tiled=True)  # (B, L)
        return race.estimate_from_vals(vals, median_of_means)

    return _smap(
        body, ctx.mesh,
        in_specs=(_race_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=ctx.spec())(state, params, qs)


# ---------------------------------------------------------------------------
# SW-AKDE
# ---------------------------------------------------------------------------

def sharded_swakde_update_chunk(state: swakde.SWAKDEState, params,
                                xs: jax.Array, cfg: swakde.SWAKDEConfig,
                                ctx: ShardingCtx) -> swakde.SWAKDEState:
    """Sharded exact chunk ingest: each device replays the chunk into its
    row block via `swakde_update_chunk` (rows are independent given the
    shared timestep counter, which every device advances identically).
    Bit-identical to the single-device call."""
    if ctx.mesh is None:
        return swakde.swakde_update_chunk(state, params, xs, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, p, xs):
        return swakde.swakde_update_chunk(st, _local_params(p, Lsh), xs,
                                          cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_swakde_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=_swakde_state_specs(ctx))(state, params, xs)


def _swakde_prep_specs(ctx: ShardingCtx):
    row = ctx.spec("sketch_rows", None)
    return swakde.SWAKDEPrep(order=row, seg_code=row, seg_len=row,
                             seg_first=row)


def sharded_swakde_prepare_chunk(params, xs: jax.Array,
                                 cfg: swakde.SWAKDEConfig,
                                 ctx: ShardingCtx) -> swakde.SWAKDEPrep:
    """Sharded prepare phase: each device hashes the (replicated) chunk with
    its row block and builds its rows' sort-into-segments structure — pure,
    no state input.  The prep stays row-sharded for
    `sharded_swakde_commit_chunk`."""
    if ctx.mesh is None:
        return swakde.swakde_prepare_chunk(params, xs, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(p, xs):
        return swakde.swakde_prepare_chunk(_local_params(p, Lsh), xs,
                                           cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_param_specs(params, ctx), ctx.spec()),
        out_specs=_swakde_prep_specs(ctx))(params, xs)


def sharded_swakde_commit_chunk(state: swakde.SWAKDEState,
                                prep: swakde.SWAKDEPrep,
                                cfg: swakde.SWAKDEConfig,
                                ctx: ShardingCtx) -> swakde.SWAKDEState:
    """Sharded commit phase: each device folds its row block's prepared
    segments into its EH rows via the closed-form segment passes
    (`kernels.ops.swakde_segment_pass` — the per-shard call dispatches the
    ingest kernels exactly like the single-device path, so the 8-device
    tests cover them; the shared clock advances identically on every
    device).  ``sharded_commit(sharded_prepare(...))`` is bit-identical to
    `sharded_swakde_update_chunk`."""
    if ctx.mesh is None:
        return swakde.swakde_commit_chunk(state, prep, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, pr):
        return swakde.swakde_commit_chunk(st, pr, cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_swakde_state_specs(ctx), _swakde_prep_specs(ctx)),
        out_specs=_swakde_state_specs(ctx))(state, prep)


def sharded_swakde_grid_estimates(state: swakde.SWAKDEState,
                                  cfg: swakde.SWAKDEConfig,
                                  ctx: ShardingCtx) -> jax.Array:
    """Sharded full-grid EH window counts → (L, W) float32, row-sharded.

    Each device runs `swakde.swakde_grid_estimates` over its row block; the
    result concatenates shard blocks in row order, so reads from it are
    bit-identical to the single-device table.  This is the query-side
    snapshot-cache producer for the sharded KDE service."""
    if ctx.mesh is None:
        return swakde.swakde_grid_estimates(state, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st):
        return swakde.swakde_grid_estimates(st, cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_swakde_state_specs(ctx),),
        out_specs=ctx.spec("sketch_rows", None))(state)


def sharded_swakde_query_from_grid(grid: jax.Array, params, qs: jax.Array,
                                   cfg: swakde.SWAKDEConfig,
                                   ctx: ShardingCtx) -> jax.Array:
    """Sharded cached-grid queries: ``grid (L, W)`` row-sharded (from
    `sharded_swakde_grid_estimates`), ``qs (B, d)`` → (B,) float32.

    Per device: hash with the local row block, gather the local grid rows,
    all-gather to (B, L) in row order, take the same mean the single-device
    estimator takes — bit-identical to `swakde.swakde_query_from_grid` and
    therefore to `sharded_swakde_query_batch` on the grid's state."""
    if ctx.mesh is None:
        return swakde.swakde_query_from_grid(grid, params, qs, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(g, p, qs):
        vals = swakde.swakde_row_estimates_from_grid(
            g, _local_params(p, Lsh), qs, cfg_local)             # (B, Lsh)
        vals = lax.all_gather(vals, SHARD_AXIS, axis=1, tiled=True)  # (B, L)
        return vals.mean(-1)

    return _smap(
        body, ctx.mesh,
        in_specs=(ctx.spec("sketch_rows", None), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=ctx.spec())(grid, params, qs)


def sharded_swakde_query_batch(state: swakde.SWAKDEState, params,
                               qs: jax.Array, cfg: swakde.SWAKDEConfig,
                               ctx: ShardingCtx) -> jax.Array:
    """Sharded batched query ``qs (B, d)`` → (B,) float32 (unnormalised Ŷ).

    Per-device fused batched row estimates
    (`swakde.swakde_row_estimates_batch` — one hash matmul + one gather,
    grid-precompute when B ≥ W) → all-gather to (B, L) in row order → the
    same mean the single-device estimator takes.  Bit-identical to
    `swakde_query_batch` (the EH-merge-style combine across devices reduces
    to concatenation because row cells are never split)."""
    if ctx.mesh is None:
        return swakde.swakde_query_batch(state, params, qs, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "SW-AKDE")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, p, qs):
        vals = swakde.swakde_row_estimates_batch(
            st, _local_params(p, Lsh), qs, cfg_local)            # (B, Lsh)
        vals = lax.all_gather(vals, SHARD_AXIS, axis=1, tiled=True)  # (B, L)
        return vals.mean(-1)

    return _smap(
        body, ctx.mesh,
        in_specs=(_swakde_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=ctx.spec())(state, params, qs)


# ---------------------------------------------------------------------------
# S-ANN
# ---------------------------------------------------------------------------

def sharded_sann_insert_batch(state: sann.SANNState, params, xs: jax.Array,
                              key: jax.Array, cfg: sann.SANNConfig,
                              ctx: ShardingCtx) -> sann.SANNState:
    """Sharded batched ingest of ``xs (B, d)``: every device runs
    `sann_insert_batch` over its table block with the *same* key, so the
    replicated point store / keep decisions / counters are computed
    identically everywhere and the table blocks line up with the
    single-device tables row-for-row.  Bit-identical to the single-device
    call under the same key."""
    if ctx.mesh is None:
        return sann.sann_insert_batch(state, params, xs, key, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, p, xs, key):
        return sann.sann_insert_batch(st, _local_params(p, Lsh), xs, key,
                                      cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec(), ctx.spec()),
        out_specs=_sann_state_specs(ctx))(state, params, xs, key)


def _sann_prep_specs(ctx: ShardingCtx):
    r = ctx.spec()                       # replicated: keep decisions + chunk
    t = ctx.spec("sketch_tables")        # flat (B * L,) append structure
    return sann.SANNPrep(
        xs=r, keep=r, kept_rank=r, n_kept=r, winner=r,
        s_l=t, s_c=t, s_b=t, rank=t, entry_win=t,
        counts=ctx.spec("sketch_tables", None))


def sharded_sann_prepare_chunk(params, xs: jax.Array, key: jax.Array,
                               cfg: sann.SANNConfig,
                               ctx: ShardingCtx) -> sann.SANNPrep:
    """Sharded prepare phase: every device draws the *same* keep decisions
    from the same key (replicated outputs, identical by construction) and
    builds the sort-by-(row, code) append structure for its own table block
    (table-sharded outputs) — pure, no state input.  Ready for
    `sharded_sann_commit_chunk`."""
    if ctx.mesh is None:
        return sann.sann_prepare_chunk(params, xs, key, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(p, xs, key):
        return sann.sann_prepare_chunk(_local_params(p, Lsh), xs, key,
                                       cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_param_specs(params, ctx), ctx.spec(), ctx.spec()),
        out_specs=_sann_prep_specs(ctx))(params, xs, key)


def sharded_sann_commit_chunk(state: sann.SANNState, prep: sann.SANNPrep,
                              cfg: sann.SANNConfig,
                              ctx: ShardingCtx) -> sann.SANNState:
    """Sharded commit phase: every device rebases the replicated slot ranks
    on the replicated pointers (identical point-store/counter updates
    everywhere) and scatters its own table block's prepared appends
    through `kernels.ops.sann_table_scatter` (per-shard kernel dispatch,
    same as single-device).  ``sharded_commit(sharded_prepare(...))`` is
    bit-identical to `sharded_sann_insert_batch`."""
    if ctx.mesh is None:
        return sann.sann_commit_chunk(state, prep, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, pr):
        return sann.sann_commit_chunk(st, pr, cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), _sann_prep_specs(ctx)),
        out_specs=_sann_state_specs(ctx))(state, prep)


def sharded_sann_merge(a: sann.SANNState, b: sann.SANNState, params,
                       cfg: sann.SANNConfig, ctx: ShardingCtx) -> sann.SANNState:
    """Sharded disjoint-stream union (`core.sann.sann_merge`): the
    stamp-interleaved union of the replicated point stores is computed
    identically on every device, and each device re-derives its own table
    block by hashing the union with its row block of the LSH params —
    exactly the sharded-ingest decomposition, so the merged sharded state
    equals the single-device merge block-for-block."""
    if ctx.mesh is None:
        return sann.sann_merge(a, b, params, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(sa, sb, p):
        return sann.sann_merge(sa, sb, _local_params(p, Lsh), cfg_local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), _sann_state_specs(ctx),
                  _param_specs(params, ctx)),
        out_specs=_sann_state_specs(ctx))(a, b, params)


def sharded_sann_delete(state: sann.SANNState, params, x: jax.Array,
                        cfg: sann.SANNConfig, ctx: ShardingCtx,
                        tol: float = 1e-5) -> sann.SANNState:
    """Sharded turnstile delete-by-value (§3.4): the hit mask over the
    replicated point store is computed identically on every device; each
    device tombstones its own table block.  Bit-identical to
    `sann_delete`."""
    if ctx.mesh is None:
        return sann.sann_delete(state, params, x, cfg, tol)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, x):
        return sann.sann_delete(st, None, x, cfg_local, tol)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), ctx.spec()),
        out_specs=_sann_state_specs(ctx))(state, x)


def sharded_sann_query_batch(state: sann.SANNState, params, qs: jax.Array,
                             cfg: sann.SANNConfig,
                             ctx: ShardingCtx) -> sann.SANNResult:
    """Sharded (c, r)-queries ``qs (B, d)`` → `SANNResult` with (B,) fields.

    Each device runs the fused batched bucket gather on its table block
    (`sann_bucket_candidates_batch` — one hash matmul + one gather for the
    whole batch); all-gather concatenates the (B, L_local·cap) blocks in
    shard order — which *is* the single-device row-major candidate order —
    and the batched truncate-and-score (`sann_score_candidates_batch`, 3L
    budget with the global L, fused scorer kernel) runs replicated.
    Bit-identical to `sann_query_batch`."""
    if ctx.mesh is None:
        return sann.sann_query_batch(state, params, qs, cfg)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)

    def body(st, p, qs):
        cand, ok = sann.sann_bucket_candidates_batch(
            st, _local_params(p, Lsh), qs, cfg_local)
        cand = lax.all_gather(cand, SHARD_AXIS, axis=1, tiled=True)
        ok = lax.all_gather(ok, SHARD_AXIS, axis=1, tiled=True)
        return sann.sann_score_candidates_batch(
            st.points, cand, ok, qs, 3 * cfg.L, cfg)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=sann.SANNResult(*(ctx.spec(),) * 4))(state, params, qs)


def sharded_sann_query_topk_batch(state: sann.SANNState, params,
                                  qs: jax.Array, cfg: sann.SANNConfig,
                                  ctx: ShardingCtx, topk: int = 50):
    """Sharded top-k ``qs (B, d)`` → ``(ids (B, k), dists (B, k))`` with
    ``k = min(topk, L * bucket_cap)`` — the cross-device combine is a
    top-k merge: per-shard fused `sann_query_topk_batch` results are
    all-gathered,
    duplicate slot ids (a point stored in tables on two shards) are masked
    to inf, and a final top-k selects across shards.  Exact: every global
    top-k entry is in its own shard's local top-k, and distances are
    computed identically everywhere (replicated point store)."""
    if ctx.mesh is None:
        return sann.sann_query_topk_batch(state, params, qs, cfg, topk)
    Lsh = _check_rows(cfg.L, _num_shards(ctx), "S-ANN")
    cfg_local = dataclasses.replace(cfg, L=Lsh)
    k_out = min(topk, cfg.L * cfg.bucket_cap)

    def merge(ids, dists):
        # ids/dists (B, n_shards * k_local): drop cross-shard duplicates
        # (identical distance on every shard — keep the first), then take
        # the global top-k by ascending distance.
        order = jnp.argsort(ids, axis=1)
        sid = jnp.take_along_axis(ids, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros_like(sid[:, :1], bool),
             (sid[:, 1:] == sid[:, :-1]) & (sid[:, 1:] >= 0)], axis=1)
        dupmask = jnp.zeros_like(dup).at[
            jnp.arange(ids.shape[0])[:, None], order].set(dup)
        d = jnp.where(dupmask | (ids < 0), jnp.inf, dists)
        neg, sel = lax.top_k(-d, k_out)
        out_ids = jnp.where(jnp.isfinite(-neg),
                            jnp.take_along_axis(ids, sel, axis=1), -1)
        return out_ids, -neg

    def body(st, p, qs):
        ids, dists = sann.sann_query_topk_batch(
            st, _local_params(p, Lsh), qs, cfg_local, topk)
        ids = lax.all_gather(ids, SHARD_AXIS, axis=1, tiled=True)
        dists = lax.all_gather(dists, SHARD_AXIS, axis=1, tiled=True)
        return merge(ids, dists)

    return _smap(
        body, ctx.mesh,
        in_specs=(_sann_state_specs(ctx), _param_specs(params, ctx),
                  ctx.spec()),
        out_specs=(ctx.spec(), ctx.spec()))(state, params, qs)


# ---------------------------------------------------------------------------
# Tenant fleets (repro.core.fleet): shard the leading [T] tenant axis
# ---------------------------------------------------------------------------
#
# Orthogonal to the row/table sharding above: a stacked fleet state keeps
# every sketch's (L, ...) block whole and splits the *tenant* axis across
# the mesh instead — each shard owns T/num_shards complete sketches and the
# fleet's single set of LSH params is replicated (hash the whole mixed
# chunk once per shard).  Routing is local: each shard remaps global tenant
# slots to its own block (slots owned elsewhere become -1, which the fleet
# ingest drops), so a mixed chunk commits with one vmapped dispatch per
# shard and NO cross-device traffic on the ingest path.  Queries read each
# request's row block locally, zero the rows the shard does not own, and
# psum — exactly one shard contributes each request's values, so adding the
# other shards' zeros is bit-exact.

def _fleet_state_specs(ctx: ShardingCtx, state_like):
    """Spec pytree for a stacked fleet: every leaf splits its leading
    tenant axis."""
    return jax.tree.map(
        lambda x: ctx.spec("tenants", *([None] * (jnp.ndim(x) - 1))),
        state_like)


def _param_replicated_specs(params, ctx: ShardingCtx):
    r = ctx.spec()
    if isinstance(params, lsh.SRPParams):
        return dataclasses.replace(params, proj=r, mix=r)
    return dataclasses.replace(params, proj=r, bias=r, mix=r)


def _check_tenants(T: int, n: int) -> int:
    if T % n:
        raise ValueError(f"fleet: T={T} not divisible by num_shards={n}")
    return T // n


def shard_fleet(stacked, params, ctx: ShardingCtx):
    """Place a stacked fleet onto the mesh (tenant axis split, params
    replicated)."""
    if ctx.mesh is None:
        return stacked, params
    return (_put(stacked, _fleet_state_specs(ctx, stacked), ctx.mesh),
            _put(params, _param_replicated_specs(params, ctx), ctx.mesh))


def _local_tids(tids: jax.Array, T_local: int) -> tuple[jax.Array, jax.Array]:
    """Remap global tenant slots to this shard's block: slots outside
    ``[shard*T_local, (shard+1)*T_local)`` become -1 (dropped/zeroed)."""
    sh = lax.axis_index(SHARD_AXIS)
    local = tids - sh * T_local
    owned = (local >= 0) & (local < T_local)
    return jnp.where(owned, local, -1), owned


def sharded_race_fleet_ingest(stacked: race.RACEState, params, xs: jax.Array,
                              tids: jax.Array,
                              ctx: ShardingCtx) -> race.RACEState:
    """Tenant-sharded fleet ingest: the mixed chunk is replicated, each
    shard scatters only the points of tenants it owns.  Bit-identical to
    `fleet.race_fleet_ingest` block-for-block."""
    if ctx.mesh is None:
        return fleet.race_fleet_ingest(stacked, params, xs, tids)
    Tl = _check_tenants(stacked.counts.shape[0], _num_shards(ctx))

    def body(st, p, xs, tids):
        local, _ = _local_tids(tids, Tl)
        return fleet.race_fleet_ingest(st, p, xs, local)

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=_fleet_state_specs(ctx, stacked))(stacked, params, xs,
                                                    tids)


def sharded_race_fleet_query(stacked: race.RACEState, params, qs: jax.Array,
                             tids: jax.Array, ctx: ShardingCtx,
                             median_of_means: int = 0) -> jax.Array:
    """Tenant-sharded fleet query: each request's (L,) counter reads come
    from the one shard owning its tenant; the psum over zeroed non-owner
    rows is bit-exact, then the single-device estimator runs replicated."""
    if ctx.mesh is None:
        return fleet.race_fleet_query(stacked, params, qs, tids,
                                      median_of_means)
    Tl = _check_tenants(stacked.counts.shape[0], _num_shards(ctx))

    def body(st, p, qs, tids):
        local, owned = _local_tids(tids, Tl)
        vals = fleet.race_fleet_row_reads(st, p, qs,
                                          jnp.clip(local, 0, Tl - 1))
        vals = lax.psum(jnp.where(owned[:, None], vals, 0.0), SHARD_AXIS)
        return race.estimate_from_vals(vals, median_of_means)

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=ctx.spec())(stacked, params, qs, tids)


def sharded_swakde_fleet_ingest(stacked: swakde.SWAKDEState, params,
                                xs: jax.Array, tids: jax.Array,
                                cfg: swakde.SWAKDEConfig, cap: int,
                                ctx: ShardingCtx) -> swakde.SWAKDEState:
    """Tenant-sharded SW-AKDE fleet ingest: each shard routes the mixed
    chunk against its own tenant block (foreign tenants drop to the
    sentinel) and runs the vmapped two-phase commit locally."""
    if ctx.mesh is None:
        return fleet.swakde_fleet_ingest(stacked, params, xs, tids, cfg, cap)
    Tl = _check_tenants(stacked.t.shape[0], _num_shards(ctx))

    def body(st, p, xs, tids):
        local, _ = _local_tids(tids, Tl)
        return fleet.swakde_fleet_ingest(st, p, xs, local, cfg, cap)

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=_fleet_state_specs(ctx, stacked))(stacked, params, xs,
                                                    tids)


def sharded_swakde_fleet_query(stacked: swakde.SWAKDEState, params,
                               qs: jax.Array, tids: jax.Array,
                               cfg: swakde.SWAKDEConfig,
                               ctx: ShardingCtx) -> jax.Array:
    """Tenant-sharded SW-AKDE fleet query: per-request EH row estimates
    from the owning shard, zero elsewhere, psum, mean — bit-identical to
    `fleet.swakde_fleet_query`."""
    if ctx.mesh is None:
        return fleet.swakde_fleet_query(stacked, params, qs, tids, cfg)
    Tl = _check_tenants(stacked.t.shape[0], _num_shards(ctx))

    def body(st, p, qs, tids):
        local, owned = _local_tids(tids, Tl)
        est = fleet.swakde_fleet_row_estimates(
            st, p, qs, jnp.clip(local, 0, Tl - 1), cfg)
        est = lax.psum(jnp.where(owned[:, None], est, 0.0), SHARD_AXIS)
        return est.mean(-1)

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=ctx.spec())(stacked, params, qs, tids)


def sharded_sann_fleet_ingest(stacked: sann.SANNState, params, xs: jax.Array,
                              tids: jax.Array, keys: jax.Array,
                              cfg: sann.SANNConfig, cap: int,
                              ctx: ShardingCtx) -> sann.SANNState:
    """Tenant-sharded S-ANN fleet ingest: the mixed chunk is replicated,
    the per-tenant chunk keys ``keys (T, 2)`` split with the tenant axis
    (each shard draws only its own tenants' Bernoulli keeps), and foreign
    tenants drop to the -1 sentinel.  Bit-identical to
    `fleet.sann_fleet_ingest` block-for-block: each shard's vmapped
    prepare/commit sees exactly the rows, codes, and keys the unsharded
    fleet hands that tenant block."""
    if ctx.mesh is None:
        return fleet.sann_fleet_ingest(stacked, params, xs, tids, keys, cfg,
                                       cap)
    Tl = _check_tenants(stacked.n_seen.shape[0], _num_shards(ctx))

    def body(st, p, xs, tids, keys):
        local, _ = _local_tids(tids, Tl)
        return fleet.sann_fleet_ingest(st, p, xs, local, keys, cfg, cap)

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec(), ctx.spec("tenants", None)),
        out_specs=_fleet_state_specs(ctx, stacked))(stacked, params, xs,
                                                    tids, keys)


def sharded_sann_fleet_query_topk(stacked: sann.SANNState, params,
                                  qs: jax.Array, tids: jax.Array,
                                  cfg: sann.SANNConfig, ctx: ShardingCtx,
                                  topk: int = 50):
    """Tenant-sharded per-tenant top-k: each request's candidates come
    from the one shard owning its tenant row; non-owners are masked to
    exact zeros before the psum, so the combine is bit-exact for every
    output — including the inf distance and -1 id padding (``inf + 0 =
    inf``, ``-1 + 0 = -1``; `jnp.where` masking, never a multiply, so no
    ``inf * 0`` NaN).  Slot ids index the request's own tenant row, so no
    cross-shard offset is needed."""
    if ctx.mesh is None:
        return fleet.sann_fleet_query_topk(stacked, params, qs, tids, cfg,
                                           topk)
    Tl = _check_tenants(stacked.n_seen.shape[0], _num_shards(ctx))

    def body(st, p, qs, tids):
        local, owned = _local_tids(tids, Tl)
        ids, dists = fleet.sann_fleet_query_topk(
            st, p, qs, jnp.clip(local, 0, Tl - 1), cfg, topk)
        ids = lax.psum(jnp.where(owned[:, None], ids, 0), SHARD_AXIS)
        dists = lax.psum(jnp.where(owned[:, None], dists, 0.0), SHARD_AXIS)
        return ids, dists

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=(ctx.spec(), ctx.spec()))(stacked, params, qs, tids)


def sharded_sann_fleet_query(stacked: sann.SANNState, params, qs: jax.Array,
                             tids: jax.Array, cfg: sann.SANNConfig,
                             ctx: ShardingCtx):
    """Tenant-sharded per-tenant (c, r)-NN queries → `SANNResult` with (B,)
    fields.  Same owner-masked psum as the top-k path; the boolean
    ``found`` field rides as int32 through the psum and casts back."""
    if ctx.mesh is None:
        return fleet.sann_fleet_query(stacked, params, qs, tids, cfg)
    Tl = _check_tenants(stacked.n_seen.shape[0], _num_shards(ctx))

    def body(st, p, qs, tids):
        local, owned = _local_tids(tids, Tl)
        res = fleet.sann_fleet_query(st, p, qs, jnp.clip(local, 0, Tl - 1),
                                     cfg)
        return sann.SANNResult(
            index=lax.psum(jnp.where(owned, res.index, 0), SHARD_AXIS),
            distance=lax.psum(jnp.where(owned, res.distance, 0.0),
                              SHARD_AXIS),
            found=lax.psum(
                jnp.where(owned, res.found.astype(jnp.int32), 0),
                SHARD_AXIS).astype(bool),
            n_candidates=lax.psum(jnp.where(owned, res.n_candidates, 0),
                                  SHARD_AXIS),
        )

    return _smap(
        body, ctx.mesh,
        in_specs=(_fleet_state_specs(ctx, stacked),
                  _param_replicated_specs(params, ctx), ctx.spec(),
                  ctx.spec()),
        out_specs=sann.SANNResult(*(ctx.spec(),) * 4))(stacked, params, qs,
                                                       tids)
