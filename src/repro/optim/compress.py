"""Gradient compression: int8 block-quantised all-reduce with error feedback.

Targets the *cross-pod* data-parallel reduction — the only inter-pod traffic
in this framework's mesh (DESIGN.md §4) and the slowest link.  Each worker
quantises (grad + carried error) to per-64-block int8, all-reduces the codes
(summing int8 as int32), dequantises, and carries the quantisation residual
to the next step (error feedback keeps SGD unbiased in the long run).

Used explicitly under shard_map; the dry-run baseline keeps XLA's fused
reduction, and the compressed variant is a §Perf collective-term lever
(4x fewer bytes on the pod links: int8 codes + one fp32 scale per 64).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 64


class ErrorState(NamedTuple):
    err: dict    # same pytree as grads, fp32 quantisation residuals


def init_error_state(grads_like) -> ErrorState:
    return ErrorState(err=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _q(x):
    n = x.size
    pad = (-n) % BLOCK
    xp = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    codes = jnp.round(xp / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return codes, scale[:, 0]


def _dq(codes, scale, shape, n):
    return (codes.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(shape)


def compressed_psum_leaf(g: jax.Array, e: jax.Array, axis: str):
    """One leaf: quantise(g+e), psum codes & scales, dequantise mean; returns
    (reduced grad, new error)."""
    x = g.astype(jnp.float32) + e
    codes, scale = _q(x)
    local = _dq(codes, scale, x.shape, x.size)
    new_err = x - local                                   # error feedback
    n_dev = jax.lax.psum(1, axis)
    summed = jax.lax.psum(codes.astype(jnp.int32) * scale[:, None], axis)
    reduced = (summed / n_dev).reshape(-1)[: x.size].reshape(x.shape)
    return reduced, new_err


def compressed_pmean(grads, err_state: ErrorState, axis: str):
    out = jax.tree.map(
        lambda g, e: compressed_psum_leaf(g, e, axis), grads, err_state.err)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), ErrorState(err=pick(1))


def bytes_saved_per_step(grads) -> tuple[int, int]:
    """(exact fp32 all-reduce bytes, compressed bytes) per worker."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    exact = n * 4
    comp = n * 1 + (n // BLOCK + 1) * 4
    return exact, comp
