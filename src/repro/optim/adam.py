"""Optimizers: AdamW (fp32 states), Adam8bit (block-quantised moments),
Adafactor (factored second moment) — optax-style (init/update) pure pytrees.

Adam8bit is what lets deepseek-v3-671b train on 512 chips: per-64-block
absmax-scaled int8 first/second moments cut optimizer state from 8 bytes to
~2.06 bytes per parameter (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: callable
    update: callable   # (grads, state, params) -> (new_params, new_state)



_CHUNK_THRESHOLD = 2 ** 27  # elements; larger leaves update layer-by-layer


def _maybe_map(upd, *leaves):
    """Apply ``upd`` leaf-wise, scanning over the leading (layer-stack) dim
    for very large leaves so the fp32 moment temporaries stay per-layer
    (a 58-layer dsv3 expert leaf would otherwise materialise ~17 GB of fp32
    m/v/u at once — EXPERIMENTS.md §Dry-run)."""
    p = leaves[-1]
    if p.ndim >= 2 and p.shape[0] >= 4 and p.size > _CHUNK_THRESHOLD:
        # statically-sliced chunks: bound the fp32 temporaries without a
        # while loop (XLA pessimises sharded dynamic-slice loops) and
        # without full per-layer unrolling (compile-time blowup).
        n = p.shape[0]
        n_chunks = min(min(16, n), max(2, p.size // _CHUNK_THRESHOLD))
        step_sz = -(-n // n_chunks)
        outs = []
        for i0 in range(0, n, step_sz):
            outs.append(upd(*(l[i0:i0 + step_sz] for l in leaves)))
        return tuple(jnp.concatenate([o[j] for o in outs])
                     for j in range(len(outs[0])))
    return upd(*leaves)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          warmup: int = 100) -> Optimizer:
    def schedule(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / warmup)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                         v=jax.tree.map(jnp.copy, zeros))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = schedule(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (u + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(lambda *ls: _maybe_map(upd, *ls),
                           grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamState(step=step, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adam8bit — block-quantised moments
# ---------------------------------------------------------------------------

BLOCK = 64


def _quantize(x: jax.Array):
    """fp32 (..., n) → (int8 codes same shape, fp32 per-block scales
    (..., ceil(n/B))).  Blockwise along the LAST axis, shape-preserving, so
    quantised optimizer state inherits the parameter's sharding exactly —
    mismatched layouts here force XLA to replicate whole expert tensors
    (measured 12x 406 GB all-gathers on dsv3; EXPERIMENTS.md §Dry-run)."""
    n = x.shape[-1] if x.ndim else 1
    xr = x.reshape(x.shape or (1,))
    pad = (-n) % BLOCK
    xp = jnp.pad(xr, [(0, 0)] * (xr.ndim - 1) + [(0, pad)])
    xb = xp.reshape(*xp.shape[:-1], xp.shape[-1] // BLOCK, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    codes = jnp.round(xb / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    codes = codes.reshape(xp.shape)[..., :n].reshape(x.shape)
    return codes, scale


def _dequantize(codes: jax.Array, scale: jax.Array, shape, n_unused=None):
    n = codes.shape[-1] if codes.ndim else 1
    cr = codes.reshape(codes.shape or (1,))
    pad = (-n) % BLOCK
    cp = jnp.pad(cr, [(0, 0)] * (cr.ndim - 1) + [(0, pad)])
    cb = cp.reshape(*cp.shape[:-1], cp.shape[-1] // BLOCK, BLOCK)
    x = cb.astype(jnp.float32) * scale[..., None]
    return x.reshape(cp.shape)[..., :n].reshape(shape)


class Adam8bitState(NamedTuple):
    step: jax.Array
    m_codes: dict
    m_scale: dict
    v_codes: dict
    v_scale: dict


def adam8bit(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
             eps: float = 1e-8, weight_decay: float = 0.01,
             warmup: int = 100) -> Optimizer:
    def schedule(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / warmup)

    def init(params):
        def q(p):
            return _quantize(jnp.zeros(p.shape, jnp.float32))
        qs = jax.tree.map(q, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], qs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return Adam8bitState(step=jnp.zeros((), jnp.int32),
                             m_codes=pick(0), m_scale=pick(1),
                             v_codes=pick(0), v_scale=pick(1))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = schedule(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, mc, ms, vc, vs, p):
            g = g.astype(jnp.float32)
            m = _dequantize(mc, ms, g.shape)
            v = _dequantize(vc, vs, g.shape)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            v = jnp.maximum(v, 0.0)
            u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (u + weight_decay * p.astype(jnp.float32))
            mc2, ms2 = _quantize(m)
            vc2, vs2 = _quantize(v)
            return new_p.astype(p.dtype), mc2, ms2, vc2, vs2

        out = jax.tree.map(lambda *ls: _maybe_map(upd, *ls),
                           grads, state.m_codes, state.m_scale,
                           state.v_codes, state.v_scale, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), Adam8bitState(step=step, m_codes=pick(1), m_scale=pick(2),
                                      v_codes=pick(3), v_scale=pick(4))

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment) — memory floor
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: dict   # row stats
    vc: dict   # col stats


def adafactor(lr: float = 3e-4, eps: float = 1e-30, clip: float = 1.0) -> Optimizer:
    def init(params):
        def zero(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return (jnp.zeros(p.shape, jnp.float32), jnp.zeros((1,), jnp.float32))
        zs = jax.tree.map(zero, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], zs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return AdafactorState(step=jnp.zeros((), jnp.int32), vr=pick(0), vc=pick(1))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** -0.8

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g / jnp.sqrt(jnp.maximum(r[..., None] * vc[..., None, :], eps))
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(vr, eps))
            norm = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, norm / clip)
            new_p = p.astype(jnp.float32) - lr * u
            return new_p.astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2))

    return Optimizer(init=init, update=update)


OPTIMIZERS = {"adamw": adamw, "adam8bit": adam8bit, "adafactor": adafactor}
