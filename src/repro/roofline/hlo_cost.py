"""Static cost model over compiled HLO text — trip-count aware.

XLA's ``compiled.cost_analysis()`` visits each instruction once, so anything
inside a ``while`` body (our lax.scan layer stacks!) is counted once instead
of n_layers times.  This parser walks the HLO text, recursing through
``while``/``call``/``fusion`` with multipliers (trip counts parsed from the
loop condition's comparison constant), and accumulates:

  * flops            — 2 * prod(result) * K for every dot (K from
                       lhs_contracting_dims × the lhs shape symbol table)
  * hbm_bytes        — fusion-boundary traffic: operands + result of every
                       top-level op (fusions are the HBM-traffic unit on TPU;
                       parameter/constant/tuple/gte/bitcast excluded)
  * collectives      — per-type summed payload bytes (all-gather counts its
                       (larger) result; others count operands); ring/link
                       factors are applied downstream in analysis.py

All sizes are *global* (the HLO is the SPMD per-device program, so shapes
are already per-device — values here are per-device costs).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_TRAFFIC = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "add-dependency", "partition-id",
               "replica-id")


def _shape_bytes(text: str) -> int:
    """Total bytes of every shape literal in `text` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


class _Instr:
    __slots__ = ("name", "rhs", "op", "result_bytes", "is_root")

    def __init__(self, name, rhs, is_root=False):
        self.name = name
        self.rhs = rhs
        self.is_root = is_root
        m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        self.op = m.group(1) if m else "unknown"
        # result shape(s): the text before the op name
        head = rhs[: m.start()] if m else rhs
        self.result_bytes = _shape_bytes(head)


def parse_computations(hlo: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        h = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
        if h and "->" in line:
            cur = h.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(2), m.group(3),
                                     is_root=bool(m.group(1))))
    return comps


def _trip_count(cond_instrs) -> int:
    """Largest integer constant in the loop condition — the bound of the
    canonical `iter < C` comparison (our scans all have static lengths)."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, table: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.rhs)
    if out_dims is None:
        return 0.0
    m = re.search(r"dot\(([^)]*)\)", ins.rhs)
    if not m:
        return 0.0
    ops = _OPERAND_RE.findall(m.group(1))
    k = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    if ops and cm and ops[0] in table:
        lhs_dims = _shape_dims(table[ops[0]])
        if lhs_dims:
            for d in (cm.group(1).split(",") if cm.group(1) else []):
                di = int(d)
                if di < len(lhs_dims):
                    k *= lhs_dims[di]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * k


def _root_instr(instrs):
    for ins in instrs:
        if ins.is_root:
            return ins
    return instrs[-1] if instrs else None


def _update_operand_bytes(ins: _Instr, table) -> int:
    """Bytes of the update operand (2nd arg) of dus/scatter."""
    m = re.search(r"\b[a-z][a-z0-9\-]*\(([^)]*)\)", ins.rhs)
    args = _OPERAND_RE.findall(m.group(1) if m else "")
    if len(args) >= 2 and args[1] in table:
        head = table[args[1]]
        mm = re.search(r"\b([a-z][a-z0-9\-]*)\(", head)
        return _shape_bytes(head[: mm.start()] if mm else head)
    return ins.result_bytes  # fallback


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    # symbol tables: instruction name -> rhs text (for operand shape lookup)
    tables = {c: {i.name: i.rhs for i in instrs} for c, instrs in comps.items()}
    memo: dict = {}

    def operand_bytes(ins: _Instr, table) -> int:
        m = re.search(r"\b[a-z][a-z0-9\-]*\(([^;]*)$", ins.rhs)
        args = _OPERAND_RE.findall(m.group(1) if m else ins.rhs)
        total = 0
        for a in args:
            if a in table:
                head = table[a]
                mm = re.search(r"\b([a-z][a-z0-9\-]*)\(", head)
                total += _shape_bytes(head[: mm.start()] if mm else head)
        return total

    def cost_of(comp: str) -> dict:
        if comp in memo:
            return memo[comp]
        acc = {"flops": 0.0, "hbm_bytes": 0.0,
               "collectives": defaultdict(float)}
        memo[comp] = acc  # cycle guard
        table = tables.get(comp, {})
        for ins in comps.get(comp, []):
            if ins.op == "while":
                bm = re.search(r"body=(%[\w\.\-]+)", ins.rhs)
                cm = re.search(r"condition=(%[\w\.\-]+)", ins.rhs)
                tc = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                if bm and bm.group(1) in comps:
                    sub = cost_of(bm.group(1))
                    acc["flops"] += tc * sub["flops"]
                    acc["hbm_bytes"] += tc * sub["hbm_bytes"]
                    for k, v in sub["collectives"].items():
                        acc["collectives"][k] += tc * v
                continue
            if ins.op == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                names = _OPERAND_RE.findall(branches.group(1)) if branches \
                    else []
                if not names:
                    for kw in ("true_computation", "false_computation"):
                        m2 = re.search(kw + r"=(%[\w\.\-]+)", ins.rhs)
                        if m2:
                            names.append(m2.group(1))
                subs = [cost_of(n) for n in names if n in comps]
                if subs:  # charge the most expensive branch
                    best = max(subs, key=lambda s: s["hbm_bytes"] + s["flops"])
                    acc["flops"] += best["flops"]
                    acc["hbm_bytes"] += best["hbm_bytes"]
                    for k, v in best["collectives"].items():
                        acc["collectives"][k] += v
                continue
            if ins.op in ("call", "fusion", "async-start"):
                cm = re.search(r"calls=(%[\w\.\-]+)", ins.rhs)
                sub_comp = cm.group(1) if cm else None
                if sub_comp in comps:
                    sub = cost_of(sub_comp)
                    acc["flops"] += sub["flops"]
                    for k, v in sub["collectives"].items():
                        acc["collectives"][k] += v
                    root = _root_instr(comps[sub_comp])
                    if root is not None and root.op == "dynamic-update-slice":
                        # in-place scan accumulator: the fusion touches only
                        # the updated slice, not the whole buffer — charging
                        # the buffer inflated zamba2 by ~7 TB/step (§Perf)
                        upd = _update_operand_bytes(
                            root, {i.name: i.rhs for i in comps[sub_comp]})
                        acc["hbm_bytes"] += 2 * upd
                        continue
                    if root is not None and root.op == "dynamic-slice":
                        acc["hbm_bytes"] += 2 * root.result_bytes
                        continue
                # fusion boundary traffic:
                acc["hbm_bytes"] += ins.result_bytes + operand_bytes(ins, table)
                continue
            if ins.op == "dot":
                acc["flops"] += _dot_flops(ins, table)
                acc["hbm_bytes"] += ins.result_bytes + operand_bytes(ins, table)
                continue
            if ins.op in _COLLECTIVES:
                if ins.op == "all-gather":
                    acc["collectives"][ins.op] += ins.result_bytes
                else:
                    acc["collectives"][ins.op] += operand_bytes(ins, table)
                continue
            if ins.op in _NO_TRAFFIC:
                continue
            if ins.op in ("dynamic-slice", "slice", "gather", "broadcast",
                          "iota", "reshape", "transpose"):
                # reads only the region it produces (scan layer-slicing must
                # NOT be charged the whole stacked array per iteration)
                acc["hbm_bytes"] += 2 * ins.result_bytes
                continue
            if ins.op in ("dynamic-update-slice", "scatter"):
                # touches the updated region (read+write), not the buffer
                upd = _update_operand_bytes(ins, table)
                acc["hbm_bytes"] += 2 * upd
                continue
            acc["hbm_bytes"] += ins.result_bytes + operand_bytes(ins, table)
        return acc

    entry = None
    m = re.search(r"^ENTRY\s+(%[\w\.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back to the largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0}
    out = cost_of(entry)
    out["collectives"] = dict(out["collectives"])
    out["collective_bytes"] = sum(out["collectives"].values())
    return out
