"""Roofline aggregation: dry-run artifacts → the §Roofline table.

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / link_bw       (per chip, ring-factored)

HLO_FLOPs / bytes / collective payloads come from the trip-count-aware HLO
parser (hlo_cost.py); hardware constants are TPU v5e (197 TF bf16, 819 GB/s
HBM, ~50 GB/s/link ICI).  Ring factors: all-reduce moves 2(N-1)/N ~ 2x its
payload over the slowest link; all-gather/reduce-scatter (N-1)/N ~ 1x;
all-to-all (N-1)/N ~ 1x; collective-permute 1x.

Usage: PYTHONPATH=src python -m repro.roofline.analysis [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def roofline_terms(rec: dict) -> dict:
    hlo = rec["hlo"]
    comp = hlo["flops_per_device"] / PEAK_FLOPS
    mem = hlo["hbm_bytes_per_device"] / HBM_BW
    coll_bytes = sum(RING_FACTOR.get(k, 1.0) * v
                     for k, v in hlo["collectives_per_device"].items())
    coll = coll_bytes / ICI_BW
    dominant = max((("compute", comp), ("memory", mem), ("collective", coll)),
                   key=lambda kv: kv[1])[0]
    total = max(comp, mem, coll)
    mf = rec["roofline"]["model_flops_global"]
    n = rec["n_chips"]
    # achievable MFU bound under this cost model: useful model flops per chip
    # over the bottleneck-dominated step time
    mfu_bound = (mf / n / PEAK_FLOPS) / total if total > 0 else 0.0
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant, "mfu_bound": mfu_bound,
        "useful_ratio": mf / max(hlo["flops_per_device"] * n, 1.0),
        "hbm_gb": rec.get("hbm_per_device_gb", 0.0),
        "fits_16gb": rec.get("hbm_per_device_gb", 1e9) <= 16.0,
    }


def load(dir_: pathlib.Path) -> list[dict]:
    recs = []
    for f in sorted(dir_.glob("*.json")):
        r = json.loads(f.read_text())
        recs.append(r)
    return recs


def table(recs: list[dict], mesh: str = "pod16x16") -> str:
    lines = ["| arch | shape | comp s | mem s | coll s | dominant | "
             "MFU bound | useful | HBM GB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"FAILED | — | — | — | — |")
            continue
        t = roofline_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['dominant']} | {t['mfu_bound']*100:.1f}% | "
            f"{t['useful_ratio']*100:.0f}% | {t['hbm_gb']:.1f} | "
            f"{'yes' if t['fits_16gb'] else 'NO'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(pathlib.Path(args.dir))
    print(table(recs, args.mesh))
    done = sum(1 for r in recs if r.get("ok"))
    print(f"\n{done}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
