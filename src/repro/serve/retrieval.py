"""S-ANN retrieval service: streaming index + batched queries (paper §3).

The serving-side integration of the paper's sketch: documents (or cached
hidden states) arrive as a stream of embeddings; the service maintains the
sublinear S-ANN sketch and answers batched (c, r)-ANN queries — e.g. for
retrieval-augmented decoding, the per-step query batch is the batch of
current decoder hidden states.

Runtime: the service is a `repro.serve.engine.SketchEngine` — the shared
streaming runtime owns the lock, the chunk loop, the two-phase pipelined
ingest (`core.sann.sann_prepare_chunk` hashing chunk k+1 on the prepare
thread while `sann_commit_chunk` folds chunk k in), the background queue
(``ingest_async`` / ``flush``) and the versioned query snapshots.  Queries
always read one committed prefix of the stream (never a torn state).

Multi-device: set ``num_shards`` (or pass a ``mesh``) to split the L hash
tables across devices via `repro.parallel.sketch_sharding` — both ingest
phases run per table shard, queries all-gather candidate blocks, and
results stay bit-identical to the single-device service.  ``mesh=None,
num_shards<=1`` (the default) keeps the single-device path untouched.

This is a thin, stateful orchestration layer over repro.core.sann; all math
lives there (and is what the paper's guarantees cover).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sann
from repro.parallel import sketch_sharding as ss
from repro.serve.engine import SketchEngine


@dataclasses.dataclass
class RetrievalConfig:
    dim: int
    n_max: int = 100_000
    eta: float = 0.5
    r: float = 0.9
    c: float = 2.0
    w: float = 4.0
    L: Optional[int] = 16
    k: Optional[int] = 8
    bucket_cap: int = 16
    seed: int = 0
    # Batched-ingest chunk: each chunk is one prepare (hash matmul + sort)
    # plus one commit (segment scatter).  Larger chunks amortise more; each
    # distinct partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Two-phase pipelining: prepare chunk k+1 on the engine's prepare thread
    # while chunk k commits.  False = strictly sequential phases (identical
    # results; the ingest-benchmark baseline).
    pipelined: bool = True
    # Query block: queries are served through the fused batch engine
    # (core.sann.sann_query_batch) in blocks of this many rows — bounds the
    # (block, 3L, dim) scoring footprint; each distinct partial-block size
    # triggers one extra jit trace.
    query_block: int = 1024
    # Multi-device sharding: num_shards > 1 splits the L tables across that
    # many local devices (L must divide evenly); ``mesh`` overrides with a
    # prebuilt 1-D ("shard",) mesh.  Both unset → single-device.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh


class RetrievalService(SketchEngine):
    """Thread-safe streaming ANN index with pipelined ingest and batched
    queries (shared runtime: `repro.serve.engine.SketchEngine`)."""

    def __init__(self, cfg: RetrievalConfig):
        base = sann.SANNConfig(
            dim=cfg.dim, n_max=cfg.n_max, eta=cfg.eta, r=cfg.r, c=cfg.c,
            w=cfg.w, L=cfg.L, k=cfg.k, bucket_cap=cfg.bucket_cap)
        self.cfg, self.params, state = sann.sann_init(
            base, jax.random.PRNGKey(cfg.seed))
        super().__init__(ingest_chunk=cfg.ingest_chunk,
                         query_block=cfg.query_block,
                         pipelined=cfg.pipelined)
        self.state = state
        self._key = jax.random.PRNGKey(cfg.seed + 1)

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_sann(self.state, self.params,
                                                    self._ctx)
        self._prepare_fn = jax.jit(
            lambda xs, key: ss.sharded_sann_prepare_chunk(
                self.params, xs, key, self.cfg, self._ctx))
        self._commit_fn = jax.jit(
            lambda st, prep: ss.sharded_sann_commit_chunk(
                st, prep, self.cfg, self._ctx))
        self._query_fn = jax.jit(
            lambda st, qs: ss.sharded_sann_query_batch(
                st, self.params, qs, self.cfg, self._ctx))
        self._delete_fn = jax.jit(
            lambda st, x: ss.sharded_sann_delete(
                st, self.params, x, self.cfg, self._ctx))

    # --- engine hooks (two-phase ingest) -----------------------------------

    def _make_chunk_item(self, chunk: jax.Array) -> tuple:
        # Per-chunk key schedule, drawn in submission order (under the
        # engine's submit lock) — the same schedule whether the chunks are
        # ingested synchronously or via ingest_async.
        self._key, sub = jax.random.split(self._key)
        return (chunk, sub)

    def _prepare(self, chunk: jax.Array, key: jax.Array) -> sann.SANNPrep:
        return self._prepare_fn(chunk, key)

    def _commit(self, state: sann.SANNState, prep: sann.SANNPrep):
        return self._commit_fn(state, prep)

    # --- serving API -------------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Devices the tables are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    def delete(self, embedding: np.ndarray) -> None:
        """Turnstile deletion (paper §3.4) — applied atomically to the
        current committed prefix (queued async chunks commit after it)."""
        x = jnp.asarray(embedding)
        self._mutate_state(lambda st: self._delete_fn(st, x))

    def query(self, queries: np.ndarray) -> sann.SANNResult:
        """Batched queries (paper §3.3) through the fused batch engine, in
        blocks of ``query_block`` rows (one hash matmul + one gather + one
        fused scorer call per block) — all blocks against one lock-consistent
        snapshot of the committed state."""
        qs = jnp.asarray(queries, jnp.float32)
        state, _ = self.snapshot()
        return self._query_blocks(lambda b: self._query_fn(state, b), qs)

    @property
    def stored(self) -> int:
        return int(self.state.n_stored)

    @property
    def sketch_bytes(self) -> int:
        return sann.sann_bytes(self.cfg)
