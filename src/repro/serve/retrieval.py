"""S-ANN retrieval service: streaming index + batched queries (paper §3).

The serving-side integration of the paper's sketch: documents (or cached
hidden states) arrive as a stream of embeddings; the service maintains the
sublinear S-ANN sketch and answers batched (c, r)-ANN queries — e.g. for
retrieval-augmented decoding, the per-step query batch is the batch of
current decoder hidden states.

Multi-device: set ``num_shards`` (or pass a ``mesh``) to split the L hash
tables across devices via `repro.parallel.sketch_sharding` — ingest runs
the PR-1 batched kernel per table shard, queries all-gather candidate
blocks, and results stay bit-identical to the single-device service.
``mesh=None, num_shards<=1`` (the default) keeps today's single-device
path untouched.

This is a thin, stateful orchestration layer over repro.core.sann; all math
lives there (and is what the paper's guarantees cover).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sann
from repro.parallel import sketch_sharding as ss


@dataclasses.dataclass
class RetrievalConfig:
    dim: int
    n_max: int = 100_000
    eta: float = 0.5
    r: float = 0.9
    c: float = 2.0
    w: float = 4.0
    L: Optional[int] = 16
    k: Optional[int] = 8
    bucket_cap: int = 16
    seed: int = 0
    # Batched-ingest chunk: each chunk is one hash matmul + one segment
    # scatter (core.sann.sann_insert_batch).  Larger chunks amortise more;
    # each distinct partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Query block: queries are served through the fused batch engine
    # (core.sann.sann_query_batch) in blocks of this many rows — bounds the
    # (block, 3L, dim) scoring footprint; each distinct partial-block size
    # triggers one extra jit trace.
    query_block: int = 1024
    # Multi-device sharding: num_shards > 1 splits the L tables across that
    # many local devices (L must divide evenly); ``mesh`` overrides with a
    # prebuilt 1-D ("shard",) mesh.  Both unset → single-device.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh


class RetrievalService:
    """Thread-safe streaming ANN index with batched ingest and queries."""

    def __init__(self, cfg: RetrievalConfig):
        base = sann.SANNConfig(
            dim=cfg.dim, n_max=cfg.n_max, eta=cfg.eta, r=cfg.r, c=cfg.c,
            w=cfg.w, L=cfg.L, k=cfg.k, bucket_cap=cfg.bucket_cap)
        self.cfg, self.params, self.state = sann.sann_init(
            base, jax.random.PRNGKey(cfg.seed))
        self._chunk = cfg.ingest_chunk
        self._query_block = max(1, cfg.query_block)
        self._key = jax.random.PRNGKey(cfg.seed + 1)
        self._lock = threading.Lock()

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_sann(self.state, self.params,
                                                    self._ctx)
        self._insert = jax.jit(
            lambda st, xs, key: ss.sharded_sann_insert_batch(
                st, self.params, xs, key, self.cfg, self._ctx))
        self._query = jax.jit(
            lambda st, qs: ss.sharded_sann_query_batch(
                st, self.params, qs, self.cfg, self._ctx))
        self._delete = jax.jit(
            lambda st, x: ss.sharded_sann_delete(
                st, self.params, x, self.cfg, self._ctx))

    @property
    def num_shards(self) -> int:
        """Devices the tables are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    def ingest(self, embeddings: np.ndarray) -> None:
        """Stream a block of embeddings through the batched insert path,
        one `sann_insert_batch` call per `ingest_chunk` rows."""
        xs = jnp.asarray(embeddings, jnp.float32)
        with self._lock:
            for i in range(0, xs.shape[0], self._chunk):
                self._key, sub = jax.random.split(self._key)
                self.state = self._insert(self.state, xs[i:i + self._chunk],
                                          sub)

    def delete(self, embedding: np.ndarray) -> None:
        """Turnstile deletion (paper §3.4)."""
        with self._lock:
            self.state = self._delete(self.state, jnp.asarray(embedding))

    def query(self, queries: np.ndarray) -> sann.SANNResult:
        """Batched queries (paper §3.3) through the fused batch engine, in
        blocks of ``query_block`` rows (one hash matmul + one gather + one
        fused scorer call per block)."""
        qs = jnp.asarray(queries, jnp.float32)
        state, qb = self.state, self._query_block
        out = [self._query(state, qs[i:i + qb])
               for i in range(0, qs.shape[0], qb)]
        if not out:                       # B = 0: one empty-engine call
            return self._query(state, qs)
        if len(out) == 1:
            return out[0]
        return sann.SANNResult(*(jnp.concatenate(f) for f in zip(*out)))

    @property
    def stored(self) -> int:
        return int(self.state.n_stored)

    @property
    def sketch_bytes(self) -> int:
        return sann.sann_bytes(self.cfg)
