"""S-ANN retrieval service: streaming index + batched queries (paper §3).

The serving-side integration of the paper's sketch: documents (or cached
hidden states) arrive as a stream of embeddings; the service maintains the
sublinear S-ANN sketch and answers batched (c, r)-ANN queries — e.g. for
retrieval-augmented decoding, the per-step query batch is the batch of
current decoder hidden states.

Runtime: the service is a `repro.serve.engine.SketchEngine` — the shared
streaming runtime owns the lock, the chunk loop, the two-phase pipelined
ingest (`core.sann.sann_prepare_chunk` hashing chunk k+1 on the prepare
thread while `sann_commit_chunk` folds chunk k in), the background queue
(``ingest_async`` / ``flush``) and the versioned query snapshots.  Queries
always read one committed prefix of the stream (never a torn state).

Multi-device: set ``num_shards`` (or pass a ``mesh``) to split the L hash
tables across devices via `repro.parallel.sketch_sharding` — both ingest
phases run per table shard, queries all-gather candidate blocks, and
results stay bit-identical to the single-device service.  ``mesh=None,
num_shards<=1`` (the default) keeps the single-device path untouched.

This is a thin, stateful orchestration layer over repro.core.sann; all math
lives there (and is what the paper's guarantees cover).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.core import sann
from repro.parallel import sketch_sharding as ss
from repro.serve.engine import SketchEngine, durability_from


@dataclasses.dataclass
class RetrievalConfig:
    dim: int
    n_max: int = 100_000
    eta: float = 0.5
    r: float = 0.9
    c: float = 2.0
    w: float = 4.0
    L: Optional[int] = 16
    k: Optional[int] = 8
    bucket_cap: int = 16
    seed: int = 0
    # Ingest-key salt: folded into the per-chunk key schedule so cluster
    # workers sharing one `seed` (→ identical LSH params, required for
    # merging) still draw independent keep decisions.
    ingest_salt: int = 0
    # Batched-ingest chunk: each chunk is one prepare (hash matmul + sort)
    # plus one commit (segment scatter).  Larger chunks amortise more; each
    # distinct partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Two-phase pipelining: prepare chunk k+1 on the engine's prepare thread
    # while chunk k commits.  False = strictly sequential phases (identical
    # results; the ingest-benchmark baseline).
    pipelined: bool = True
    # Prepare lookahead depth: chunks the prepare pool may run ahead of the
    # commit side (1 = classic double buffering; bit-identical either way).
    prepare_depth: int = 1
    # Query block: queries are served through the fused batch engine
    # (core.sann.sann_query_batch) in blocks of this many rows — bounds the
    # (block, 3L, dim) scoring footprint; each distinct partial-block size
    # triggers one extra jit trace.
    query_block: int = 1024
    # Top-k result width for `query_topk` / the "topk" query kind (recall
    # workloads; no (c, r) contract) — capped at L * bucket_cap.
    topk: int = 50
    # Cross-request query micro-batching (DESIGN.md §13): with
    # ``batch_queries`` the sync query wrappers enqueue with the admission
    # scheduler, which coalesces concurrent clients into one fused batch
    # per tick — at most ``max_batch`` rows (None = query_block), waiting
    # at most ``max_wait_us`` for the batch to fill.  Answers stay
    # bit-identical to unbatched calls; ``submit_query`` (future-returning)
    # is available either way.
    batch_queries: bool = False
    max_batch: Optional[int] = None
    max_wait_us: float = 200.0
    # Multi-device sharding: num_shards > 1 splits the L tables across that
    # many local devices (L must divide evenly); ``mesh`` overrides with a
    # prebuilt 1-D ("shard",) mesh.  Both unset → single-device.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh
    # Admission control: bound on queued-but-uncommitted rows; ingest_async
    # blocks (backpressure) at the bound.  None = unbounded queue.
    max_pending: Optional[int] = None
    # Durability (repro.persist): set ``snapshot_dir`` to WAL-log every
    # ingest chunk at enqueue time and write background state snapshots
    # every ``snapshot_every`` committed operations; ``recover()`` then
    # restores snapshot + WAL tail bit-identically after a crash.
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 64
    wal_fsync: bool = False
    # Fault-injection site-name prefix (repro.persist.faults,
    # DESIGN.md §14); the cluster sets ``worker_<w>/`` per worker.
    fault_scope: str = ""


class RetrievalService(SketchEngine):
    """Thread-safe streaming ANN index with pipelined ingest and batched
    queries (shared runtime: `repro.serve.engine.SketchEngine`)."""

    def __init__(self, cfg: RetrievalConfig):
        self.service_cfg = cfg
        base = sann.SANNConfig(
            dim=cfg.dim, n_max=cfg.n_max, eta=cfg.eta, r=cfg.r, c=cfg.c,
            w=cfg.w, L=cfg.L, k=cfg.k, bucket_cap=cfg.bucket_cap)
        self.cfg, self.params, state = sann.sann_init(
            base, jax.random.PRNGKey(cfg.seed))
        super().__init__(ingest_chunk=cfg.ingest_chunk,
                         query_block=cfg.query_block,
                         pipelined=cfg.pipelined,
                         prepare_depth=cfg.prepare_depth,
                         max_pending=cfg.max_pending,
                         durability=durability_from(cfg),
                         batch_queries=cfg.batch_queries,
                         max_batch=cfg.max_batch,
                         max_wait_us=cfg.max_wait_us,
                         fault_scope=cfg.fault_scope)
        self.state = state
        # Per-chunk keys are fold_in(base, chunk seq): a pure function of
        # the chunk's global sequence number, so the schedule is identical
        # across sync/async ingest and across crash-recovery replay.
        self._ingest_key = jax.random.fold_in(
            jax.random.PRNGKey(cfg.seed + 1), cfg.ingest_salt)

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_sann(self.state, self.params,
                                                    self._ctx)
        self._prepare_fn = jax.jit(
            lambda xs, key: ss.sharded_sann_prepare_chunk(
                self.params, xs, key, self.cfg, self._ctx))
        self._commit_fn = jax.jit(
            lambda st, prep: ss.sharded_sann_commit_chunk(
                st, prep, self.cfg, self._ctx))
        self._query_fn = jax.jit(
            lambda st, qs: ss.sharded_sann_query_batch(
                st, self.params, qs, self.cfg, self._ctx))
        self._topk_fn = jax.jit(
            lambda st, qs: ss.sharded_sann_query_topk_batch(
                st, self.params, qs, self.cfg, self._ctx,
                topk=self.service_cfg.topk))
        self._delete_fn = jax.jit(
            lambda st, x: ss.sharded_sann_delete(
                st, self.params, x, self.cfg, self._ctx))

    # --- engine hooks (two-phase ingest) -----------------------------------

    def _make_chunk_item(self, chunk: jax.Array, seq: int) -> tuple:
        # Per-chunk key = fold_in(base, chunk seq): deterministic given the
        # submission order, identical for sync/async ingest and for the
        # crash-recovery replay of the same sequence numbers.
        return (chunk, jax.random.fold_in(self._ingest_key, seq))

    def _prepare(self, chunk: jax.Array, key: jax.Array) -> sann.SANNPrep:
        return self._prepare_fn(chunk, key)

    def _commit(self, state: sann.SANNState, prep: sann.SANNPrep):
        return self._commit_fn(state, prep)

    def _place_state(self, state: sann.SANNState) -> sann.SANNState:
        if self._ctx.mesh is None:
            return state
        return ss.shard_sann(state, self.params, self._ctx)[0]

    def _apply_wal_record(self, kind: int, arrays: dict) -> None:
        if kind == persist.KIND_DELETE:
            x = jnp.asarray(arrays["x"])
            self._mutate_state(lambda st: self._delete_fn(st, x))
            return
        super()._apply_wal_record(kind, arrays)

    # --- query kinds (micro-batching; engine._BatchedQueryMixin) -----------

    _default_query_kind = "cr"

    def _query_kind_fns(self):
        """Both S-ANN query kinds — the (c, r) contract and the top-k
        recall variant — read one snapshot's state through the fused batch
        engine in ``query_block`` blocks, so a coalesced tick can mix
        them against the same committed prefix."""
        def cr(ctx, qs):
            state, _ = ctx
            return self._query_blocks(lambda b: self._query_fn(state, b), qs)

        def topk(ctx, qs):
            state, _ = ctx
            return self._query_blocks(lambda b: self._topk_fn(state, b), qs)

        return {"cr": cr, "topk": topk}

    # --- serving API -------------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Devices the tables are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    def delete(self, embedding: np.ndarray) -> None:
        """Turnstile deletion (paper §3.4).  Pending async chunks are
        flushed first, then the delete applies atomically — so apply order
        equals submission order (and, with durability, WAL order; the
        delete is logged before it applies)."""
        x = jnp.asarray(embedding)
        self._durable_mutate(persist.KIND_DELETE,
                             {"x": np.asarray(x, np.float32)},
                             lambda st: self._delete_fn(st, x))

    def query(self, queries: np.ndarray) -> sann.SANNResult:
        """Batched (c, r)-queries (paper §3.3) through the fused batch
        engine, in blocks of ``query_block`` rows (one hash matmul + one
        gather + one fused scorer call per block) — all blocks against one
        lock-consistent snapshot of the committed state.  With
        ``batch_queries`` the call is coalesced with concurrent clients'
        queries into one fused batch (bit-identical results)."""
        return self._serve_query("cr", queries)

    def query_topk(self, queries: np.ndarray):
        """Batched top-k queries (recall workloads; no (c, r) contract):
        ``(B, d)`` → ``(ids (B, k), dists (B, k))`` with ``k = min(cfg.topk,
        L * bucket_cap)``, padded with id -1 / distance inf.  Same snapshot
        and micro-batching semantics as `query`."""
        return self._serve_query("topk", queries)

    @property
    def stored(self) -> int:
        return int(self.state.n_stored)

    @property
    def sketch_bytes(self) -> int:
        return sann.sann_bytes(self.cfg)
