"""Merge-based multi-worker streaming cluster (DESIGN.md §11.4–11.5).

Scales ingest past one engine: N worker `SketchEngine`s (the existing
single-engine services, unchanged) ingest **hash-partitioned substreams**
concurrently — each worker has its own commit worker + prepare thread, so
K workers drive up to 2K cores — and a coordinator combines the per-worker
sketch states through the merge algebra the cores already expose:

  * RACE      — `core.race.race_merge` (exact counter addition): cluster
                estimates are *bit-identical* to a single engine over the
                whole stream, any partition.
  * SW-AKDE   — `core.swakde.swakde_merge` (canonical DGIM bucket-union):
                bit-identical while nothing has expired from the window;
                once worker windows expire, estimate-level (per-input eps')
                like any EH merge.  Worker clocks tick per *local* point —
                size worker windows as window/K for a balanced partition.
  * S-ANN     — `core.sann.sann_merge` (stamp-interleaved union under the
                paper's n^-eta sampling: a union of independently sampled
                substreams is exactly a sample of the union stream).
                Workers share LSH params (same seed) but salt their keep
                decisions (`ingest_salt`), and the merged sketch equals a
                single engine fed the canonical interleaving
                (tests/test_cluster.py).

Merge cadence: the coordinator folds worker snapshots into a cached merged
state whenever the summed worker commit count has advanced by
``merge_every`` since the last merge (checked at submit/flush time), and
*at query time* whenever the cache is stale — so queries always see every
committed chunk, and ``merge_every`` only tunes how much merge latency is
paid inline by queries vs amortised into ingest.  Worker snapshots are
lock-consistent committed prefixes; the merged view is a committed prefix
per worker.

The cluster exposes the same ``ingest`` / ``ingest_async`` / ``flush`` /
query API as the single-engine services, plus per-worker durability:
with ``snapshot_dir`` set, worker w persists under ``<dir>/worker_<w>``
and ``recover()`` recovers every worker (bit-identically) and re-merges.

Query-side micro-batching (DESIGN.md §13): the coordinator owns its own
`engine.QueryBatcher` — with ``batch_queries`` set on the service config,
concurrent client queries coalesce into one fused batch per tick served
from ONE ``merged_snapshot()``.  This matters more here than on a single
engine: a stale merge cache makes every query pay a query-time tail merge
under the coordinator lock, so K concurrent clients used to pay K merges —
the batcher folds them into one merge + one fused call per tick
(tests/test_serve_batching.py pins that query cost does not scale with
the concurrent-client count).  Workers never enable their own batcher
(the coordinator reads them through their jitted query fns directly).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
import threading
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import race, sann, swakde
from repro.parallel import sketch_sharding as ss
from repro.serve.engine import SketchEngine, _BatchedQueryMixin
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

_MIX0 = np.uint64(0x9E3779B97F4A7C15)   # splitmix64 golden-ratio constant
_MIX1 = np.uint64(0xFF51AFD7ED558CCD)
_MIX2 = np.uint64(0xC4CEB9FE1A85EC53)


def hash_partition(xs: np.ndarray, num_workers: int) -> np.ndarray:
    """Deterministic content-hash worker assignment: ``xs (B, d) float32``
    → worker ids ``(B,) int64`` in [0, num_workers).

    Hashes the raw float32 bit patterns (splitmix64-style mix over a
    per-dimension-weighted sum), so the partition is a pure function of the
    row's bytes — stable across runs, processes and recovery replays, and
    independent of arrival order (the property the S-ANN "union of samples"
    merge argument needs: each point's owner is fixed, so substreams are
    disjoint)."""
    if num_workers <= 1:
        return np.zeros(len(xs), np.int64)
    b = np.ascontiguousarray(np.asarray(xs, np.float32)).view(np.uint32)
    with np.errstate(over="ignore"):
        w = (_MIX0 * (np.arange(b.shape[1], dtype=np.uint64) * np.uint64(2)
                      + np.uint64(1)))
        h = (b.astype(np.uint64) * w[None, :]).sum(axis=1)
        h ^= h >> np.uint64(33)
        h *= _MIX1
        h ^= h >> np.uint64(33)
        h *= _MIX2
        h ^= h >> np.uint64(33)
    return (h % np.uint64(num_workers)).astype(np.int64)


class ClusterService(_BatchedQueryMixin):
    """Coordinator over N worker engines + a merge function (base class;
    use the sketch-specific subclasses below).

    ``make_worker(w)`` must build workers with *identical* sketch params
    (same seed) — the precondition of every merge.  ``merge_states`` folds
    a list of worker states into one (worker order fixes the canonical
    interleaving for S-ANN).  ``merge_every`` is the proactive merge
    cadence in summed worker commits.  ``batch_queries`` routes the sync
    query wrappers through the coordinator's admission scheduler: one
    merged snapshot (and, when stale, one tail merge) serves the whole
    coalesced batch instead of one per client query."""

    def __init__(self, make_worker: Callable[[int], SketchEngine],
                 num_workers: int, merge_every: int,
                 merge_states: Callable[[Sequence], object],
                 snapshot_dir: Optional[str] = None,
                 batch_queries: bool = False,
                 max_batch: Optional[int] = None,
                 max_wait_us: float = 200.0):
        if num_workers < 1:
            raise ValueError(f"num_workers={num_workers}")
        if snapshot_dir is not None:
            self._check_cluster_dir(snapshot_dir, num_workers)
        self.workers: List[SketchEngine] = [make_worker(w)
                                            for w in range(num_workers)]
        self._merge_every = max(1, int(merge_every))
        self._merge_fn = jax.jit(merge_states)
        self._mlock = threading.Lock()
        self._merged = None
        self._merged_versions: Optional[tuple] = None
        self._merged_meta: Optional[dict] = None
        self._last_merge_total = 0
        self._init_query_batching(
            batch_queries, max_batch, max_wait_us,
            default_max_batch=self.workers[0]._query_block)

    @staticmethod
    def _check_cluster_dir(snapshot_dir: str, num_workers: int) -> None:
        """Refuse to open a durable cluster directory with a different
        worker count than it was written with: hash ownership is a
        function of the count, so a mismatched reopen would silently drop
        the missing workers' WAL-logged data (and mis-route new points).
        The count is pinned in ``cluster.json`` on first open."""
        root = pathlib.Path(snapshot_dir)
        root.mkdir(parents=True, exist_ok=True)
        meta_path = root / "cluster.json"
        # A root holding single-engine durable state (root-level WAL or
        # snapshots) must not be quietly re-pinned as a cluster dir — the
        # workers would open empty worker_* subdirs and the existing index
        # would be silently absent after "recovery".  (The engine-level
        # cluster.json guard covers the converse direction.)
        if any(root.glob("step_*")) or (root / "wal").exists():
            raise RuntimeError(
                f"{snapshot_dir!r} holds single-engine durable state "
                "(root-level snapshots/WAL); a cluster persists under "
                "worker_* subdirectories and cannot recover it.  Use a "
                "fresh directory, or reopen with the single-engine "
                "service.")
        existing = sorted(p.name for p in root.glob("worker_*") if p.is_dir())
        if meta_path.exists():
            saved = json.loads(meta_path.read_text()).get("num_workers")
        elif existing:
            saved = len(existing)        # legacy dir without metadata
        else:
            saved = None
        if saved is not None and saved != num_workers:
            raise RuntimeError(
                f"cluster durability dir {snapshot_dir!r} was written with "
                f"num_workers={saved}, reopened with {num_workers}: hash "
                "partition ownership depends on the worker count, so "
                "recovery would silently lose the other workers' data.  "
                "Reopen with the original count.")
        if saved is None:
            meta_path.write_text(json.dumps({"num_workers": num_workers}))

    # --- ingest ------------------------------------------------------------

    def ingest(self, data) -> None:
        """Hash-partition ``data`` across the workers and wait for every
        chunk to commit (``ingest_async`` + ``flush``)."""
        self.ingest_async(data)
        self.flush()

    def ingest_async(self, data) -> None:
        """Hash-partition ``data (B, d)`` and submit each worker's substream
        to its background ingest queue (order-preserving within a worker).

        Submission interleaves one engine-chunk per worker, round-robin:
        with ``max_pending`` admission control on, a backpressured worker
        then only stalls the cluster once *its own* bound is hit with every
        other queue already fed — submitting whole substreams in worker
        order would instead park the caller inside worker 0's bound while
        workers 1..K-1 sit idle (head-of-line blocking).  Per-worker chunk
        boundaries are identical either way, so states are unchanged."""
        xs = np.asarray(data, np.float32)
        if xs.shape[0] == 0:
            return
        pid = hash_partition(xs, len(self.workers))
        parts = [xs[pid == w] for w in range(len(self.workers))]
        offs = [0] * len(self.workers)
        pending = True
        while pending:
            pending = False
            for w, worker in enumerate(self.workers):
                if offs[w] < parts[w].shape[0]:
                    chunk = worker._chunk
                    worker.ingest_async(parts[w][offs[w]:offs[w] + chunk])
                    offs[w] += chunk
                    pending = pending or offs[w] < parts[w].shape[0]
        self._maybe_merge()

    def flush(self) -> None:
        """Wait for every worker's queued chunks to commit (re-raising any
        worker's background failure), then apply the merge cadence."""
        for w in self.workers:
            w.flush()
        self._maybe_merge()

    def close(self) -> None:
        """Drain the coordinator's query batcher, then close every worker;
        the first failure is re-raised *after* the remaining workers have
        still been closed (no leaked WAL handles or threads behind an
        early error)."""
        self._close_batcher()
        first: Optional[BaseException] = None
        for w in self.workers:
            try:
                w.close()
            except BaseException as e:
                first = first or e
        if first is not None:
            raise first

    def recover(self) -> int:
        """Recover every worker from its durability directory (snapshot +
        WAL replay, bit-identical per worker) and rebuild the merged view.
        Returns the total number of WAL records replayed."""
        n = sum(w.recover() for w in self.workers)
        self._refresh()
        return n

    # --- merged view ---------------------------------------------------------

    @property
    def versions(self) -> tuple:
        """Per-worker commit versions (the merge-cadence clock)."""
        return tuple(w.version for w in self.workers)

    @property
    def version(self) -> int:
        """Summed worker commit count."""
        return sum(self.versions)

    def _maybe_merge(self) -> None:
        if self.version - self._last_merge_total >= self._merge_every:
            self._refresh()

    def _refresh(self):
        """Fold the workers' current committed snapshots into the merged
        cache (no-op when the cache already matches the snapshots).
        Returns the consistent ``(state, meta, versions)`` triple."""
        snaps = [w.snapshot() for w in self.workers]
        states = [s for s, _ in snaps]
        vers = tuple(v for _, v in snaps)
        with self._mlock:
            if self._merged_versions == vers:
                return self._merged, self._merged_meta, vers
            merged = (states[0] if len(states) == 1
                      else jax.block_until_ready(self._merge_fn(states)))
            meta = self._meta(states)
            if (self._merged_versions is None
                    or sum(self._merged_versions) <= sum(vers)):
                # Install only if not older than the cache: a racing
                # _refresh whose snapshots were taken later may already
                # have installed a newer merge (worker versions are
                # monotone, so the sum orders snapshots).  Either way this
                # caller gets its own consistent triple.
                self._merged = merged
                self._merged_versions = vers
                self._merged_meta = meta
                self._last_merge_total = sum(vers)
            return merged, meta, vers

    def merged_snapshot(self):
        """``(state, meta, versions)`` of one consistent merge covering
        every worker commit: the cached merge when fresh, else a
        query-time merge of the unmerged tails.  Numerator and any
        normalising scalars of one answer must come from a single call —
        state and meta are written together under the merge lock."""
        vers = self.versions
        with self._mlock:
            if self._merged_versions == vers:
                return self._merged, self._merged_meta, vers
        return self._refresh()

    def merged_state(self):
        """The merged sketch alone (see `merged_snapshot`)."""
        return self.merged_snapshot()[0]

    def _meta(self, states) -> Optional[dict]:
        """Subclass hook: scalars to capture alongside a merge (same
        snapshot the merged state came from)."""
        return None

    def _query_state(self, st, queries):
        """Run worker 0's jitted query function over ``st`` in its
        ``query_block`` blocks — the shared read path of every subclass's
        query API (worker params are identical, so any worker's query fn
        serves the merged sketch)."""
        qs = jnp.asarray(queries, jnp.float32)
        w0 = self.workers[0]
        return w0._query_blocks(lambda b: w0._query_fn(st, b), qs)

    def _query_snapshot_ctx(self):
        """One consistent merged ``(state, meta, versions)`` triple serving
        a whole query tick — a stale merge cache costs ONE tail merge per
        coalesced batch, not one per client (subclasses with extra
        per-merge caches extend this)."""
        return self.merged_snapshot()

    def _batch_query_block(self) -> int:
        return self.workers[0]._query_block

    @property
    def sketch_bytes(self) -> int:
        """Total sketch footprint across the workers (N replicas of the
        same allocation)."""
        return sum(w.sketch_bytes for w in self.workers)


# ---------------------------------------------------------------------------
# Sketch-specific clusters
# ---------------------------------------------------------------------------

def _worker_cfg(cfg, w: int, **extra):
    """Per-worker config: same seed (identical params), per-worker
    durability subdirectory, plus sketch-specific fields via ``extra``."""
    sub = (None if getattr(cfg, "snapshot_dir", None) is None
           else f"{cfg.snapshot_dir}/worker_{w}")
    return dataclasses.replace(cfg, snapshot_dir=sub, **extra)


class ClusterRetrievalService(ClusterService):
    """N-worker S-ANN cluster: hash-partitioned ingest, `sann_merge`-based
    coordinator, single-service query API (`query`, `delete`)."""

    def __init__(self, cfg: RetrievalConfig, num_workers: int = 2,
                 merge_every: int = 8):
        def make(w: int) -> RetrievalService:
            # Same seed → identical LSH params (merge precondition); the
            # salt decorrelates the workers' Bernoulli keep decisions.
            # Workers never run their own query batcher — the coordinator
            # coalesces and reads them via their jitted query fns.
            return RetrievalService(
                _worker_cfg(cfg, w, ingest_salt=w, batch_queries=False))

        super().__init__(
            make, num_workers, merge_every,
            lambda states: functools.reduce(
                lambda a, b: ss.sharded_sann_merge(
                    a, b, self.workers[0].params, self.workers[0].cfg,
                    self.workers[0]._ctx),
                states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us)

    _default_query_kind = "cr"

    def _query_kind_fns(self):
        def cr(ctx, qs):
            return self._query_state(ctx[0], qs)

        def topk(ctx, qs):
            w0 = self.workers[0]
            return w0._query_blocks(lambda b: w0._topk_fn(ctx[0], b), qs)

        return {"cr": cr, "topk": topk}

    def query(self, queries: np.ndarray) -> sann.SANNResult:
        """Batched (c, r)-queries against the merged sketch, in the worker
        engine's ``query_block`` blocks (coalesced with concurrent clients
        when ``batch_queries`` — one merged snapshot per tick)."""
        return self._serve_query("cr", queries)

    def query_topk(self, queries: np.ndarray):
        """Batched top-k queries against the merged sketch (same snapshot
        and micro-batching semantics as `query`)."""
        return self._serve_query("topk", queries)

    def delete(self, embedding: np.ndarray) -> None:
        """Turnstile delete-by-value, broadcast to every worker.

        `sann_delete` tombstones every stored point within ``tol`` of the
        value, so a near-copy with different float bits can live on *any*
        worker (hash ownership is per bit pattern) — routing to the exact
        owner alone would miss it.  Broadcasting reproduces single-engine
        semantics exactly; workers without a match apply a no-op."""
        x = np.asarray(embedding, np.float32)
        for worker in self.workers:
            worker.delete(x)

    @property
    def stored(self) -> int:
        """Live stored points in the merged sketch (post union-eviction)."""
        return int(self.merged_state().n_stored)


class ClusterKDEService(ClusterService):
    """N-worker SW-AKDE cluster: hash-partitioned ingest, EH bucket-union
    coordinator.  Worker windows tick per local point — configure
    ``window`` as the per-worker span (≈ global window / K for a balanced
    partition); estimates are bit-identical to one engine until window
    expiry, estimate-level after (DESIGN.md §11.5)."""

    def __init__(self, cfg: KDEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8):
        super().__init__(
            lambda w: KDEService(_worker_cfg(cfg, w, batch_queries=False)),
            num_workers, merge_every,
            lambda states: functools.reduce(
                lambda a, b: swakde.swakde_merge(
                    a, b, self.workers[0].sketch_cfg),
                states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us)
        self.cfg = cfg
        # cache_grid over the merged sketch: the (L, W) grid-estimate table
        # is pure given the merged state, so it is cached per merged
        # versions tuple (same invalidation clock as the merge cache).
        self._grid = None
        self._grid_versions: Optional[tuple] = None

    def _meta(self, states):
        # Captured from the *same* snapshots the merged state came from:
        # the density denominator is the number of points the merged grid
        # can still see — each worker contributes its last
        # min(t_w, window) steps (worker windows tick on local clocks), so
        # the coverages sum; summing raw clocks would overestimate density
        # by up to K once the windows saturate.
        return {"coverage": int(sum(min(int(s.t), self.cfg.window)
                                    for s in states))}

    def _merged_grid(self, st, vers):
        """The (L, W) grid-estimate table of merged state ``st`` (computed
        at most once per merged versions tuple; concurrent same-version
        computes are benign, last install wins)."""
        with self._mlock:
            if self._grid_versions == vers:
                return self._grid
        grid = jax.block_until_ready(self.workers[0]._grid_fn(st))
        with self._mlock:
            self._grid, self._grid_versions = grid, vers
        return grid

    def _estimates(self, st, vers, queries) -> np.ndarray:
        """Batched Ŷ against one merged snapshot — from the per-merge grid
        cache when ``cache_grid`` is on (bit-identical either way), else
        the fused engine."""
        qs = jnp.asarray(queries, jnp.float32)
        if self.cfg.cache_grid:
            grid = self._merged_grid(st, vers)
            w0 = self.workers[0]
            return np.asarray(w0._query_blocks(
                lambda b: w0._grid_query_fn(grid, b), qs))
        return np.asarray(self._query_state(st, qs))

    _default_query_kind = "kde"

    def _query_kind_fns(self):
        def kde(ctx, qs):
            st, _, vers = ctx
            return self._estimates(st, vers, qs)

        def density(ctx, qs):
            # coverage and estimates from the *same* merged snapshot; the
            # batch-wide scalar divide keeps coalescing bit-identical.
            st, meta, vers = ctx
            return (self._estimates(st, vers, qs)
                    / max((meta or {}).get("coverage", 0), 1))

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised window-density estimates Ŷ against the
        merged grid (coalesced with concurrent clients when
        ``batch_queries`` — one merged snapshot + one grid per tick)."""
        return self._serve_query("kde", queries)

    def density(self, queries: np.ndarray) -> np.ndarray:
        """Normalised density: Ŷ / (summed per-worker window coverage) —
        the coverage and the estimates come from the *same* merged
        snapshot (micro-batched like `query`)."""
        return self._serve_query("density", queries)

    @property
    def steps(self) -> int:
        """Stream steps consumed across all workers."""
        return sum(w.steps for w in self.workers)


class ClusterRACEService(ClusterService):
    """N-worker RACE cluster: hash-partitioned ingest, exact counter-sum
    coordinator — cluster estimates are bit-identical to a single engine
    over the whole stream (tests/test_cluster.py)."""

    def __init__(self, cfg: RACEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8):
        super().__init__(
            lambda w: RACEService(_worker_cfg(cfg, w, batch_queries=False)),
            num_workers, merge_every,
            lambda states: functools.reduce(race.race_merge, states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us)
        self.cfg = cfg

    _default_query_kind = "kde"

    def _query_kind_fns(self):
        def kde(ctx, qs):
            return np.asarray(self._query_state(ctx[0], qs))

        def density(ctx, qs):
            # counters and n from the *same* merged snapshot
            st = ctx[0]
            return kde(ctx, qs) / max(float(np.asarray(st.n)), 1.0)

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised KDE estimates against the merged counters
        (coalesced with concurrent clients when ``batch_queries`` — one
        merged snapshot, and at most one tail merge, per tick)."""
        return self._serve_query("kde", queries)

    def kde(self, queries: np.ndarray) -> np.ndarray:
        """Normalised density — counters and ``n`` from the *same* merged
        snapshot (micro-batched like `query`)."""
        return self._serve_query("density", queries)

    def delete(self, embeddings: np.ndarray) -> None:
        """Turnstile decrements, routed to each row's hash owner."""
        xs = np.atleast_2d(np.asarray(embeddings, np.float32))
        pid = hash_partition(xs, len(self.workers))
        for w, worker in enumerate(self.workers):
            rows = xs[pid == w]
            if rows.shape[0]:
                worker.delete(rows)

    @property
    def count(self) -> int:
        """Signed stream size across all workers."""
        return sum(w.count for w in self.workers)
