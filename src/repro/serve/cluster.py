"""Merge-based multi-worker streaming cluster (DESIGN.md §11.4–11.5).

Scales ingest past one engine: N worker `SketchEngine`s (the existing
single-engine services, unchanged) ingest **hash-partitioned substreams**
concurrently — each worker has its own commit worker + prepare thread, so
K workers drive up to 2K cores — and a coordinator combines the per-worker
sketch states through the merge algebra the cores already expose:

  * RACE      — `core.race.race_merge` (exact counter addition): cluster
                estimates are *bit-identical* to a single engine over the
                whole stream, any partition.
  * SW-AKDE   — `core.swakde.swakde_merge` (canonical DGIM bucket-union):
                bit-identical while nothing has expired from the window;
                once worker windows expire, estimate-level (per-input eps')
                like any EH merge.  Worker clocks tick per *local* point —
                size worker windows as window/K for a balanced partition.
  * S-ANN     — `core.sann.sann_merge` (stamp-interleaved union under the
                paper's n^-eta sampling: a union of independently sampled
                substreams is exactly a sample of the union stream).
                Workers share LSH params (same seed) but salt their keep
                decisions (`ingest_salt`), and the merged sketch equals a
                single engine fed the canonical interleaving
                (tests/test_cluster.py).

Merge cadence: the coordinator folds worker snapshots into a cached merged
state whenever the summed worker commit count has advanced by
``merge_every`` since the last merge (checked at submit/flush time), and
*at query time* whenever the cache is stale — so queries always see every
committed chunk, and ``merge_every`` only tunes how much merge latency is
paid inline by queries vs amortised into ingest.  Worker snapshots are
lock-consistent committed prefixes; the merged view is a committed prefix
per worker.

The cluster exposes the same ``ingest`` / ``ingest_async`` / ``flush`` /
query API as the single-engine services, plus per-worker durability:
with ``snapshot_dir`` set, worker w persists under ``<dir>/worker_<w>``
and ``recover()`` recovers every worker (bit-identically) and re-merges.

Query-side micro-batching (DESIGN.md §13): the coordinator owns its own
`engine.QueryBatcher` — with ``batch_queries`` set on the service config,
concurrent client queries coalesce into one fused batch per tick served
from ONE ``merged_snapshot()``.  This matters more here than on a single
engine: a stale merge cache makes every query pay a query-time tail merge
under the coordinator lock, so K concurrent clients used to pay K merges —
the batcher folds them into one merge + one fused call per tick
(tests/test_serve_batching.py pins that query cost does not scale with
the concurrent-client count).  Workers never enable their own batcher
(the coordinator reads them through their jitted query fns directly).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
import threading
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.core import race, sann, swakde
from repro.parallel import sketch_sharding as ss
from repro.persist import faults
from repro.serve.engine import SketchEngine, _BatchedQueryMixin
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

_MIX0 = np.uint64(0x9E3779B97F4A7C15)   # splitmix64 golden-ratio constant
_MIX1 = np.uint64(0xFF51AFD7ED558CCD)
_MIX2 = np.uint64(0xC4CEB9FE1A85EC53)


def _mix_u64(xs: np.ndarray) -> np.ndarray:
    """splitmix64-style content hash of each row's raw float32 bit
    patterns: ``xs (B, d) float32`` → ``(B,) uint64``.  A pure function of
    the row's bytes — stable across runs, processes and recovery replays,
    and independent of arrival order."""
    b = np.ascontiguousarray(np.asarray(xs, np.float32)).view(np.uint32)
    with np.errstate(over="ignore"):
        w = (_MIX0 * (np.arange(b.shape[1], dtype=np.uint64) * np.uint64(2)
                      + np.uint64(1)))
        h = (b.astype(np.uint64) * w[None, :]).sum(axis=1)
        h ^= h >> np.uint64(33)
        h *= _MIX1
        h ^= h >> np.uint64(33)
        h *= _MIX2
        h ^= h >> np.uint64(33)
    return h


def hash_partition(xs: np.ndarray, num_workers: int) -> np.ndarray:
    """Deterministic content-hash worker assignment: ``xs (B, d) float32``
    → worker ids ``(B,) int64`` in [0, num_workers).

    The partition is a pure function of the row's bytes (`_mix_u64`) —
    the property the S-ANN "union of samples" merge argument needs: each
    point's owner is fixed, so substreams are disjoint."""
    if num_workers <= 1:
        return np.zeros(len(xs), np.int64)
    return (_mix_u64(xs) % np.uint64(num_workers)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class FailoverConfig:
    """Worker-failover policy for a `ClusterService` (DESIGN.md §14).

    ``on_degraded`` — query behaviour while any worker is DEAD:
      * ``"fail"``    raise `ClusterDegradedError` (loud, strict);
      * ``"block"``   wait up to ``block_deadline_s`` for the cluster's
        data to be whole again (poisoned workers recovered, every dead
        worker's WAL tail fully re-partitioned), then serve — or raise at
        the deadline;
      * ``"partial"`` serve the live subset, with coverage metadata
        (``worker_coverage < 1``) on every answer.

    ``max_retries``/``backoff_s`` — in-place retries with exponential
    backoff for *transient* faults (`faults.is_transient`), and the
    rebuild-and-`recover()` attempt budget for a poisoned worker.
    ``repartition`` — when a worker is unrecoverable, re-ingest its
    replayable WAL tail into the surviving workers through the normal
    content-hash route (exact for every sketch via the merge algebra;
    §14 has the per-sketch argument).  Passing ``failover=None`` to the
    cluster keeps the legacy fail-stop semantics: the first worker error
    propagates and queries keep re-raising until an operator intervenes.
    """
    on_degraded: str = "fail"        # "fail" | "block" | "partial"
    block_deadline_s: float = 10.0
    max_retries: int = 3
    backoff_s: float = 0.01
    repartition: bool = True

    def __post_init__(self):
        if self.on_degraded not in ("fail", "block", "partial"):
            raise ValueError(f"on_degraded={self.on_degraded!r}")
        if self.max_retries < 0 or self.backoff_s < 0:
            raise ValueError("max_retries/backoff_s must be >= 0")


class ClusterDegradedError(RuntimeError):
    """Raised by queries under the ``fail``/``block`` degraded policies
    while the cluster cannot answer from complete data.  Carries the dead
    worker ids (``.dead``) and whether each one's WAL tail was fully
    re-partitioned (``.salvaged``)."""

    def __init__(self, msg: str, dead: Sequence[int] = (),
                 salvaged: Sequence[int] = ()):
        super().__init__(msg)
        self.dead = sorted(dead)
        self.salvaged = sorted(salvaged)


class ClusterService(_BatchedQueryMixin):
    """Coordinator over N worker engines + a merge function (base class;
    use the sketch-specific subclasses below).

    ``make_worker(w)`` must build workers with *identical* sketch params
    (same seed) — the precondition of every merge.  ``merge_states`` folds
    a list of worker states into one (worker order fixes the canonical
    interleaving for S-ANN).  ``merge_every`` is the proactive merge
    cadence in summed worker commits.  ``batch_queries`` routes the sync
    query wrappers through the coordinator's admission scheduler: one
    merged snapshot (and, when stale, one tail merge) serves the whole
    coalesced batch instead of one per client query."""

    _query_fault_site = "cluster.query"

    def __init__(self, make_worker: Callable[[int], SketchEngine],
                 num_workers: int, merge_every: int,
                 merge_states: Callable[[Sequence], object],
                 snapshot_dir: Optional[str] = None,
                 batch_queries: bool = False,
                 max_batch: Optional[int] = None,
                 max_wait_us: float = 200.0,
                 failover: Optional[FailoverConfig] = None):
        if num_workers < 1:
            raise ValueError(f"num_workers={num_workers}")
        if snapshot_dir is not None:
            self._check_cluster_dir(snapshot_dir, num_workers)
        self._make_worker = make_worker
        self.workers: List[SketchEngine] = [make_worker(w)
                                            for w in range(num_workers)]
        self._merge_every = max(1, int(merge_every))
        self._merge_fn = jax.jit(merge_states)
        self._mlock = threading.Lock()
        self._merged = None
        self._merged_versions: Optional[tuple] = None
        self._merged_meta: Optional[dict] = None
        self._merged_epoch = 0
        self._last_merge_total = 0
        # Failover (DESIGN §14).  _flock orders failure handling; it is
        # reentrant because salvage re-ingests through ingest_async, which
        # may itself hit (and handle) another worker's failure.  Lock
        # order: _flock before _mlock, never the reverse.
        self._failover = failover
        self._flock = threading.RLock()
        self._health: List[str] = ["live"] * num_workers
        self._dead: set = set()
        self._salvaged: set = set()      # dead workers whose full WAL tail
        #                                  was re-partitioned (no data lost)
        # Per-dead-worker salvage checkpoint: last WAL seq durably handed
        # to the survivors — a coordinator crash mid-salvage resumes past
        # this prefix instead of re-ingesting the whole log.
        self._salvage_progress: dict = {}
        self._epoch = 0                  # partition epoch: bumps per death
        self._counters = {"retries": 0, "recoveries": 0,
                          "repartitions": 0, "salvaged_records": 0,
                          "salvaged_rows": 0}
        self._meta_path = (None if snapshot_dir is None
                           else pathlib.Path(snapshot_dir) / "cluster.json")
        if self._meta_path is not None and self._meta_path.exists():
            saved = json.loads(self._meta_path.read_text())
            self._dead = set(saved.get("dead_workers", []))
            self._salvaged = set(saved.get("salvage_complete", []))
            self._salvage_progress = {
                int(k): int(v)
                for k, v in saved.get("salvage_progress", {}).items()}
            self._epoch = int(saved.get("epoch", 0))
            for w in self._dead:
                self._health[w] = "dead"
        self._init_query_batching(
            batch_queries, max_batch, max_wait_us,
            default_max_batch=self._ref._query_block)

    @property
    def _ref(self):
        """The *reference engine* supplying jitted query/merge functions,
        sketch params and shape knobs for the coordinator's read path.
        Workers share identical params (same seed), so any worker serves;
        the in-process cluster uses worker 0.  The RPC cluster overrides
        this with a local never-ingested template engine — remote proxies
        have no jitted functions to borrow."""
        return self.workers[0]

    @staticmethod
    def _check_cluster_dir(snapshot_dir: str, num_workers: int) -> None:
        """Refuse to open a durable cluster directory with a different
        worker count than it was written with: hash ownership is a
        function of the count, so a mismatched reopen would silently drop
        the missing workers' WAL-logged data (and mis-route new points).
        The count is pinned in ``cluster.json`` on first open."""
        root = pathlib.Path(snapshot_dir)
        root.mkdir(parents=True, exist_ok=True)
        meta_path = root / "cluster.json"
        # A root holding single-engine durable state (root-level WAL or
        # snapshots) must not be quietly re-pinned as a cluster dir — the
        # workers would open empty worker_* subdirs and the existing index
        # would be silently absent after "recovery".  (The engine-level
        # cluster.json guard covers the converse direction.)
        if any(root.glob("step_*")) or (root / "wal").exists():
            raise RuntimeError(
                f"{snapshot_dir!r} holds single-engine durable state "
                "(root-level snapshots/WAL); a cluster persists under "
                "worker_* subdirectories and cannot recover it.  Use a "
                "fresh directory, or reopen with the single-engine "
                "service.")
        existing = sorted(p.name for p in root.glob("worker_*") if p.is_dir())
        if meta_path.exists():
            saved = json.loads(meta_path.read_text()).get("num_workers")
        elif existing:
            saved = len(existing)        # legacy dir without metadata
        else:
            saved = None
        if saved is not None and saved != num_workers:
            raise RuntimeError(
                f"cluster durability dir {snapshot_dir!r} was written with "
                f"num_workers={saved}, reopened with {num_workers}: hash "
                "partition ownership depends on the worker count, so "
                "recovery would silently lose the other workers' data.  "
                "Reopen with the original count.")
        if saved is None:
            meta_path.write_text(json.dumps({"num_workers": num_workers}))

    # --- ingest ------------------------------------------------------------

    def ingest(self, data) -> None:
        """Hash-partition ``data`` across the workers and wait for every
        chunk to commit (``ingest_async`` + ``flush``)."""
        self.ingest_async(data)
        self.flush()

    def ingest_async(self, data) -> None:
        """Hash-partition ``data (B, d)`` and submit each worker's substream
        to its background ingest queue (order-preserving within a worker).

        Submission interleaves one engine-chunk per worker, round-robin:
        with ``max_pending`` admission control on, a backpressured worker
        then only stalls the cluster once *its own* bound is hit with every
        other queue already fed — submitting whole substreams in worker
        order would instead park the caller inside worker 0's bound while
        workers 1..K-1 sit idle (head-of-line blocking).  Per-worker chunk
        boundaries are identical either way, so states are unchanged."""
        xs = np.asarray(data, np.float32)
        if xs.shape[0] == 0:
            return
        pid = self._partition(xs)
        n = len(self.workers)
        parts = [xs[pid == w] for w in range(n)]
        offs = [0] * n
        pending = True
        while pending:
            pending = False
            for w in range(n):
                if offs[w] >= parts[w].shape[0]:
                    continue
                worker = self.workers[w]
                chunk = parts[w][offs[w]:offs[w] + worker._chunk]
                try:
                    self._with_retries(
                        w, lambda w=w, c=chunk: self.workers[w]
                        .ingest_async(c))
                except BaseException as e:
                    if self._failover is None:
                        raise
                    if self._handle_worker_failure(w, e):
                        # Recovered bit-identically: the failed chunk was
                        # rejected (an ingest_async raise never accepts the
                        # submitted chunk), so resubmitting it — and only
                        # it — is exact.
                        pending = True
                        continue
                    # Unrecoverable: the worker's accepted tail was
                    # re-partitioned by _declare_dead; its unsubmitted
                    # substream (this chunk included) re-routes to the
                    # survivors through the normal dead-aware hash path.
                    rest = parts[w][offs[w]:]
                    offs[w] = parts[w].shape[0]
                    if rest.shape[0]:
                        self.ingest_async(rest)
                    continue
                offs[w] += chunk.shape[0]
                pending = pending or offs[w] < parts[w].shape[0]
        self._maybe_merge()

    def flush(self) -> None:
        """Wait for every worker's queued chunks to commit (re-raising any
        worker's background failure), then apply the merge cadence.

        With failover: a worker whose background commit failed poisoned
        itself with the failing chunk already WAL-logged (accepted), so
        the handler recovers it in place — the replay re-commits the
        chunk, nothing is resubmitted, nothing is lost."""
        for w in range(len(self.workers)):
            if w in self._dead:
                continue
            try:
                self.workers[w].flush()
            except BaseException as e:
                if self._failover is None:
                    raise
                self._handle_worker_failure(w, e)
        self._maybe_merge()

    def close(self) -> None:
        """Drain the coordinator's query batcher, then close every worker.
        Every worker is closed even when some fail (no leaked WAL handles
        or threads behind an early error); all failures are aggregated
        into ONE exception naming the failed workers (`__cause__` = the
        first).  Idempotent: a retry after a partial failure re-closes
        only what is still open (worker close is itself idempotent)."""
        self._close_batcher()
        errs: List[tuple] = []
        for w, worker in enumerate(self.workers):
            try:
                worker.close()
            except BaseException as e:
                errs.append((w, e))
        if errs:
            names = ", ".join(f"worker_{w}" for w, _ in errs)
            err = RuntimeError(
                f"cluster close failed on {len(errs)} worker(s) [{names}]: "
                + "; ".join(f"worker_{w}: {e!r}" for w, e in errs))
            raise err from errs[0][1]

    def recover(self) -> int:
        """Recover every live worker from its durability directory
        (snapshot + WAL replay, bit-identical per worker) and rebuild the
        merged view.  Workers marked dead in ``cluster.json`` (their WAL
        tails were re-partitioned to the survivors in a previous run) are
        skipped — their salvaged data replays from the survivors' logs.
        A salvage a previous coordinator crash left unfinished is resumed
        from its checkpointed prefix (`_resume_salvage`).  Returns the
        total number of WAL records replayed."""
        n = sum(self.workers[w].recover() for w in range(len(self.workers))
                if w not in self._dead)
        self._resume_salvage()
        self._refresh()
        return n

    def _resume_salvage(self) -> None:
        """Finish any re-partition a previous coordinator crash left
        incomplete: a worker that is DEAD but not salvage-complete still
        has replayable WAL records the survivors never received.  The
        resumed salvage skips everything up to the checkpointed progress
        seq (already durable in the survivors' logs), so at most one
        in-flight hand-off batch is re-ingested.  A worker whose log
        genuinely cannot reach back to seq 0 (compacted prefix) re-checks
        as incomplete without re-ingesting anything."""
        fo = self._failover
        if fo is None or not fo.repartition or self._meta_path is None:
            return
        with self._flock:
            for w in sorted(self._dead - self._salvaged):
                try:
                    complete = self._salvage(w)
                except BaseException:
                    complete = False     # still partial: DEAD, resumable
                if complete:
                    self._salvaged.add(w)
                    self._salvage_progress.pop(w, None)
                self._persist_meta()

    # --- failover (DESIGN.md §14) -------------------------------------------

    def _partition(self, xs: np.ndarray) -> np.ndarray:
        """Dead-aware content-hash routing: owner = hash % N as ever; rows
        owned by a dead worker re-route to a live worker picked by an
        independent slice of the same hash — a pure function of (row
        bytes, dead set), so re-routing is identical across retries,
        processes and salvage replays.  The dead set is pinned (with its
        partition epoch) in ``cluster.json``."""
        n = len(self.workers)
        if n == 1:
            if 0 in self._dead:
                raise ClusterDegradedError("no live workers", dead=[0],
                                           salvaged=self._salvaged)
            return np.zeros(len(xs), np.int64)
        h = _mix_u64(xs)
        pid = (h % np.uint64(n)).astype(np.int64)
        if self._dead:
            live = np.array([w for w in range(n) if w not in self._dead],
                            np.int64)
            if live.size == 0:
                raise ClusterDegradedError(
                    "no live workers", dead=sorted(self._dead),
                    salvaged=self._salvaged)
            mask = np.isin(pid, np.fromiter(self._dead, np.int64))
            if mask.any():
                pid[mask] = live[(h[mask] // np.uint64(n))
                                 % np.uint64(live.size)]
        return pid

    def _with_retries(self, w: Optional[int], fn: Callable):
        """Run a worker/coordinator op, retrying *transient* faults
        (`faults.is_transient`) in place with exponential backoff; the
        worker is DEGRADED while retrying and LIVE again on success.
        Non-transient failures (and exhausted budgets) propagate to the
        caller's failure handling.  Safe only for ops whose failure
        rejects the attempted work (WAL appends, merges) — never for a
        failed flush, whose chunk was already accepted."""
        fo = self._failover
        if fo is None:
            return fn()
        delay = fo.backoff_s
        for attempt in range(fo.max_retries + 1):
            try:
                out = fn()
                if w is not None and self._health[w] == "degraded":
                    self._health[w] = "live"
                return out
            except BaseException as e:
                if not faults.is_transient(e) or attempt == fo.max_retries:
                    raise
                if w is not None:
                    self._health[w] = "degraded"
                self._counters["retries"] += 1
                time.sleep(delay)
                delay *= 2

    def _mutate_live(self, w: int, fn: Callable) -> None:
        """Apply a mutation op to live worker ``w`` under failover:
        transient faults retry in place; a hard failure recovers (or
        kills) the worker.  The op is resubmitted after a recovery only
        when it was *rejected* (never WAL-logged): the engine marks the
        raised exception with ``wal_accepted=True`` iff THIS op's record
        hit the log before the failure (`_durable_mutate`) — the worker's
        poison *reason* is never consulted, because it can describe an
        earlier op (e.g. a background commit failure) and would then
        silently drop a rejected mutation.  An *accepted* op already
        replayed from the log, and resubmitting would double-apply it
        (RACE decrements are not idempotent)."""
        try:
            self._with_retries(w, fn)
        except BaseException as e:
            if self._failover is None:
                raise
            accepted = bool(getattr(e, "wal_accepted", False))
            if self._handle_worker_failure(w, e) and not accepted:
                fn()

    def _handle_worker_failure(self, w: int, exc: BaseException) -> bool:
        """Fail over worker ``w``: rebuild a fresh engine on its durability
        directory and `recover()` (bit-identical: snapshot + accepted WAL
        tail) with retries; if unrecoverable, declare it DEAD — salvaging
        its replayable WAL tail into the survivors first (`_declare_dead`).
        Returns True when the worker is LIVE again, False when DEAD."""
        fo = self._failover
        with self._flock:
            if w in self._dead:
                return False
            self._health[w] = "degraded"
            old = self.workers[w]
            durable = old._dur is not None
            try:
                old.close()
            except BaseException:
                pass                     # the old engine is being replaced
            delay = fo.backoff_s
            if durable:
                for attempt in range(max(fo.max_retries, 1)):
                    fresh = None
                    try:
                        fresh = self._make_worker(w)
                        fresh.recover()
                        self.workers[w] = fresh
                        self._health[w] = "live"
                        self._counters["recoveries"] += 1
                        return True
                    except BaseException:
                        if fresh is not None:
                            try:
                                fresh.close()
                            except BaseException:
                                pass
                        time.sleep(delay)
                        delay *= 2
            self._declare_dead(w, exc)
            return False

    def _declare_dead(self, w: int, exc: BaseException) -> None:
        """Mark worker ``w`` DEAD under a new partition epoch, then
        re-partition its replayable WAL tail to the survivors (the dead
        set must be in place first so the salvage re-ingest routes around
        ``w``), and pin the outcome in ``cluster.json``.

        Crash-safety (§14): the dead set + epoch persist *before* salvage
        starts (routing stays dead-aware across a coordinator restart),
        and salvage checkpoints its progress — the last seq durably
        handed to the survivors — into ``cluster.json`` after every
        hand-off.  A coordinator crash mid-salvage therefore resumes
        (`recover()` → `_resume_salvage`) from the checkpointed prefix:
        at-least-once only within the single in-flight hand-off batch,
        never a full-WAL replay, never silent loss."""
        self._health[w] = "dead"
        self._dead.add(w)
        self._epoch += 1
        self._persist_meta()
        complete = False
        if self._failover.repartition and self._meta_path is not None:
            try:
                complete = self._salvage(w)
            except BaseException:
                complete = False         # partial salvage: DEAD, lossy
        if complete:
            self._salvaged.add(w)
            self._salvage_progress.pop(w, None)
        self._persist_meta()

    def _salvage(self, w: int) -> bool:
        """Stream the dead worker's readable WAL records back through the
        cluster's own ingest/delete path (content-hash re-route to the
        survivors).  Exactness per sketch is the merge-algebra argument of
        DESIGN §14: RACE counters add, SW-AKDE buckets union, S-ANN keep
        decisions are per-point functions of (bytes, salt) — so replayed
        rows land exactly as if originally routed there.

        Resumable: records with seq <= the checkpointed salvage progress
        for ``w`` were already durably handed to the survivors (their own
        WALs logged them before the hand-off returned) and are skipped;
        progress re-checkpoints into ``cluster.json`` after every
        hand-off, so a coordinator crash mid-salvage re-ingests at most
        one in-flight batch on resume, not the whole log.  Returns True
        when the *whole* history was replayable (records from seq 0:
        nothing was compacted behind an unloadable snapshot)."""
        wdir = pathlib.Path(self._meta_path.parent) / f"worker_{w}"
        wal = persist.WriteAheadLog(wdir / "wal")
        done = self._salvage_progress.get(w, -1)
        first_seq: Optional[int] = None
        last_seq = done
        nrec = nrows = 0
        buf: List[np.ndarray] = []

        def _checkpoint() -> None:
            # Everything handed off so far is durable on the survivors
            # (ingest_async WAL-logs at enqueue time; deletes log inside
            # _durable_mutate before returning), so last_seq is safe to
            # skip on a post-crash resume.
            self._salvage_progress[w] = last_seq
            self._persist_meta()
            # Coordinator-death stand-in (DESIGN §14): a crash injected
            # here leaves a checkpointed prefix for recover() to resume.
            faults.fire("cluster.salvage")

        def _drain():
            if buf:
                self.ingest_async(np.concatenate(buf))
                buf.clear()
                _checkpoint()

        it = wal.iter_replay()
        try:
            for rec in it:
                if first_seq is None:
                    first_seq = rec.seq
                if rec.seq <= done:
                    continue             # salvaged before a prior crash
                nrec += 1
                if rec.kind == persist.KIND_CHUNK:
                    rows = np.asarray(rec.arrays["xs"], np.float32)
                    nrows += rows.shape[0]
                    buf.append(rows)
                    last_seq = rec.seq
                    if sum(b.shape[0] for b in buf) >= 4096:
                        _drain()
                else:
                    # Order matters: mutations apply after every chunk
                    # logged before them, exactly as the worker would
                    # have replayed.
                    _drain()
                    self.flush()
                    self._salvage_delete(rec.kind, rec.arrays)
                    last_seq = rec.seq
                    _checkpoint()
            _drain()
        finally:
            # Close the (possibly suspended) generator *before* closing
            # the WAL: iter_replay holds the non-reentrant WAL lock across
            # yields, so on a GC-based interpreter — or whenever the loop
            # body raises while the generator stays referenced —
            # wal.close() would otherwise deadlock on that lock while this
            # thread holds _flock, freezing queries and failure handling.
            it.close()
            wal.close()
        if nrec:
            self._counters["repartitions"] += 1
            self._counters["salvaged_records"] += nrec
            self._counters["salvaged_rows"] += nrows
        # Complete iff the log still reaches back to the first op (no
        # snapshot-covered prefix was compacted away — resume skips
        # records but still *observes* the log's true first seq), or
        # nothing was ever written.
        return (first_seq == 0
                or (first_seq is None and done < 0
                    and persist.snapshot.latest_seq(str(wdir)) is None))

    def _salvage_delete(self, kind: int, arrays: dict) -> None:
        """Re-apply a dead worker's logged mutation through the cluster
        API (subclasses with mutation kinds override)."""
        raise NotImplementedError(
            f"cannot re-partition WAL record kind {kind}")

    def _ensure_live(self) -> None:
        """Query-path health gate (failover mode only): recover any
        poisoned worker in place, then apply the ``on_degraded`` policy
        while workers are DEAD.  ``block`` waits for the cluster's data to
        be *whole* — every dead worker fully re-partitioned — not for the
        workers themselves (death is permanent within an epoch)."""
        fo = self._failover
        if fo is None:
            return
        deadline = time.monotonic() + fo.block_deadline_s
        while True:
            with self._flock:
                for w in range(len(self.workers)):
                    if w not in self._dead and self.workers[w]._poisoned:
                        self._handle_worker_failure(
                            w, RuntimeError(self.workers[w]._poison_reason
                                            or "poisoned"))
                if not self._dead or fo.on_degraded == "partial":
                    return
                whole = self._dead <= self._salvaged
                if whole and fo.on_degraded == "block":
                    return
                if fo.on_degraded == "fail" or time.monotonic() >= deadline:
                    raise ClusterDegradedError(
                        f"cluster degraded: workers {sorted(self._dead)} "
                        f"dead ({'fully' if whole else 'not fully'} "
                        "re-partitioned); on_degraded="
                        f"{fo.on_degraded!r}", dead=self._dead,
                        salvaged=self._salvaged)
            # Sleep outside _flock: another thread's failure handling (and
            # its salvage re-ingest) must be able to make progress while a
            # blocked query waits for the data to be whole.
            time.sleep(min(0.05, fo.block_deadline_s / 10 or 0.05))

    def _persist_meta(self) -> None:
        # Atomic replace: salvage checkpoints rewrite this file once per
        # hand-off, and a crash mid-write must never leave a torn
        # cluster.json behind (the next open json-parses it).
        if self._meta_path is None:
            return
        tmp = self._meta_path.with_name(self._meta_path.name + ".tmp")
        tmp.write_text(json.dumps(
            {"num_workers": len(self.workers),
             "dead_workers": sorted(self._dead),
             "salvage_complete": sorted(self._salvaged),
             "salvage_progress": {str(w): s for w, s in
                                  sorted(self._salvage_progress.items())},
             "epoch": self._epoch}))
        tmp.replace(self._meta_path)

    # --- observability ------------------------------------------------------

    @property
    def coverage(self) -> float:
        """Fraction of workers serving queries (< 1 while any is DEAD —
        even after a complete re-partition, which restores the *data* but
        not the worker)."""
        return 1.0 - len(self._dead) / len(self.workers)

    def health(self) -> dict:
        """Coordinator + per-worker health (DESIGN §14): health states,
        dead set + partition epoch, failover counters, and each live
        engine's own `health()` (poison reason, committed seq, queue
        depth)."""
        fo = self._failover
        return {"workers": [
                    {"worker": w, "health": self._health[w],
                     **self.workers[w].health()}
                    for w in range(len(self.workers))],
                "dead_workers": sorted(self._dead),
                "salvage_complete": sorted(self._salvaged),
                "salvage_progress": dict(sorted(
                    self._salvage_progress.items())),
                "epoch": self._epoch,
                "coverage": self.coverage,
                "counters": dict(self._counters),
                "on_degraded": None if fo is None else fo.on_degraded}

    def stats(self) -> dict:
        """`health()` plus the coordinator's query-scheduler counters."""
        out = self.health()
        if self._batcher is not None:
            out["batcher"] = self._batcher.stats()
        return out

    # --- merged view ---------------------------------------------------------

    @property
    def versions(self) -> tuple:
        """Per-worker commit versions (the merge-cadence clock); a DEAD
        worker holds the sentinel ``-1`` (its commits now live in the
        survivors' logs via re-partition)."""
        return tuple(-1 if w in self._dead else self.workers[w].version
                     for w in range(len(self.workers)))

    @property
    def version(self) -> int:
        """Summed live-worker commit count."""
        return sum(v for v in self.versions if v >= 0)

    def _maybe_merge(self) -> None:
        if self.version - self._last_merge_total >= self._merge_every:
            self._refresh()

    def _refresh(self):
        """Fold the live workers' current committed snapshots into the
        merged cache (no-op when the cache already matches).  Returns the
        consistent ``(state, meta, versions)`` triple.  The cache clock is
        ``(versions, epoch)``: the partition epoch bumps on every worker
        death, so a merge that predates a death can never be mistaken for
        fresh (the live sum *drops* when a worker dies — the old
        sum-ordered install guard alone would wedge the cache)."""
        epoch = self._epoch
        live = [w for w in range(len(self.workers)) if w not in self._dead]
        if not live:
            raise ClusterDegradedError("no live workers",
                                       dead=sorted(self._dead),
                                       salvaged=self._salvaged)
        snaps = {w: self.workers[w].snapshot() for w in live}
        states = [snaps[w][0] for w in live]
        vers = tuple(-1 if w in self._dead else snaps[w][1]
                     for w in range(len(self.workers)))
        with self._mlock:
            if self._merged_versions == vers and self._merged_epoch == epoch:
                return self._merged, self._merged_meta, vers
            self._with_retries(None, lambda: faults.fire("cluster.merge"))
            merged = self._combine(states, live)
            meta = dict(self._meta(states) or {})
            meta.update(workers_live=len(live),
                        workers_total=len(self.workers),
                        worker_coverage=len(live) / len(self.workers))
            vsum = sum(v for v in vers if v >= 0)
            if (self._merged_versions is None
                    or epoch > self._merged_epoch
                    or (epoch == self._merged_epoch
                        and sum(v for v in self._merged_versions
                                if v >= 0) <= vsum)):
                # Install only if not older than the cache: a racing
                # _refresh whose snapshots were taken later may already
                # have installed a newer merge (live worker versions are
                # monotone within an epoch, so the live sum orders
                # snapshots; across epochs the epoch orders them).
                self._merged = merged
                self._merged_versions = vers
                self._merged_meta = meta
                self._merged_epoch = epoch
                self._last_merge_total = vsum
            return merged, meta, vers

    def merged_snapshot(self):
        """``(state, meta, versions)`` of one consistent merge covering
        every live worker commit: the cached merge when fresh, else a
        query-time merge of the unmerged tails.  Numerator and any
        normalising scalars of one answer must come from a single call —
        state and meta are written together under the merge lock.

        With failover configured this is also the degraded-policy gate:
        poisoned workers are recovered in place first, then the
        ``on_degraded`` policy decides whether a cluster with DEAD workers
        fails, blocks, or serves the live subset (`_ensure_live`)."""
        self._ensure_live()
        epoch = self._epoch
        vers = self.versions
        with self._mlock:
            if self._merged_versions == vers and self._merged_epoch == epoch:
                return self._merged, self._merged_meta, vers
        return self._refresh()

    def merged_state(self):
        """The merged sketch alone (see `merged_snapshot`)."""
        return self.merged_snapshot()[0]

    def _combine(self, states, live):
        """Subclass hook: fold the live workers' snapshot states into one
        merged state (called under ``_mlock`` from `_refresh`).  Default:
        the full ``merge_states`` fold, with the single-worker
        short-circuit.  Overrides must return a result bit-identical to
        the full fold (`ClusterRACEService._combine` folds only counter
        deltas)."""
        if len(states) == 1:
            return states[0]
        return jax.block_until_ready(self._merge_fn(states))

    def _meta(self, states) -> Optional[dict]:
        """Subclass hook: scalars to capture alongside a merge (same
        snapshot the merged state came from)."""
        return None

    def _query_state(self, st, queries):
        """Run worker 0's jitted query function over ``st`` in its
        ``query_block`` blocks — the shared read path of every subclass's
        query API (worker params are identical, so any worker's query fn
        serves the merged sketch)."""
        qs = jnp.asarray(queries, jnp.float32)
        w0 = self._ref
        return w0._query_blocks(lambda b: w0._query_fn(st, b), qs)

    def _query_snapshot_ctx(self):
        """One consistent merged ``(state, meta, versions)`` triple serving
        a whole query tick — a stale merge cache costs ONE tail merge per
        coalesced batch, not one per client (subclasses with extra
        per-merge caches extend this)."""
        return self.merged_snapshot()

    def _batch_query_block(self) -> int:
        return self._ref._query_block

    @property
    def sketch_bytes(self) -> int:
        """Total sketch footprint across the workers (N replicas of the
        same allocation)."""
        return sum(w.sketch_bytes for w in self.workers)


# ---------------------------------------------------------------------------
# Sketch-specific clusters
# ---------------------------------------------------------------------------

def _worker_cfg(cfg, w: int, **extra):
    """Per-worker config: same seed (identical params), per-worker
    durability subdirectory and fault-injection scope (so a `FaultPlan`
    can target ``worker_<w>/<site>`` deterministically), plus
    sketch-specific fields via ``extra``."""
    sub = (None if getattr(cfg, "snapshot_dir", None) is None
           else f"{cfg.snapshot_dir}/worker_{w}")
    return dataclasses.replace(cfg, snapshot_dir=sub,
                               fault_scope=f"worker_{w}/", **extra)


class ClusterRetrievalService(ClusterService):
    """N-worker S-ANN cluster: hash-partitioned ingest, `sann_merge`-based
    coordinator, single-service query API (`query`, `delete`)."""

    def __init__(self, cfg: RetrievalConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 make_worker: Optional[Callable] = None):
        def make(w: int) -> RetrievalService:
            # Same seed → identical LSH params (merge precondition); the
            # salt decorrelates the workers' Bernoulli keep decisions.
            # Workers never run their own query batcher — the coordinator
            # coalesces and reads them via their jitted query fns.
            return RetrievalService(
                _worker_cfg(cfg, w, ingest_salt=w, batch_queries=False))

        super().__init__(
            make_worker or make, num_workers, merge_every,
            lambda states: functools.reduce(
                lambda a, b: ss.sharded_sann_merge(
                    a, b, self._ref.params, self._ref.cfg,
                    self._ref._ctx),
                states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us,
            failover=failover)

    _default_query_kind = "cr"

    def _query_kind_fns(self):
        def cr(ctx, qs):
            return self._query_state(ctx[0], qs)

        def topk(ctx, qs):
            w0 = self._ref
            return w0._query_blocks(lambda b: w0._topk_fn(ctx[0], b), qs)

        return {"cr": cr, "topk": topk}

    def query(self, queries: np.ndarray) -> sann.SANNResult:
        """Batched (c, r)-queries against the merged sketch, in the worker
        engine's ``query_block`` blocks (coalesced with concurrent clients
        when ``batch_queries`` — one merged snapshot per tick)."""
        return self._serve_query("cr", queries)

    def query_topk(self, queries: np.ndarray):
        """Batched top-k queries against the merged sketch (same snapshot
        and micro-batching semantics as `query`)."""
        return self._serve_query("topk", queries)

    def delete(self, embedding: np.ndarray) -> None:
        """Turnstile delete-by-value, broadcast to every worker.

        `sann_delete` tombstones every stored point within ``tol`` of the
        value, so a near-copy with different float bits can live on *any*
        worker (hash ownership is per bit pattern) — routing to the exact
        owner alone would miss it.  Broadcasting reproduces single-engine
        semantics exactly; workers without a match apply a no-op.  Under
        failover the broadcast covers the live workers (a dead worker's
        surviving points were re-partitioned onto them)."""
        x = np.asarray(embedding, np.float32)
        for w in range(len(self.workers)):
            if w not in self._dead:
                self._mutate_live(w, lambda w=w: self.workers[w].delete(x))

    def _salvage_delete(self, kind: int, arrays: dict) -> None:
        if kind != persist.KIND_DELETE:
            return super()._salvage_delete(kind, arrays)
        self.delete(arrays["x"])

    @property
    def stored(self) -> int:
        """Live stored points in the merged sketch (post union-eviction)."""
        return int(self.merged_state().n_stored)


class ClusterKDEService(ClusterService):
    """N-worker SW-AKDE cluster: hash-partitioned ingest, EH bucket-union
    coordinator.  Worker windows tick per local point — configure
    ``window`` as the per-worker span (≈ global window / K for a balanced
    partition); estimates are bit-identical to one engine until window
    expiry, estimate-level after (DESIGN.md §11.5).

    ``global_clock=True`` switches the windows to *stream* time: the
    coordinator keeps a logical clock of total points submitted and, after
    every ingest call, folds it into each live worker
    (`KDEService.advance_clock` — max-monotone, WAL-logged).  Configure
    ``window`` as the full global span; expiry then happens at the
    coordinator's ingest-call granularity (points inside one call still
    tick worker-locally), so per-call streams match a single global-window
    engine exactly (tests/test_cluster.py)."""

    def __init__(self, cfg: KDEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 global_clock: bool = False,
                 make_worker: Optional[Callable] = None):
        super().__init__(
            make_worker or (lambda w: KDEService(
                _worker_cfg(cfg, w, batch_queries=False))),
            num_workers, merge_every,
            lambda states: functools.reduce(
                lambda a, b: swakde.swakde_merge(
                    a, b, self._ref.sketch_cfg),
                states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us,
            failover=failover)
        self.cfg = cfg
        self.global_clock = bool(global_clock)
        self._global_steps = 0
        self._clock_local = threading.local()   # re-entrancy guard
        # cache_grid over the merged sketch: the (L, W) grid-estimate table
        # is pure given the merged state, so it is cached per merged
        # versions tuple (same invalidation clock as the merge cache).
        self._grid = None
        self._grid_versions: Optional[tuple] = None

    def _meta(self, states):
        if self.global_clock:
            # All live clocks were folded to the coordinator's logical
            # clock after the last ingest, so the workers share ONE stream
            # clock (= the max over this snapshot set) and their window
            # coverages overlap instead of summing.
            t = max((int(s.t) for s in states), default=0)
            return {"coverage": min(t, self.cfg.window)}
        # Captured from the *same* snapshots the merged state came from:
        # the density denominator is the number of points the merged grid
        # can still see — each worker contributes its last
        # min(t_w, window) steps (worker windows tick on local clocks), so
        # the coverages sum; summing raw clocks would overestimate density
        # by up to K once the windows saturate.
        return {"coverage": int(sum(min(int(s.t), self.cfg.window)
                                    for s in states))}

    # --- global-clock plumbing ---------------------------------------------

    def ingest_async(self, data) -> None:
        if not self.global_clock:
            return super().ingest_async(data)
        xs = np.asarray(data, np.float32)
        # Failover hand-offs re-enter ingest_async with rows that were
        # already counted (a dead worker's unsubmitted tail, a salvage
        # batch replayed mid-call): only the outermost call advances the
        # logical clock, and only by its own row count.
        outer = not getattr(self._clock_local, "active", False)
        if outer:
            self._clock_local.active = True
            self._global_steps += int(xs.shape[0])
        try:
            super().ingest_async(xs)
        finally:
            if outer:
                self._clock_local.active = False
        if outer:
            self._advance_clocks(self._global_steps)

    def _advance_clocks(self, target: int) -> None:
        """Fold the coordinator clock into every live worker.  The advance
        is max-monotone and WAL-logged per worker (``KIND_CLOCK``), so
        retries, failover recoveries and salvage replays are idempotent."""
        for w in range(len(self.workers)):
            if w in self._dead:
                continue
            try:
                self._with_retries(
                    w, lambda w=w: self.workers[w].advance_clock(target))
            except BaseException as e:
                if self._failover is None:
                    raise
                self._handle_worker_failure(w, e)
        self._maybe_merge()

    def recover(self) -> int:
        n = super().recover()
        if self.global_clock:
            # Every live worker replayed its clock advances; the newest
            # one IS the coordinator clock at the last durable ingest.
            self._global_steps = max(
                (self.workers[w].steps for w in range(len(self.workers))
                 if w not in self._dead), default=0)
        return n

    def _salvage(self, w: int) -> bool:
        if not self.global_clock:
            return super()._salvage(w)
        # Salvaged rows replay a dead worker's log — the coordinator clock
        # counted them when they were first submitted, so the re-ingest
        # must not advance it again.
        outer = not getattr(self._clock_local, "active", False)
        if outer:
            self._clock_local.active = True
        try:
            return super()._salvage(w)
        finally:
            if outer:
                self._clock_local.active = False

    def _salvage_delete(self, kind: int, arrays: dict) -> None:
        if kind != persist.KIND_CLOCK:
            return super()._salvage_delete(kind, arrays)
        # A dead worker's logged clock advance: every survivor received
        # the same coordinator advance already, so re-folding it is a
        # max-monotone no-op — applied anyway for the resume case where a
        # survivor recovered from an older snapshot.
        t = int(np.asarray(arrays["t"]))
        self._advance_clocks(t)

    def _merged_grid(self, st, vers):
        """The (L, W) grid-estimate table of merged state ``st`` (computed
        at most once per merged versions tuple; concurrent same-version
        computes are benign, last install wins)."""
        with self._mlock:
            if self._grid_versions == vers:
                return self._grid
        grid = jax.block_until_ready(self._ref._grid_fn(st))
        with self._mlock:
            self._grid, self._grid_versions = grid, vers
        return grid

    def _estimates(self, st, vers, queries) -> np.ndarray:
        """Batched Ŷ against one merged snapshot — from the per-merge grid
        cache when ``cache_grid`` is on (bit-identical either way), else
        the fused engine."""
        qs = jnp.asarray(queries, jnp.float32)
        if self.cfg.cache_grid:
            grid = self._merged_grid(st, vers)
            w0 = self._ref
            return np.asarray(w0._query_blocks(
                lambda b: w0._grid_query_fn(grid, b), qs))
        return np.asarray(self._query_state(st, qs))

    _default_query_kind = "kde"

    def _query_kind_fns(self):
        def kde(ctx, qs):
            st, _, vers = ctx
            return self._estimates(st, vers, qs)

        def density(ctx, qs):
            # coverage and estimates from the *same* merged snapshot; the
            # batch-wide scalar divide keeps coalescing bit-identical.
            st, meta, vers = ctx
            return (self._estimates(st, vers, qs)
                    / max((meta or {}).get("coverage", 0), 1))

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised window-density estimates Ŷ against the
        merged grid (coalesced with concurrent clients when
        ``batch_queries`` — one merged snapshot + one grid per tick)."""
        return self._serve_query("kde", queries)

    def density(self, queries: np.ndarray) -> np.ndarray:
        """Normalised density: Ŷ / (summed per-worker window coverage) —
        the coverage and the estimates come from the *same* merged
        snapshot (micro-batched like `query`)."""
        return self._serve_query("density", queries)

    @property
    def steps(self) -> int:
        """Stream steps consumed across the live workers (a dead worker's
        salvaged steps were re-ingested by the survivors).  Under
        ``global_clock`` every live clock equals the coordinator's, so the
        stream length is their max, not their sum."""
        live = [self.workers[w].steps for w in range(len(self.workers))
                if w not in self._dead]
        if self.global_clock:
            return max(live, default=0)
        return sum(live)


class ClusterRACEService(ClusterService):
    """N-worker RACE cluster: hash-partitioned ingest, exact counter-sum
    coordinator — cluster estimates are bit-identical to a single engine
    over the whole stream (tests/test_cluster.py)."""

    def __init__(self, cfg: RACEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 make_worker: Optional[Callable] = None):
        super().__init__(
            make_worker or (lambda w: RACEService(
                _worker_cfg(cfg, w, batch_queries=False))),
            num_workers, merge_every,
            lambda states: functools.reduce(race.race_merge, states),
            snapshot_dir=cfg.snapshot_dir,
            batch_queries=cfg.batch_queries,
            max_batch=cfg.max_batch, max_wait_us=cfg.max_wait_us,
            failover=failover)
        self.cfg = cfg
        # Delta-merge base (under _mlock): the previous merged counters
        # plus each live worker's counters at that merge, keyed by
        # (live set, partition epoch) so any death/re-partition falls
        # back to a full fold.
        self._delta_base = None
        self._delta_fn = jax.jit(self._delta_merge)
        self._counters["delta_merges"] = 0
        self._counters["full_merges"] = 0

    @staticmethod
    def _delta_merge(prev_merged_counts, prev_counts, states):
        """``prev_merged + Σ_w (counts_now_w - counts_then_w)``.

        int32 addition is associative/commutative (wrapping included), so
        this equals the full ``reduce(race_merge, states)`` counter fold
        bit-exactly while moving only the *delta* arithmetic; ``n``
        saturates, so it is re-folded from the current scalars directly
        (O(workers) scalar work)."""
        counts = prev_merged_counts
        for prev, st in zip(prev_counts, states):
            counts = counts + (st.counts - prev)
        n = functools.reduce(race.saturating_add, [st.n for st in states])
        return race.RACEState(counts=counts, n=n)

    def _combine(self, states, live):
        """Incremental coordinator fold (carried-forward PR 5 item): after
        the first full merge, each refresh folds only the counter delta
        each worker accumulated since the last merge.  Falls back to the
        full fold on the first merge, a live-set change, or a partition-
        epoch bump (death/re-partition invalidates the base).  Pinned
        bit-exact against the full fold in tests/test_cluster.py."""
        if len(states) == 1:
            self._delta_base = None
            return states[0]
        key = (tuple(live), self._epoch)
        base = self._delta_base
        if base is not None and base[0] == key:
            merged = jax.block_until_ready(
                self._delta_fn(base[1], base[2], states))
            self._counters["delta_merges"] += 1
        else:
            merged = jax.block_until_ready(self._merge_fn(states))
            self._counters["full_merges"] += 1
        self._delta_base = (key, merged.counts,
                            [st.counts for st in states])
        return merged

    _default_query_kind = "kde"

    def _query_kind_fns(self):
        def kde(ctx, qs):
            return np.asarray(self._query_state(ctx[0], qs))

        def density(ctx, qs):
            # counters and n from the *same* merged snapshot
            st = ctx[0]
            return kde(ctx, qs) / max(float(np.asarray(st.n)), 1.0)

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised KDE estimates against the merged counters
        (coalesced with concurrent clients when ``batch_queries`` — one
        merged snapshot, and at most one tail merge, per tick)."""
        return self._serve_query("kde", queries)

    def kde(self, queries: np.ndarray) -> np.ndarray:
        """Normalised density — counters and ``n`` from the *same* merged
        snapshot (micro-batched like `query`)."""
        return self._serve_query("density", queries)

    def delete(self, embeddings: np.ndarray) -> None:
        """Turnstile decrements, routed to each row's hash owner (dead
        owners re-route to the survivors exactly like ingest — the
        decrement must land where the original increment did or will,
        which the shared dead-aware hash guarantees)."""
        xs = np.atleast_2d(np.asarray(embeddings, np.float32))
        pid = self._partition(xs)
        for w in range(len(self.workers)):
            rows = xs[pid == w]
            if rows.shape[0]:
                self._mutate_live(w, lambda w=w, r=rows:
                                  self.workers[w].delete(r))

    def _salvage_delete(self, kind: int, arrays: dict) -> None:
        if kind != persist.KIND_DELETE:
            return super()._salvage_delete(kind, arrays)
        self.delete(arrays["xs"])

    @property
    def count(self) -> int:
        """Signed stream size across the live workers (a dead worker's
        salvaged rows were re-ingested by the survivors)."""
        return sum(self.workers[w].count for w in range(len(self.workers))
                   if w not in self._dead)
