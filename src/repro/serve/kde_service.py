"""SW-AKDE density service: streaming sliding-window KDE with pipelined
ingest and batched queries (paper §4).

The serving-side integration of the paper's second sketch, mirroring
`repro.serve.retrieval.RetrievalService`: points arrive as a stream of
embeddings, the service maintains the sliding-window EH grid, and answers
batched density queries — e.g. drift monitoring over a decode-time
activation stream, or novelty scoring of incoming requests.

Runtime: the service is a `repro.serve.engine.SketchEngine` — the shared
streaming runtime owns the lock, the chunk loop, the two-phase pipelined
ingest (`core.swakde.swakde_prepare_chunk` hashing + sorting chunk k+1 on
the prepare thread while `swakde_commit_chunk` replays chunk k into the EH
grid) and the background queue (``ingest_async`` / ``flush``).

Query-side snapshot cache: the (L, W) grid-estimate table
(`core.swakde.swakde_grid_estimates`) is pure given the committed state, so
the service caches it per commit version (``cache_grid=True``) and serves
*every* query batch — including B < W — as one hash matmul + one table
gather, bit-identical to the uncached fused path.  Any commit invalidates
the cache (tests/test_engine.py pins this).

Multi-device: set ``num_shards`` (or pass a ``mesh``) to split the L
sketch rows across devices via `repro.parallel.sketch_sharding` — both
ingest phases run per row shard and queries all-gather the per-row
estimates; results stay bit-identical to the single-device service.
``mesh=None, num_shards<=1`` (the default) keeps the single-device path
untouched.

This is a thin, stateful orchestration layer over repro.core.swakde; all
math lives there (and is what the paper's Theorem 4.1 guarantee covers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.core import lsh, swakde
from repro.parallel import sketch_sharding as ss
from repro.serve.engine import SketchEngine, durability_from


@dataclasses.dataclass
class KDEServiceConfig:
    dim: int
    L: int = 16              # sketch rows (repetitions)
    W: int = 128             # LSH range after rehash
    window: int = 10_000     # sliding-window length N (stream steps)
    eh_eps: float = 0.1      # per-cell EH relative error eps'
    hash_family: str = "srp"  # "srp" (angular) | "pstable" (Euclidean)
    k: int = 2               # concatenation power p
    w: float = 4.0           # p-stable bucket width (pstable only)
    seed: int = 0
    # Batched-ingest chunk: one prepare/commit pair per chunk; each distinct
    # partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Two-phase pipelining: prepare chunk k+1 on the engine's prepare thread
    # while chunk k commits.  False = strictly sequential phases (identical
    # results; the ingest-benchmark baseline).
    pipelined: bool = True
    # Prepare lookahead depth: chunks the prepare pool may run ahead of the
    # commit side (1 = classic double buffering).  Results are
    # bit-identical at any depth; deeper lookahead helps once commits are
    # cheap (the closed-form segment fold) and the producer is bursty.
    prepare_depth: int = 1
    # Skew guard (DESIGN.md §12): bound how many adds one (row, cell)
    # segment absorbs per commit pass; 0 = uncapped.  Bit-identical for
    # any value — a per-tile work bound, not an accuracy knob.
    heavy_cell_cap: int = 0
    # Query block: queries are answered in blocks of this many rows; each
    # distinct partial-block size triggers one extra jit trace.
    query_block: int = 1024
    # Snapshot cache: memoise the (L, W) grid-estimate table per committed
    # state (invalidated on every commit) and serve all query batches from
    # it — one hash matmul + one gather per block, no EH arithmetic.
    # False = recompute through the fused engine every call (bit-identical
    # results either way).
    cache_grid: bool = True
    # Cross-request query micro-batching (DESIGN.md §13): coalesce
    # concurrent clients' queries into one fused batch per scheduler tick
    # (max ``max_batch`` rows, ``max_wait_us`` latency budget), sharing one
    # state snapshot and one grid-cache entry across the coalesced batch.
    # Bit-identical answers; ``submit_query`` works either way.
    batch_queries: bool = False
    max_batch: Optional[int] = None
    max_wait_us: float = 200.0
    # Multi-device sharding: num_shards > 1 splits the L rows across that
    # many local devices (L must divide evenly); ``mesh`` overrides with a
    # prebuilt 1-D ("shard",) mesh.  Both unset → single-device.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh
    # Admission control: bound on queued-but-uncommitted rows; ingest_async
    # blocks (backpressure) at the bound.  None = unbounded queue.
    max_pending: Optional[int] = None
    # Durability (repro.persist): WAL-logged chunks + background snapshots
    # under ``snapshot_dir``; ``recover()`` restores bit-identically.
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 64
    wal_fsync: bool = False
    # Fault-injection site-name prefix (repro.persist.faults,
    # DESIGN.md §14); the cluster sets ``worker_<w>/`` per worker.
    fault_scope: str = ""


class KDEService(SketchEngine):
    """Thread-safe streaming sliding-window KDE with pipelined ingest,
    batched queries and a per-commit grid snapshot cache (shared runtime:
    `repro.serve.engine.SketchEngine`)."""

    def __init__(self, cfg: KDEServiceConfig):
        self.cfg = cfg
        self.sketch_cfg = swakde.SWAKDEConfig(
            L=cfg.L, W=cfg.W, window=cfg.window, eh_eps=cfg.eh_eps,
            heavy_cell_cap=cfg.heavy_cell_cap)
        key = jax.random.PRNGKey(cfg.seed)
        if cfg.hash_family == "srp":
            self.params = lsh.init_srp(key, cfg.dim, L=cfg.L, k=cfg.k,
                                       n_buckets=cfg.W)
        elif cfg.hash_family == "pstable":
            self.params = lsh.init_pstable(key, cfg.dim, L=cfg.L, k=cfg.k,
                                           w=cfg.w, n_buckets=cfg.W)
        else:
            raise ValueError(cfg.hash_family)
        super().__init__(ingest_chunk=cfg.ingest_chunk,
                         query_block=cfg.query_block,
                         pipelined=cfg.pipelined,
                         prepare_depth=cfg.prepare_depth,
                         max_pending=cfg.max_pending,
                         durability=durability_from(cfg),
                         batch_queries=cfg.batch_queries,
                         max_batch=cfg.max_batch,
                         max_wait_us=cfg.max_wait_us,
                         fault_scope=cfg.fault_scope)
        self.state = swakde.swakde_init(self.sketch_cfg)

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_swakde(self.state, self.params,
                                                      self._ctx)
        self._prepare_fn = jax.jit(
            lambda xs: ss.sharded_swakde_prepare_chunk(
                self.params, xs, self.sketch_cfg, self._ctx))
        self._commit_fn = jax.jit(
            lambda st, prep: ss.sharded_swakde_commit_chunk(
                st, prep, self.sketch_cfg, self._ctx))
        self._query_fn = jax.jit(
            lambda st, qs: ss.sharded_swakde_query_batch(
                st, self.params, qs, self.sketch_cfg, self._ctx))
        self._grid_fn = jax.jit(
            lambda st: ss.sharded_swakde_grid_estimates(
                st, self.sketch_cfg, self._ctx))
        self._grid_query_fn = jax.jit(
            lambda grid, qs: ss.sharded_swakde_query_from_grid(
                grid, self.params, qs, self.sketch_cfg, self._ctx))

    # --- engine hooks (two-phase ingest) -----------------------------------

    def _prepare(self, chunk: jax.Array) -> swakde.SWAKDEPrep:
        return self._prepare_fn(chunk)

    def _commit(self, state: swakde.SWAKDEState, prep: swakde.SWAKDEPrep):
        return self._commit_fn(state, prep)

    def _place_state(self, state: swakde.SWAKDEState) -> swakde.SWAKDEState:
        if self._ctx.mesh is None:
            return state
        return ss.shard_swakde(state, self.params, self._ctx)[0]

    def _apply_wal_record(self, kind: int, arrays: dict) -> None:
        if kind == persist.KIND_CLOCK:
            t = int(np.asarray(arrays["t"]))
            self._mutate_state(
                lambda st: st._replace(t=jnp.maximum(st.t, jnp.int32(t))))
            return
        super()._apply_wal_record(kind, arrays)

    # --- serving API -------------------------------------------------------

    def advance_clock(self, target: int) -> None:
        """Advance the sliding-window clock to ``max(t, target)`` without
        ingesting points — expiring EH buckets exactly as if ``target - t``
        empty stream steps had passed.

        This is the coordinator-assigned *global clock* option for cluster
        SW-AKDE (`repro.serve.cluster.ClusterKDEService(global_clock=True)`,
        DESIGN.md §10): each worker's local clock counts only its own
        partition's arrivals, so windows expire in partition-local time;
        folding in the coordinator's logical clock after every ingest makes
        every worker expire in *stream* time instead.  Pending async chunks
        flush first; when durable the advance is WAL-logged
        (``KIND_CLOCK``) and replays bit-identically on ``recover()``."""
        t = int(target)
        self._durable_mutate(
            persist.KIND_CLOCK, {"t": np.asarray(t, np.int32)},
            lambda st: st._replace(t=jnp.maximum(st.t, jnp.int32(t))))

    @property
    def num_shards(self) -> int:
        """Devices the rows are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    # --- query kinds (micro-batching; engine._BatchedQueryMixin) -----------

    _default_query_kind = "kde"

    def _query_snapshot_ctx(self):
        """One lock-consistent ``(state, version, grid)`` serving a whole
        query tick: with ``cache_grid`` the per-version grid table is
        resolved here — computed at most once per commit and shared by
        every query of the coalesced batch (a commit bumps the version, so
        a stale grid can never be paired with a newer state)."""
        state, version = self.snapshot()
        grid = None
        if self.cfg.cache_grid:
            grid = self.cached("grid", version,
                               lambda: jax.block_until_ready(
                                   self._grid_fn(state)))
        return state, version, grid

    def _query_kind_fns(self):
        def kde(ctx, qs):
            state, _, grid = ctx
            if grid is not None:
                out = self._query_blocks(
                    lambda b: self._grid_query_fn(grid, b), qs)
            else:
                out = self._query_blocks(
                    lambda b: self._query_fn(state, b), qs)
            return np.asarray(out)

        def density(ctx, qs):
            # Ŷ and the window clock from the *same* snapshot; the batch-
            # wide scalar divide is elementwise, so per-row results equal
            # an unbatched density() call bit-for-bit.
            state = ctx[0]
            denom = max(min(int(state.t), self.cfg.window), 1)
            return kde(ctx, qs) / float(denom)

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised window-density estimates Ŷ (Thm 4.1) against
        one committed snapshot, in ``query_block`` blocks.  With
        ``batch_queries`` the call is coalesced with concurrent clients'
        queries into one fused batch sharing one grid-cache entry
        (bit-identical results)."""
        return self._serve_query("kde", queries)

    def density(self, queries: np.ndarray) -> np.ndarray:
        """Normalised sliding-window density: Ŷ / min(t, N) — the state and
        the clock come from the *same* snapshot (micro-batched like
        `query` when ``batch_queries`` is set)."""
        return self._serve_query("density", queries)

    @property
    def steps(self) -> int:
        """Stream steps consumed so far."""
        return int(self.state.t)

    @property
    def sketch_bytes(self) -> int:
        return swakde.swakde_bytes(self.sketch_cfg)
