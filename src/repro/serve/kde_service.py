"""SW-AKDE density service: streaming sliding-window KDE with batched
ingest and batched queries (paper §4).

The serving-side integration of the paper's second sketch, mirroring
`repro.serve.retrieval.RetrievalService`: points arrive as a stream of
embeddings, the service maintains the sliding-window EH grid via the
chunked batched-update path (`core.swakde.swakde_update_chunk` — one hash
matmul + one grid traversal per chunk), and answers batched density
queries — e.g. drift monitoring over a decode-time activation stream, or
novelty scoring of incoming requests.

Multi-device: set ``num_shards`` (or pass a ``mesh``) to split the L
sketch rows across devices via `repro.parallel.sketch_sharding` — each
device replays chunks into its row block of the EH grid and queries
all-gather the per-row estimates; results stay bit-identical to the
single-device service.  ``mesh=None, num_shards<=1`` (the default) keeps
today's single-device path untouched.

This is a thin, stateful orchestration layer over repro.core.swakde; all
math lives there (and is what the paper's Theorem 4.1 guarantee covers).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, swakde
from repro.parallel import sketch_sharding as ss


@dataclasses.dataclass
class KDEServiceConfig:
    dim: int
    L: int = 16              # sketch rows (repetitions)
    W: int = 128             # LSH range after rehash
    window: int = 10_000     # sliding-window length N (stream steps)
    eh_eps: float = 0.1      # per-cell EH relative error eps'
    hash_family: str = "srp"  # "srp" (angular) | "pstable" (Euclidean)
    k: int = 2               # concatenation power p
    w: float = 4.0           # p-stable bucket width (pstable only)
    seed: int = 0
    # Batched-ingest chunk: one swakde_update_chunk call per chunk; each
    # distinct partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Query block: queries run through the fused batch engine
    # (core.swakde.swakde_query_batch — one hash matmul + one row gather
    # per block, grid-precompute once block ≥ W) in blocks of this many
    # rows; each distinct partial-block size triggers one extra jit trace.
    query_block: int = 1024
    # Multi-device sharding: num_shards > 1 splits the L rows across that
    # many local devices (L must divide evenly); ``mesh`` overrides with a
    # prebuilt 1-D ("shard",) mesh.  Both unset → single-device.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh


class KDEService:
    """Thread-safe streaming sliding-window KDE with batched queries."""

    def __init__(self, cfg: KDEServiceConfig):
        self.cfg = cfg
        self.sketch_cfg = swakde.SWAKDEConfig(
            L=cfg.L, W=cfg.W, window=cfg.window, eh_eps=cfg.eh_eps)
        key = jax.random.PRNGKey(cfg.seed)
        if cfg.hash_family == "srp":
            self.params = lsh.init_srp(key, cfg.dim, L=cfg.L, k=cfg.k,
                                       n_buckets=cfg.W)
        elif cfg.hash_family == "pstable":
            self.params = lsh.init_pstable(key, cfg.dim, L=cfg.L, k=cfg.k,
                                           w=cfg.w, n_buckets=cfg.W)
        else:
            raise ValueError(cfg.hash_family)
        self.state = swakde.swakde_init(self.sketch_cfg)
        self._lock = threading.Lock()

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_swakde(self.state, self.params,
                                                      self._ctx)
        self._update = jax.jit(
            lambda st, xs: ss.sharded_swakde_update_chunk(
                st, self.params, xs, self.sketch_cfg, self._ctx))
        self._query = jax.jit(
            lambda st, qs: ss.sharded_swakde_query_batch(
                st, self.params, qs, self.sketch_cfg, self._ctx))

    @property
    def num_shards(self) -> int:
        """Devices the rows are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    def ingest(self, points: np.ndarray) -> None:
        """Stream a block of points through the chunked batched update."""
        xs = jnp.asarray(points, jnp.float32)
        chunk = self.cfg.ingest_chunk
        with self._lock:
            for i in range(0, xs.shape[0], chunk):
                self.state = self._update(self.state, xs[i:i + chunk])

    def _query_blocks(self, state, qs: jnp.ndarray) -> np.ndarray:
        qb = max(1, self.cfg.query_block)
        out = [self._query(state, qs[i:i + qb])
               for i in range(0, qs.shape[0], qb)]
        if not out:                       # B = 0: one empty-engine call
            return np.asarray(self._query(state, qs))
        return np.asarray(out[0] if len(out) == 1 else jnp.concatenate(out))

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised window-density estimates Ŷ (Thm 4.1),
        served through the fused batch engine in ``query_block`` blocks."""
        return self._query_blocks(self.state,
                                  jnp.asarray(queries, jnp.float32))

    def density(self, queries: np.ndarray) -> np.ndarray:
        """Normalised sliding-window density: Ŷ / min(t, N)."""
        with self._lock:  # snapshot state + t together vs concurrent ingest
            state = self.state
        denom = max(min(int(state.t), self.cfg.window), 1)
        out = self._query_blocks(state, jnp.asarray(queries, jnp.float32))
        return out / float(denom)

    @property
    def steps(self) -> int:
        """Stream steps consumed so far."""
        return int(self.state.t)

    @property
    def sketch_bytes(self) -> int:
        return swakde.swakde_bytes(self.sketch_cfg)
