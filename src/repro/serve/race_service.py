"""RACE density service: streaming (whole-stream) KDE counters with
pipelined ingest and batched queries (paper §2.3, [CS20]).

The third sketch's serving layer, completing the trio next to
`repro.serve.retrieval.RetrievalService` (S-ANN) and
`repro.serve.kde_service.KDEService` (SW-AKDE): points arrive as a stream
of embeddings, the service maintains the (L, W) RACE counter grid and
answers batched unnormalised KDE queries.  Deletions are native turnstile
decrements (`delete`).

Runtime: a `repro.serve.engine.SketchEngine` — the shared streaming
runtime owns the lock, the chunk loop, the two-phase pipelined ingest
(`core.race.race_prepare_chunk` hashing + histogramming chunk k+1 on the
prepare thread while `race_commit_chunk` adds chunk k), the background
queue (``ingest_async`` / ``flush``), admission control (``max_pending``)
and — with ``snapshot_dir`` set — the snapshot + WAL durability subsystem
(`repro.persist`; counters restore bit-identically via ``recover()``).

Because RACE counters merge by exact addition (`core.race.race_merge`),
this service is also the per-worker engine of the merge-based cluster
runtime (`repro.serve.cluster.ClusterRACEService`), where N workers ingest
hash-partitioned substreams and the coordinator's merged counters are
*bit-identical* to a single engine over the whole stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.core import lsh, race
from repro.parallel import sketch_sharding as ss
from repro.serve.engine import SketchEngine, durability_from


@dataclasses.dataclass
class RACEServiceConfig:
    dim: int
    L: int = 32               # sketch rows (repetitions)
    W: int = 128              # LSH range after rehash
    hash_family: str = "srp"  # "srp" (angular) | "pstable" (Euclidean)
    k: int = 2                # concatenation power p
    w: float = 4.0            # p-stable bucket width (pstable only)
    median_of_means: int = 0  # 0/1 = row mean; g > 1 = median of g means
    seed: int = 0
    # Batched-ingest chunk: one prepare/commit pair per chunk; each distinct
    # partial-chunk size triggers one extra jit trace.
    ingest_chunk: int = 1024
    # Two-phase pipelining: prepare chunk k+1 on the engine's prepare thread
    # while chunk k commits (identical results either way).
    pipelined: bool = True
    # Query block: queries are answered in blocks of this many rows.
    query_block: int = 1024
    # Multi-device sharding: num_shards > 1 splits the L rows across that
    # many local devices; ``mesh`` overrides with a prebuilt mesh.
    num_shards: int = 0
    mesh: Optional[object] = None   # jax.sharding.Mesh
    # Admission control: bound on queued-but-uncommitted rows (None = off).
    max_pending: Optional[int] = None
    # Cross-request query micro-batching (DESIGN.md §13): coalesce
    # concurrent clients' queries into one fused batch per scheduler tick
    # (max ``max_batch`` rows, ``max_wait_us`` latency budget) against one
    # state snapshot.  Bit-identical answers; ``submit_query`` works
    # either way.
    batch_queries: bool = False
    max_batch: Optional[int] = None
    max_wait_us: float = 200.0
    # Durability (repro.persist): WAL-logged chunks + background snapshots
    # under ``snapshot_dir``; ``recover()`` restores bit-identically.
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 64
    wal_fsync: bool = False
    # Fault-injection site-name prefix (repro.persist.faults,
    # DESIGN.md §14); the cluster sets ``worker_<w>/`` per worker.
    fault_scope: str = ""


class RACEService(SketchEngine):
    """Thread-safe streaming RACE KDE counters with pipelined ingest and
    batched queries (shared runtime: `repro.serve.engine.SketchEngine`)."""

    def __init__(self, cfg: RACEServiceConfig):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        if cfg.hash_family == "srp":
            self.params = lsh.init_srp(key, cfg.dim, L=cfg.L, k=cfg.k,
                                       n_buckets=cfg.W)
        elif cfg.hash_family == "pstable":
            self.params = lsh.init_pstable(key, cfg.dim, L=cfg.L, k=cfg.k,
                                           w=cfg.w, n_buckets=cfg.W)
        else:
            raise ValueError(cfg.hash_family)
        super().__init__(ingest_chunk=cfg.ingest_chunk,
                         query_block=cfg.query_block,
                         pipelined=cfg.pipelined,
                         max_pending=cfg.max_pending,
                         durability=durability_from(cfg),
                         batch_queries=cfg.batch_queries,
                         max_batch=cfg.max_batch,
                         max_wait_us=cfg.max_wait_us,
                         fault_scope=cfg.fault_scope)
        self.state = race.race_init(cfg.L, cfg.W)

        self._ctx = ss.make_service_ctx(cfg.mesh, cfg.num_shards)
        if self._ctx.mesh is not None:
            self.state, self.params = ss.shard_race(self.state, self.params,
                                                    self._ctx)
        self._prepare_fn = jax.jit(
            lambda xs: ss.sharded_race_prepare_chunk(
                self.params, xs, cfg.W, self._ctx))
        self._commit_fn = jax.jit(
            lambda st, prep: ss.sharded_race_commit_chunk(
                st, prep, self._ctx))
        self._delete_commit_fn = jax.jit(
            lambda st, prep: ss.sharded_race_commit_chunk(
                st, prep, self._ctx, sign=-1))
        self._query_fn = jax.jit(
            lambda st, qs: ss.sharded_race_query_batch(
                st, self.params, qs, self._ctx,
                median_of_means=cfg.median_of_means))

    # --- engine hooks (two-phase ingest) -----------------------------------

    def _prepare(self, chunk: jax.Array) -> race.RACEPrep:
        return self._prepare_fn(chunk)

    def _commit(self, state: race.RACEState, prep: race.RACEPrep):
        return self._commit_fn(state, prep)

    def _place_state(self, state: race.RACEState) -> race.RACEState:
        if self._ctx.mesh is None:
            return state
        return ss.shard_race(state, self.params, self._ctx)[0]

    def _apply_wal_record(self, kind: int, arrays: dict) -> None:
        if kind == persist.KIND_DELETE:
            xs = jnp.asarray(arrays["xs"], jnp.float32)
            self._mutate_state(
                lambda st: self._delete_commit_fn(st, self._prepare_fn(xs)))
            return
        super()._apply_wal_record(kind, arrays)

    # --- serving API -------------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Devices the rows are split across (1 = single-device path)."""
        return ss.ctx_num_shards(self._ctx)

    def delete(self, embeddings: np.ndarray) -> None:
        """Turnstile deletion: decrement the counters for a batch of rows
        ``(B, d)``.  Pending async chunks flush first, then the decrement
        applies atomically (WAL-logged before applying when durable)."""
        xs = jnp.atleast_2d(jnp.asarray(embeddings, jnp.float32))
        self._durable_mutate(
            persist.KIND_DELETE, {"xs": np.asarray(xs)},
            lambda st: self._delete_commit_fn(st, self._prepare_fn(xs)))

    # --- query kinds (micro-batching; engine._BatchedQueryMixin) -----------

    _default_query_kind = "kde"

    def _query_kind_fns(self):
        def kde(ctx, qs):
            state, _ = ctx
            return np.asarray(
                self._query_blocks(lambda b: self._query_fn(state, b), qs))

        def density(ctx, qs):
            # estimates and n from the *same* snapshot; the scalar divide
            # is elementwise, so coalescing preserves bit-identity.
            state = ctx[0]
            return kde(ctx, qs) / max(float(np.asarray(state.n)), 1.0)

        return {"kde": kde, "density": density}

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched unnormalised KDE estimates (Theorem 2.3) against one
        committed snapshot, in ``query_block`` blocks.  With
        ``batch_queries`` the call is coalesced with concurrent clients'
        queries into one fused batch (bit-identical results)."""
        return self._serve_query("kde", queries)

    def kde(self, queries: np.ndarray) -> np.ndarray:
        """Normalised density: raw estimate / signed stream size, from one
        snapshot (micro-batched like `query` when ``batch_queries``)."""
        return self._serve_query("density", queries)

    @property
    def count(self) -> int:
        """Signed stream size (insertions - deletions) consumed so far."""
        return int(self.state.n)

    @property
    def sketch_bytes(self) -> int:
        return self.cfg.L * self.cfg.W * 4 + 4
