"""Shared streaming-service runtime: two-phase pipelined ingest + snapshot
queries (DESIGN.md §10).

Every sketch service is the same state machine: a stream of embedding
chunks folds into immutable sketch state under a lock, while concurrent
queries read a snapshot of that state.  `SketchEngine` owns that machinery
exactly once — `RetrievalService` and `KDEService` are thin subclasses that
plug in the sketch-specific *prepare* / *commit* pair:

  * **prepare** (`core.*.{sann,race,swakde}_prepare_chunk`) is pure: the
    hash matmul plus all per-chunk precomputation (keep decisions, sort
    orders, per-cell segments).  It never reads sketch state, so the engine
    runs it on a dedicated thread, one chunk ahead of the commits
    (double-buffering: prepare of chunk k+1 overlaps commit of chunk k —
    the streaming regime of Coleman & Shrivastava's RACE sketch, where
    hashing is the embarrassingly parallel half of an update).
  * **commit** (`core.*.*_commit_chunk`) is the only state-sequential part:
    it rebases the prepared chunk on the current pointers/clock and applies
    the dense update.  Commits are serialized by the engine's lock and are
    the only writers of ``self.state``.

Consistency contract: ``self.state`` is only ever replaced *atomically*
under the lock with a fully committed value, so a query snapshot is always
the exact state after some committed prefix of the submitted stream —
never a torn mix of chunks (tests/test_engine.py).  ``flush()`` after any
number of ``ingest_async()`` calls leaves the service in exactly the state
the synchronous ``ingest()`` path produces (it *is* the same path:
``ingest == ingest_async + flush``, one chunk loop, one lock).

Query-side snapshot caching: every commit bumps a version counter;
`cached()` memoises pure functions of a snapshot (e.g. the SW-AKDE
(L, W) grid-estimate table) keyed by that version, so repeated query
batches between commits skip the recompute and any commit invalidates the
cache automatically.
"""
from __future__ import annotations

import collections
import threading
import traceback
from typing import Any, Callable, Optional
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp

# Queue marker telling the ingest worker to exit (see SketchEngine.close).
_STOP = object()


class SketchEngine:
    """Two-phase streaming-ingest runtime shared by the sketch services.

    Subclass contract (all other plumbing lives here, once):

      * set ``self.state`` (an immutable pytree) before first use;
      * ``_make_chunk_item(chunk)`` — called in submission order under the
        submit lock; returns the argument tuple for ``_prepare`` (this is
        where a per-chunk PRNG key schedule is drawn, so the schedule is
        deterministic across sync/async ingest);
      * ``_prepare(*item)`` — jitted pure prepare phase (state-independent);
      * ``_commit(state, prep)`` — jitted commit phase.

    Knobs: ``ingest_chunk`` rows per prepare/commit pair, ``query_block``
    rows per fused query call, ``pipelined=False`` disables the
    double-buffered overlap (prepare and commit run strictly in sequence —
    the benchmark baseline; results are bit-identical either way).
    """

    state: Any

    def __init__(self, ingest_chunk: int, query_block: int = 1024,
                 pipelined: bool = True):
        self._chunk = max(1, int(ingest_chunk))
        self._query_block = max(1, int(query_block))
        self._pipelined = bool(pipelined)
        # _lock guards state + version + snapshot cache; _submit_lock orders
        # chunk submission (key draws happen in queue order).
        self._lock = threading.Lock()
        self._submit_lock = threading.Lock()
        self._version = 0
        self._snap_cache: dict = {}
        self._queue: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._pending = 0
        self._worker: Optional[threading.Thread] = None
        self._ingest_error: Optional[str] = None
        self._closed = False
        # One dedicated prepare thread: the CPU PJRT client serializes
        # executables dispatched from a single thread, so the overlap of
        # prepare(k+1) with commit(k) needs a second dispatch thread (the
        # ingest worker blocks on the commit while this pool blocks on the
        # prepare).
        self._prep_pool = (ThreadPoolExecutor(max_workers=1)
                           if self._pipelined else None)

    # --- subclass hooks ----------------------------------------------------

    def _make_chunk_item(self, chunk: jax.Array) -> tuple:
        return (chunk,)

    def _prepare(self, *item):
        raise NotImplementedError

    def _commit(self, state, prep):
        raise NotImplementedError

    # --- ingest ------------------------------------------------------------

    def ingest(self, data) -> None:
        """Synchronous chunked ingest: submit + wait.  Exactly
        ``ingest_async(data)`` followed by ``flush()`` — one code path."""
        self.ingest_async(data)
        self.flush()

    def ingest_async(self, data) -> None:
        """Queue a block of rows for background two-phase ingest and return
        immediately.  Chunks commit in submission order; concurrent queries
        observe some committed prefix.  Call ``flush()`` to wait."""
        xs = jnp.asarray(data, jnp.float32)
        if xs.shape[0] == 0:
            return
        with self._submit_lock:
            if self._closed:
                raise RuntimeError(f"{type(self).__name__} is closed")
            items = [self._make_chunk_item(xs[i:i + self._chunk])
                     for i in range(0, xs.shape[0], self._chunk)]
            with self._cv:
                self._queue.extend(items)
                self._pending += len(items)
                if self._worker is None:
                    self._worker = threading.Thread(
                        target=self._worker_loop, daemon=True,
                        name=f"{type(self).__name__}-ingest")
                    self._worker.start()
                self._cv.notify_all()

    def flush(self) -> None:
        """Block until every queued chunk is committed (and the state is
        materialised).  Re-raises any background ingest failure since the
        last flush — delivered to exactly one caller when several threads
        flush concurrently.  Failure semantics are fail-stop/at-most-once:
        once a chunk fails, the chunks queued behind it are *discarded*
        (never committed out of order, so snapshots stay committed
        prefixes) until the error is consumed here; the caller decides
        what to re-submit.  After a clean flush, the state equals what
        synchronous ingest of the same stream would have produced."""
        with self._cv:
            while self._pending:
                self._cv.wait()
            err, self._ingest_error = self._ingest_error, None
        if err is not None:
            raise RuntimeError(f"background ingest failed:\n{err}")
        with self._lock:
            st = self.state
        jax.block_until_ready(st)

    def close(self) -> None:
        """Commit everything already queued, then stop the worker thread
        and the prepare pool.  Idempotent; the engine rejects new ingests
        afterwards (queries keep working).  Call ``flush()`` first if you
        need background failures re-raised."""
        with self._submit_lock:
            if self._closed:
                return
            self._closed = True
            with self._cv:
                worker = self._worker
                if worker is not None:
                    self._queue.append(_STOP)
                    self._cv.notify_all()
        if worker is not None:
            worker.join()
        if self._prep_pool is not None:
            self._prep_pool.shutdown(wait=True)
            self._prep_pool = None

    def _worker_loop(self) -> None:
        """THE chunk loop: double-buffered prepare/commit over the live
        queue.  While this thread blocks on chunk k's prepare/commit, the
        prepare pool computes chunk k+1 — including chunks that were
        queued after k started (the lookahead pulls from the live queue,
        so one-chunk-per-call producers still pipeline)."""
        ahead: Optional[tuple] = None       # (item, future) prepared ahead
        while True:
            if ahead is not None:
                item, fut = ahead
                ahead = None
            else:
                with self._cv:
                    while not self._queue:
                        self._cv.wait()
                    item = self._queue.popleft()
                if item is _STOP:
                    return
                fut = None
            try:
                # Fail-stop: after a failure, drop queued chunks (instead
                # of committing a stream with a hole in it) until flush()
                # consumes the error.
                if self._ingest_error is None:
                    if fut is None:
                        fut = self._submit_prepare(item)
                    # schedule the lookahead before blocking on this chunk
                    if self._prep_pool is not None:
                        with self._cv:
                            nxt = (self._queue.popleft()
                                   if self._queue and
                                   self._queue[0] is not _STOP else None)
                        if nxt is not None:
                            ahead = (nxt, self._submit_prepare(nxt))
                    prep = fut.result() if hasattr(fut, "result") else fut
                    self._commit_one(prep)
            except BaseException:
                with self._cv:
                    self._ingest_error = traceback.format_exc()
            finally:
                with self._cv:
                    self._pending -= 1
                    self._cv.notify_all()

    def _submit_prepare(self, item: tuple):
        """Dispatch a chunk's prepare: on the pool when pipelined (so it
        overlaps this thread's commit), inline otherwise."""
        if self._prep_pool is not None:
            return self._prep_pool.submit(self._prepare_ready, item)
        return self._prepare_ready(item)

    def _prepare_ready(self, item: tuple):
        return jax.block_until_ready(self._prepare(*item))

    def _commit_one(self, prep) -> None:
        with self._lock:
            self.state = st = self._commit(self.state, prep)
            self._version += 1
        # Pace the pipeline outside the lock: queries snapshot the (futures
        # of the) new state immediately; the worker waits here while the
        # prepare pool hashes the next chunk.
        jax.block_until_ready(st)

    # --- snapshots, caching, queries ---------------------------------------

    def snapshot(self):
        """Atomically read ``(state, version)`` — the lock-consistent way to
        serve a query batch against one committed prefix."""
        with self._lock:
            return self.state, self._version

    @property
    def version(self) -> int:
        """Commits applied so far (every commit invalidates `cached`)."""
        with self._lock:
            return self._version

    def cached(self, name: str, version: int, compute: Callable[[], Any]):
        """Memoise a pure function of the snapshot at ``version`` (e.g. the
        SW-AKDE grid-estimate table).  A commit bumps the version, so stale
        entries are never served; concurrent same-version computes are
        benign (identical values, last install wins)."""
        with self._lock:
            ent = self._snap_cache.get(name)
            if ent is not None and ent[0] == version:
                return ent[1]
        val = compute()                      # outside the lock: may be slow
        with self._lock:
            ent = self._snap_cache.get(name)
            if ent is None or ent[0] <= version:
                self._snap_cache[name] = (version, val)
        return val

    def _mutate_state(self, fn: Callable[[Any], Any]) -> None:
        """Apply an out-of-band state update (e.g. a turnstile delete)
        atomically; bumps the version so snapshot caches invalidate.  Note:
        applies to the current committed prefix — chunks still queued
        behind it commit afterwards."""
        with self._lock:
            self.state = fn(self.state)
            self._version += 1

    def _query_blocks(self, fn: Callable[[jax.Array], Any], qs: jax.Array):
        """Run ``fn`` over ``qs`` in ``query_block``-row blocks and
        concatenate the result pytrees (B = 0 → one empty-engine call)."""
        qb = self._query_block
        out = [fn(qs[i:i + qb]) for i in range(0, qs.shape[0], qb)]
        if not out:
            return fn(qs)
        if len(out) == 1:
            return out[0]
        return jax.tree.map(lambda *parts: jnp.concatenate(parts), *out)


# The runtime is sketch-agnostic; the serving layer refers to it by either
# name (the engine *is* the streaming service base class).
StreamingService = SketchEngine
