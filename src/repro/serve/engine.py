"""Shared streaming-service runtime: two-phase pipelined ingest + snapshot
queries (DESIGN.md §10), durable via snapshot + WAL (DESIGN.md §11).

Every sketch service is the same state machine: a stream of embedding
chunks folds into immutable sketch state under a lock, while concurrent
queries read a snapshot of that state.  `SketchEngine` owns that machinery
exactly once — `RetrievalService`, `KDEService` and `RACEService` are thin
subclasses that plug in the sketch-specific *prepare* / *commit* pair:

  * **prepare** (`core.*.{sann,race,swakde}_prepare_chunk`) is pure: the
    hash matmul plus all per-chunk precomputation (keep decisions, sort
    orders, per-cell segments).  It never reads sketch state, so the engine
    runs it on a dedicated thread, one chunk ahead of the commits
    (double-buffering: prepare of chunk k+1 overlaps commit of chunk k —
    the streaming regime of Coleman & Shrivastava's RACE sketch, where
    hashing is the embarrassingly parallel half of an update).
  * **commit** (`core.*.*_commit_chunk`) is the only state-sequential part:
    it rebases the prepared chunk on the current pointers/clock and applies
    the dense update.  Commits are serialized by the engine's lock and are
    the only writers of ``self.state``.

Consistency contract: ``self.state`` is only ever replaced *atomically*
under the lock with a fully committed value, so a query snapshot is always
the exact state after some committed prefix of the submitted stream —
never a torn mix of chunks (tests/test_engine.py).  ``flush()`` after any
number of ``ingest_async()`` calls leaves the service in exactly the state
the synchronous ``ingest()`` path produces (it *is* the same path:
``ingest == ingest_async + flush``, one chunk loop, one lock).

Admission control: ``max_pending`` bounds the rows queued behind the
commit worker; ``ingest_async`` blocks (backpressure) instead of letting
the queue grow without bound.  One chunk is always admitted, so progress
is guaranteed even when a single chunk exceeds the bound.

Durability (`repro.persist`): with a `DurabilityConfig`, every operation
gets a global sequence number, chunks are appended to a chunk-granular
write-ahead log *at enqueue time* (before the commit worker can see
them), and the commit worker writes background state snapshots every
``snapshot_every`` operations (WAL segments behind a durable snapshot are
compacted away).  ``recover()`` = load the newest snapshot + replay the
WAL tail through this same prepare/commit path — bit-identical to the
uninterrupted run, because per-chunk PRNG keys are a pure function of the
chunk's sequence number (the ``_make_chunk_item(chunk, seq)`` contract).

Query-side snapshot caching: every commit bumps a version counter;
`cached()` memoises pure functions of a snapshot (e.g. the SW-AKDE
(L, W) grid-estimate table) keyed by that version, so repeated query
batches between commits skip the recompute and any commit invalidates the
cache automatically.

Cross-request query micro-batching (`QueryBatcher`, DESIGN.md §13): the
fused batch engine is ~an order of magnitude faster per row at B = 1024
than at B = 1, but real client traffic arrives as B = 1 — so the engine
also owns a query-side *admission scheduler* that coalesces concurrent
client queries into one fused `query_batch` per tick (bounded by
``max_batch`` rows and a ``max_wait_us`` latency budget), serves the whole
coalesced batch from ONE versioned state snapshot (and, for SW-AKDE, one
grid-cache entry), and scatters per-query results back to the waiting
callers' futures.  The paper's batched-query guarantee (a batch of ANN
queries shares a single sketch pass) makes the coalesced batch
semantically identical to N independent queries, and the PR-3 fused
engines are row-independent and pinned bit-identical to the per-query
oracles — so coalescing is invisible to clients bit-for-bit
(tests/test_serve_batching.py).  ``submit_query`` enqueues and returns a
future; the sync query wrappers route through the batcher when the
service is built with ``batch_queries=True`` (default off: nothing
changes for existing callers).
"""
from __future__ import annotations

import collections
import pathlib
import threading
import time
import traceback
from typing import Any, Callable, Optional, Sequence
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.checkpoint.checkpoint import AsyncCheckpointer
from repro.persist import faults

# Queue marker telling the ingest worker to exit (see SketchEngine.close).
_STOP = object()


def durability_from(cfg) -> Optional[persist.DurabilityConfig]:
    """Shared service-config → DurabilityConfig mapping: any config with a
    ``snapshot_dir`` (plus ``snapshot_every`` / ``wal_fsync``) opts into
    the snapshot + WAL subsystem; ``snapshot_dir=None`` stays volatile."""
    if getattr(cfg, "snapshot_dir", None) is None:
        return None
    return persist.DurabilityConfig(
        dir=cfg.snapshot_dir, snapshot_every=cfg.snapshot_every,
        fsync=cfg.wal_fsync, fault_scope=getattr(cfg, "fault_scope", ""))


def batch_plan(pending: Sequence, now_us: float, max_batch: int,
               max_wait_us: float):
    """Pure admission policy of one `QueryBatcher` tick.

    ``pending`` is the FIFO queue of waiting requests as ``(arrival_us,
    n_rows)`` pairs (non-empty); returns ``(take, wait_us)``:

      * ``take >= 1`` — coalesce the first ``take`` requests into one
        fused batch *now*;
      * ``take == 0`` — no batch yet: sleep at most ``wait_us`` for more
        arrivals (always the *oldest* request's remaining budget, so later
        arrivals can never push the deadline out — no starvation).

    Firing rule: dispatch as soon as the coalesced prefix (a) holds at
    least ``max_batch`` rows, (b) is row-capped (the next request would
    not fit — waiting adds latency without adding rows), or (c) the oldest
    request's ``max_wait_us`` budget is spent.  The prefix never exceeds
    ``max_batch`` rows unless a single request alone does (one-request
    progress guarantee).  Kept free of threads/clocks so the scheduler
    properties are fuzz-testable exactly (tests/test_serve_batching.py).
    """
    take, rows = 0, 0
    for _, n in pending:
        if take and rows + n > max_batch:
            break
        take += 1
        rows += n
    capped = take < len(pending)
    deadline = pending[0][0] + max_wait_us
    if rows >= max_batch or capped or now_us >= deadline:
        return take, 0.0
    return 0, deadline - now_us


class QueryBatcher:
    """Cross-request query micro-batching: an admission queue + tick loop.

    Concurrent ``submit(kind, rows)`` calls enqueue ``(B_i, d)`` query
    blocks and get a `concurrent.futures.Future` back; a dedicated
    scheduler thread coalesces the queue into one execute call per tick
    under the `batch_plan` policy (``max_batch`` rows / ``max_wait_us``
    latency budget) and scatters per-request result slices onto the
    futures.  ``execute(reqs)`` — supplied by the engine — receives the
    FIFO list of ``(kind, rows)`` and must return one result per request;
    it runs *outside* the queue lock, so arrivals during a slow batch
    simply form the next tick (a slow query delays later arrivals by at
    most one in-flight execute, never indefinitely).

    ``close()`` drains: queued requests are still served (in order), then
    the thread exits; ``close(drain=False)`` fails pending futures with
    `RuntimeError` instead.  Either way no future is left hanging and new
    submissions are rejected.

    Lone-client fast path (`try_submit_inline`): in continuous-batching
    mode (``max_wait_us == 0`` — fire the moment the executor is free) a
    *sync* caller that finds the queue empty and no tick in flight can
    run its request as its own tick on the caller thread, skipping both
    scheduler-thread handoffs (C = 1 previously paid ~2.5× the direct
    path on wakeup latency alone).  The inline tick claims the same
    single-executor slot the loop uses (``_busy``), so coalescing under
    load is unchanged: requests arriving while any tick is in flight
    queue up and form the next fused batch.  ``submit`` itself never
    inlines — async callers must get their future back immediately, even
    when the execute is slow.  With ``max_wait_us > 0`` every request
    takes the queued path — an idle-start request must *wait* for
    coalescing partners there, which is exactly what the inline path
    would skip.  Results are bit-identical either way (same execute,
    same rows).
    """

    def __init__(self, execute: Callable[[list], list],
                 max_batch: int = 1024, max_wait_us: float = 200.0):
        self._execute = execute
        self._max_batch = max(1, int(max_batch))
        self._max_wait_us = max(0.0, float(max_wait_us))
        self._cv = threading.Condition()
        self._pending: collections.deque = collections.deque()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        # stats (under _cv): ticks = execute calls, queries/rows = totals
        # over all coalesced batches, max_tick_rows = largest single tick.
        self._ticks = 0
        self._queries = 0
        self._rows = 0
        self._max_tick_rows = 0
        self._inline_ticks = 0
        # Ticks in flight (0 or 1): the loop and the inline fast path
        # both claim this slot under _cv, so at most one execute runs.
        self._busy = 0

    @property
    def closed(self) -> bool:
        return self._closed

    def submit(self, kind: str, rows) -> Future:
        """Enqueue one query block; returns a future resolving to the
        engine's result for exactly these rows (bit-identical to an
        uncoalesced call)."""
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("QueryBatcher is closed")
            self._pending.append(
                (time.monotonic() * 1e6, kind, rows, fut))
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, daemon=True, name="query-batcher")
                self._thread.start()
            self._cv.notify_all()
        return fut

    def try_submit_inline(self, kind: str, rows) -> Optional[Future]:
        """Lone-client fast path (see class docstring): when the executor
        is idle and nothing is queued in continuous-batching mode, run
        this request as its own tick on the *caller* thread and return
        its (completed) future.  Returns None when the fast path is
        unavailable — the caller falls back to `submit`.  Only for sync
        callers that would block on the future anyway."""
        with self._cv:
            if self._closed:
                raise RuntimeError("QueryBatcher is closed")
            if (self._max_wait_us != 0.0 or self._pending
                    or self._busy != 0):
                return None
            self._busy = 1
            self._ticks += 1
            self._queries += 1
            self._inline_ticks += 1
            n = int(rows.shape[0])
            self._rows += n
            self._max_tick_rows = max(self._max_tick_rows, n)
        fut: Future = Future()
        try:
            results = self._execute([(kind, rows)])
            fut.set_result(results[0])
        except BaseException as e:
            fut.set_exception(e)
        finally:
            with self._cv:
                self._busy = 0
                self._cv.notify_all()
        return fut

    def stats(self) -> dict:
        """Scheduler counters: ticks (fused execute calls), coalesced
        queries/rows, mean coalesced batch size, largest tick."""
        with self._cv:
            t = max(self._ticks, 1)
            return {"ticks": self._ticks, "queries": self._queries,
                    "rows": self._rows,
                    "mean_batch_queries": self._queries / t,
                    "mean_batch_rows": self._rows / t,
                    "max_tick_rows": self._max_tick_rows,
                    "inline_ticks": self._inline_ticks}

    def close(self, drain: bool = True) -> None:
        """Stop accepting work; serve (``drain=True``) or fail the queue,
        then join the scheduler thread.  Idempotent."""
        with self._cv:
            self._closed = True
            if not drain:
                while self._pending:
                    *_, fut = self._pending.popleft()
                    fut.set_exception(
                        RuntimeError("QueryBatcher closed before serving"))
            thread = self._thread
            self._cv.notify_all()
        if thread is not None:
            thread.join()
            self._thread = None
        with self._cv:
            # Wait out any inline tick so no execute is still running
            # when close() returns.
            while self._busy:
                self._cv.wait()

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending:
                    return                       # closed and drained
                take = 0
                while self._pending:
                    # A closing batcher fires the planned prefix at once
                    # (wait budget 0) — drain without the latency budget.
                    take, wait_us = batch_plan(
                        [(arr, r.shape[0]) for arr, _, r, _ in
                         self._pending],
                        time.monotonic() * 1e6, self._max_batch,
                        0.0 if self._closed else self._max_wait_us)
                    if take:
                        break
                    self._cv.wait(wait_us / 1e6)
                while take and self._busy:   # an inline tick is in flight
                    self._cv.wait()
                # Every wait above releases the lock, so close(drain=False)
                # may have failed-and-drained the queue meanwhile: re-clamp
                # the planned prefix to what is still queued before popping
                # (a stale `take` would underflow the deque and kill this
                # thread with an unhandled IndexError).
                take = min(take, len(self._pending))
                if not take:
                    continue
                batch = [self._pending.popleft() for _ in range(take)]
                self._busy = 1
                self._ticks += 1
                self._queries += len(batch)
                rows = sum(r.shape[0] for _, _, r, _ in batch)
                self._rows += rows
                self._max_tick_rows = max(self._max_tick_rows, rows)
            reqs = [(kind, r) for _, kind, r, _ in batch]
            try:
                results = self._execute(reqs)
                for (*_, fut), res in zip(batch, results):
                    fut.set_result(res)
            except BaseException as e:
                for *_, fut in batch:
                    if not fut.done():
                        fut.set_exception(e)
            finally:
                with self._cv:
                    self._busy = 0
                    self._cv.notify_all()


class _BatchedQueryMixin:
    """Shared query-side micro-batching API: `SketchEngine` and the
    cluster coordinator both coalesce through this (one snapshot per
    tick, per-kind fused calls, per-request result scatter).

    Host-class contract: ``_query_kind_fns()`` maps query-kind names to
    ``fn(snapshot_ctx, qs) -> pytree`` with a leading B axis (row
    independent and bit-identical to per-query calls — the PR-3 fused
    engines); ``_query_snapshot_ctx()`` captures everything a tick shares
    (state snapshot, version, caches) in ONE lock-consistent read; and
    ``_default_query_kind`` names the plain-``query()`` kind.
    """

    _default_query_kind = "query"
    # Fault-injection naming (DESIGN §14): the engine's query path is
    # ``engine.query``; the cluster coordinator overrides the site (and
    # scoped engines override the prefix).
    _fault_scope = ""
    _query_fault_site = "engine.query"

    def _init_query_batching(self, batch_queries: bool,
                             max_batch: Optional[int],
                             max_wait_us: float, default_max_batch: int):
        self._batch_queries = bool(batch_queries)
        self._max_batch = (default_max_batch if max_batch is None
                           else max(1, int(max_batch)))
        self._max_wait_us = float(max_wait_us)
        self._batcher: Optional[QueryBatcher] = None
        self._batcher_lock = threading.Lock()
        self._kind_fns: Optional[dict] = None

    # --- host-class hooks ---------------------------------------------------

    def _query_kind_fns(self) -> dict:
        raise NotImplementedError

    def _query_snapshot_ctx(self):
        raise NotImplementedError

    # --- API ----------------------------------------------------------------

    @property
    def batcher(self) -> Optional[QueryBatcher]:
        """The live scheduler (None until the first ``submit_query``)."""
        return self._batcher

    def _kind_fn(self, kind: str) -> Callable:
        if self._kind_fns is None:
            self._kind_fns = self._query_kind_fns()
        try:
            return self._kind_fns[kind]
        except KeyError:
            raise ValueError(
                f"unknown query kind {kind!r}; expected one of "
                f"{sorted(self._kind_fns)}") from None

    def submit_query(self, queries, kind: Optional[str] = None) -> Future:
        """Enqueue a query block ``(B, d)`` with the admission scheduler
        and return a future — the asynchronous client entry point.  The
        result is bit-identical to the corresponding sync call; B = 0
        blocks resolve to the matching empty result."""
        kind = self._default_query_kind if kind is None else kind
        self._kind_fn(kind)                      # validate before enqueue
        # Host-side staging: keep the rows as numpy so the tick can
        # concatenate and scatter without any per-request device dispatch.
        qs = np.asarray(queries, np.float32)
        with self._batcher_lock:
            if self._batcher is None:
                self._batcher = QueryBatcher(
                    self._batch_execute, max_batch=self._max_batch,
                    max_wait_us=self._max_wait_us)
            return self._batcher.submit(kind, qs)

    def _serve_query(self, kind: str, queries):
        """Sync query entry: through the scheduler when the service was
        built with ``batch_queries=True`` (and it is still accepting),
        directly against one snapshot otherwise — identical results."""
        qs = np.asarray(queries, np.float32)
        if self._batch_queries and not (
                self._batcher is not None and self._batcher.closed):
            self._kind_fn(kind)                  # validate before enqueue
            with self._batcher_lock:
                if self._batcher is None:
                    self._batcher = QueryBatcher(
                        self._batch_execute, max_batch=self._max_batch,
                        max_wait_us=self._max_wait_us)
                batcher = self._batcher
            # Sync callers block on the result either way, so they may
            # take the lone-client inline tick when the scheduler is idle.
            fut = batcher.try_submit_inline(kind, qs)
            if fut is None:
                fut = batcher.submit(kind, qs)
            return fut.result()
        faults.fire(self._fault_scope + self._query_fault_site)
        return self._kind_fn(kind)(self._query_snapshot_ctx(), qs)

    def _close_batcher(self) -> None:
        with self._batcher_lock:
            if self._batcher is not None:
                self._batcher.close()

    # --- the coalesced tick -------------------------------------------------

    @staticmethod
    def _pad_rows(n: int, block: int) -> int:
        """Coalesced batch sizes vary per tick; pad to a bucketed size so
        the fused engines see O(log max_batch) distinct shapes instead of
        one jit trace per size: next power of two below ``block``, next
        multiple of ``block`` above (every `_query_blocks` block is then
        full-size).  Padding rows are zeros and the fused engines are row
        independent, so the first n rows are bit-identical."""
        if n >= block:
            return -(-n // block) * block
        p = 1
        while p < n:
            p <<= 1
        return p

    def _batch_execute(self, reqs: list) -> list:
        """Serve one coalesced tick: ONE snapshot context for every
        request, one fused (padded) call per query kind, per-request
        result slices scattered back in FIFO order.

        All batch bookkeeping stays on the host: requests arrive as
        numpy (submit_query), the concat + zero-pad are numpy ops, and
        the fused output is pulled to the host ONCE so the per-request
        scatter is numpy views — a tick costs one device round trip per
        kind regardless of how many requests coalesced.  (A device-side
        jnp.concatenate would retrace per distinct request *count*, and
        per-request device slices would pay one dispatch per array —
        both defeat the point of coalescing.)"""
        faults.fire(self._fault_scope + self._query_fault_site)
        ctx = self._query_snapshot_ctx()
        results: list = [None] * len(reqs)
        groups: dict = {}
        for i, (kind, qs) in enumerate(reqs):
            groups.setdefault(kind, []).append(i)
        for kind, idxs in groups.items():
            fn = self._kind_fn(kind)
            live = [i for i in idxs if reqs[i][1].shape[0]]
            for i in idxs:                       # B = 0: direct empty call
                if reqs[i][1].shape[0] == 0:
                    results[i] = jax.tree.map(np.asarray, fn(ctx, reqs[i][1]))
            if not live:
                continue
            if len(live) == 1:
                results[live[0]] = jax.tree.map(
                    np.asarray, fn(ctx, reqs[live[0]][1]))
                continue
            parts = [reqs[i][1] for i in live]
            n = sum(p.shape[0] for p in parts)
            pad = self._pad_rows(n, self._batch_query_block()) - n
            if pad:
                parts = parts + [np.zeros((pad,) + parts[0].shape[1:],
                                          parts[0].dtype)]
            out = jax.tree.map(np.asarray, fn(ctx, np.concatenate(parts)))
            lo = 0
            for i in live:
                hi = lo + reqs[i][1].shape[0]
                results[i] = jax.tree.map(
                    lambda a, lo=lo, hi=hi: a[lo:hi], out)
                lo = hi
        return results

    def _batch_query_block(self) -> int:
        """Row bucket for `_pad_rows` (the host's fused-query block)."""
        return self._query_block                 # SketchEngine attribute


class SketchEngine(_BatchedQueryMixin):
    """Two-phase streaming-ingest runtime shared by the sketch services.

    Subclass contract (all other plumbing lives here, once):

      * set ``self.state`` (an immutable pytree) before first use;
      * ``_make_chunk_item(chunk, seq)`` — called in submission order under
        the submit lock; returns the argument tuple for ``_prepare``.  Any
        per-chunk randomness must be a pure function of ``seq`` (e.g.
        ``jax.random.fold_in(base_key, seq)``) so the schedule is identical
        across sync/async ingest *and* across crash recovery replay;
      * ``_prepare(*item)`` — jitted pure prepare phase (state-independent);
      * ``_commit(state, prep)`` — jitted commit phase;
      * optionally ``_apply_wal_record(kind, arrays)`` for service-logged
        mutations (e.g. deletes) and ``_place_state(state)`` to re-shard a
        host-restored snapshot.

    Knobs: ``ingest_chunk`` rows per prepare/commit pair, ``query_block``
    rows per fused query call, ``pipelined=False`` disables the
    double-buffered overlap (prepare and commit run strictly in sequence —
    the benchmark baseline; results are bit-identical either way),
    ``prepare_depth`` is how many chunks the prepare side may run ahead of
    the commit side (default 1 = classic double buffering; deeper lookahead
    only pays off now that the commit half is a closed-form segment fold
    and no longer dominates — results stay bit-identical because commits
    still apply strictly in submission order), ``max_pending`` bounds
    queued-but-uncommitted rows (None = unbounded), ``durability`` enables
    the snapshot + WAL subsystem.

    Query-side micro-batching (`_BatchedQueryMixin`): ``batch_queries``
    routes the sync query wrappers through the admission scheduler,
    ``max_batch`` bounds the rows coalesced per tick (None = the
    ``query_block``) and ``max_wait_us`` is the scheduler's latency
    budget; ``submit_query`` is always available regardless.
    """

    state: Any

    def __init__(self, ingest_chunk: int, query_block: int = 1024,
                 pipelined: bool = True,
                 prepare_depth: int = 1,
                 max_pending: Optional[int] = None,
                 durability: Optional[persist.DurabilityConfig] = None,
                 batch_queries: bool = False,
                 max_batch: Optional[int] = None,
                 max_wait_us: float = 200.0,
                 fault_scope: str = ""):
        self._chunk = max(1, int(ingest_chunk))
        # Fault-injection site prefix (repro.persist.faults; DESIGN §14) —
        # the cluster names each worker's sites ``worker_<w>/...``.
        self._fault_scope = fault_scope or (
            durability.fault_scope if durability is not None else "")
        self._query_block = max(1, int(query_block))
        self._init_query_batching(batch_queries, max_batch, max_wait_us,
                                  default_max_batch=self._query_block)
        self._pipelined = bool(pipelined)
        self._prepare_depth = max(1, int(prepare_depth))
        self._max_pending = (None if max_pending is None
                             else max(1, int(max_pending)))
        # _lock guards state + version + snapshot cache; _submit_lock orders
        # chunk submission (seq numbers + WAL appends happen in queue order).
        self._lock = threading.Lock()
        self._submit_lock = threading.Lock()
        self._version = 0
        self._snap_cache: dict = {}
        self._queue: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._pending = 0
        self._pending_rows = 0
        self._worker: Optional[threading.Thread] = None
        self._ingest_error: Optional[str] = None
        self._closed = False
        self._poisoned = False
        self._poison_reason: Optional[str] = None
        # Durability: global operation sequence (chunks + logged mutations).
        # _seq = next seq to assign, _committed_seq = ops applied to state.
        self._seq = 0
        self._committed_seq = 0
        self._dur = durability
        self._wal: Optional[persist.WriteAheadLog] = None
        self._ckpt: Optional[AsyncCheckpointer] = None
        self._needs_recover = False
        self._snap_inflight: Optional[int] = None
        self._last_snap_seq = 0
        if durability is not None:
            if (pathlib.Path(durability.dir) / "cluster.json").exists():
                raise RuntimeError(
                    f"{durability.dir!r} is a cluster durability directory "
                    "(its state lives under worker_* subdirectories); a "
                    "single engine cannot recover it — reopen with the "
                    "cluster service at the original worker count.")
            self._wal = persist.WriteAheadLog(
                pathlib.Path(durability.dir) / "wal", fsync=durability.fsync,
                fault_scope=self._fault_scope)
            self._ckpt = AsyncCheckpointer()
            self._needs_recover = (
                persist.snapshot.latest_seq(durability.dir) is not None
                or self._wal.has_records())
        # Dedicated prepare threads: the CPU PJRT client serializes
        # executables dispatched from a single thread, so the overlap of
        # prepare(k+1..k+depth) with commit(k) needs separate dispatch
        # threads (the ingest worker blocks on the commit while this pool
        # blocks on the prepares).
        self._prep_pool = (ThreadPoolExecutor(
            max_workers=self._prepare_depth)
            if self._pipelined else None)

    def _poison(self, where: str, exc: BaseException) -> None:
        """Fail-stop with a recorded reason (surfaced by `health()`)."""
        self._poisoned = True
        self._poison_reason = f"{where}: {exc!r}"

    # --- subclass hooks ----------------------------------------------------

    def _make_chunk_item(self, chunk: jax.Array, seq: int) -> tuple:
        return (chunk,)

    def _prepare(self, *item):
        raise NotImplementedError

    def _commit(self, state, prep):
        raise NotImplementedError

    def _apply_wal_record(self, kind: int, arrays: dict) -> None:
        """Replay a service-logged mutation record (see `_durable_mutate`).
        Subclasses that log mutations must override."""
        raise NotImplementedError(f"unknown WAL record kind {kind}")

    def _place_state(self, state):
        """Re-place a host-restored snapshot onto the engine's devices/mesh
        (identity by default; sharded services override)."""
        return state

    # --- ingest ------------------------------------------------------------

    def ingest(self, data) -> None:
        """Synchronous chunked ingest: submit + wait.  Exactly
        ``ingest_async(data)`` followed by ``flush()`` — one code path."""
        self.ingest_async(data)
        self.flush()

    def ingest_async(self, data) -> None:
        """Queue a block of rows for background two-phase ingest and return
        (mostly) immediately.  Chunks commit in submission order; concurrent
        queries observe some committed prefix.  With ``max_pending`` set,
        blocks while the queue holds that many uncommitted rows
        (admission-control backpressure).  With durability, each chunk is
        WAL-logged before it becomes visible to the commit worker.  Call
        ``flush()`` to wait for the commits."""
        if self._wal is not None:
            # Durable path: keep a host copy so WAL records slice host
            # memory (byte-identical to the device chunks) instead of
            # paying a device→host read per chunk under the submit lock —
            # converting on whichever side the input already lives on, so
            # neither host nor device inputs pay a redundant round trip.
            if isinstance(data, np.ndarray):
                host = np.asarray(data, np.float32)
                xs = jnp.asarray(host)
            else:
                xs = jnp.asarray(data, jnp.float32)
                host = np.asarray(xs)
        else:
            host, xs = None, jnp.asarray(data, jnp.float32)
        if xs.shape[0] == 0:
            return
        with self._submit_lock:
            self._check_ingestable()
            for i in range(0, xs.shape[0], self._chunk):
                c = xs[i:i + self._chunk]
                if self._max_pending is not None:
                    with self._cv:
                        while self._pending_rows >= self._max_pending:
                            self._cv.wait()
                seq = self._seq
                item = self._make_chunk_item(c, seq)
                if self._wal is not None:
                    # WAL-before-publish: the record is durable before the
                    # commit worker can see the chunk.  A failed append
                    # (e.g. ENOSPC) leaves seq assignment and the log in
                    # sync, but chunks of this call logged *before* the
                    # failure are already accepted — so the engine poisons
                    # itself rather than invite a blind resubmit that
                    # would double-ingest them; recover() replays exactly
                    # the accepted prefix.  Exception: a *transient* fault
                    # (faults.is_transient) on the FIRST chunk of a call
                    # accepted nothing — the call is cleanly rejected and
                    # safe to retry in place, so the engine stays live.
                    try:
                        self._wal.append([(seq, persist.KIND_CHUNK,
                                           {"xs": host[i:i + self._chunk]})])
                    except BaseException as e:
                        if not (i == 0 and faults.is_transient(e)):
                            self._poison("wal append (chunk rejected)", e)
                        raise
                self._seq = seq + 1
                with self._cv:
                    self._queue.append((item, int(c.shape[0])))
                    self._pending += 1
                    self._pending_rows += int(c.shape[0])
                    if self._worker is None:
                        self._worker = threading.Thread(
                            target=self._worker_loop, daemon=True,
                            name=f"{type(self).__name__}-ingest")
                        self._worker.start()
                    self._cv.notify_all()

    def _check_ingestable(self) -> None:
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        if self._needs_recover:
            raise RuntimeError(
                f"durable state found under {self._dur.dir!r}: call "
                "recover() before ingesting (or point durability at an "
                "empty directory)")
        if self._poisoned:
            raise RuntimeError(
                "ingest failed on a durable engine: WAL-logged chunks were "
                "dropped by fail-stop, so in-memory state no longer tracks "
                "the log.  Open a fresh engine on the same directory and "
                "recover() — the WAL replays every accepted chunk.")

    def flush(self) -> None:
        """Block until every queued chunk is committed (and the state is
        materialised).  Re-raises any background ingest failure since the
        last flush — delivered to exactly one caller when several threads
        flush concurrently.  Failure semantics are fail-stop/at-most-once:
        once a chunk fails, the chunks queued behind it are *discarded*
        (never committed out of order, so snapshots stay committed
        prefixes) until the error is consumed here; the caller decides
        what to re-submit.  After a clean flush, the state equals what
        synchronous ingest of the same stream would have produced."""
        with self._cv:
            while self._pending:
                self._cv.wait()
            err, self._ingest_error = self._ingest_error, None
        if err is not None:
            raise RuntimeError(f"background ingest failed:\n{err}")
        with self._lock:
            st = self.state
        jax.block_until_ready(st)

    def close(self) -> None:
        """Commit everything already queued, then stop the worker thread,
        the prepare pool, the query batcher and the durability writers.
        Idempotent; the engine rejects new ingests afterwards (sync
        queries keep working — the batcher drains its pending futures and
        later sync calls take the direct snapshot path).
        Call ``flush()`` first if you need background failures re-raised."""
        self._close_batcher()
        with self._submit_lock:
            if self._closed:
                return
            self._closed = True
            with self._cv:
                worker = self._worker
                if worker is not None:
                    self._queue.append(_STOP)
                    self._cv.notify_all()
        if worker is not None:
            worker.join()
        if self._prep_pool is not None:
            self._prep_pool.shutdown(wait=True)
            self._prep_pool = None
        try:
            if self._ckpt is not None:
                self._ckpt.wait()       # re-raises a failed background save
        finally:
            if self._wal is not None:
                self._wal.close()       # ... without leaking the handle

    def _worker_loop(self) -> None:
        """THE chunk loop: pipelined prepare/commit over the live queue.
        While this thread blocks on chunk k's prepare/commit, the prepare
        pool computes chunks k+1..k+prepare_depth — including chunks that
        were queued after k started (the lookahead pulls from the live
        queue, so one-chunk-per-call producers still pipeline).  Commits
        always apply in submission order (the lookahead deque preserves
        queue order), so any depth is bit-identical to depth 1."""
        ahead: collections.deque = collections.deque()  # (entry, future)
        while True:
            if ahead:
                entry, fut = ahead.popleft()
            else:
                with self._cv:
                    while not self._queue:
                        self._cv.wait()
                    entry = self._queue.popleft()
                if entry is _STOP:
                    return
                fut = None
            item, rows = entry
            try:
                # Fail-stop: after a failure, drop queued chunks (instead
                # of committing a stream with a hole in it) until flush()
                # consumes the error.
                if self._ingest_error is None:
                    if fut is None:
                        fut = self._submit_prepare(item)
                    # top up the lookahead before blocking on this chunk
                    if self._prep_pool is not None:
                        while len(ahead) < self._prepare_depth:
                            with self._cv:
                                nxt = (self._queue.popleft()
                                       if self._queue and
                                       self._queue[0] is not _STOP else None)
                            if nxt is None:
                                break
                            ahead.append((nxt, self._submit_prepare(nxt[0])))
                    prep = fut.result() if hasattr(fut, "result") else fut
                    self._commit_one(prep)
            except BaseException as e:
                with self._cv:
                    self._ingest_error = traceback.format_exc()
                    # A durable engine cannot keep accepting work after a
                    # failed commit: the failed/dropped chunks are already
                    # WAL-logged (= accepted), so continuing would let seq
                    # assignment and snapshot labels drift from the log.
                    # Volatile engines keep the old retry-after-flush
                    # semantics; durable ones direct the caller to
                    # recover(), which replays every logged chunk.
                    if self._dur is not None:
                        self._poison("background commit (chunk accepted)", e)
            finally:
                with self._cv:
                    self._pending -= 1
                    self._pending_rows -= rows
                    self._cv.notify_all()

    def _submit_prepare(self, item: tuple):
        """Dispatch a chunk's prepare: on the pool when pipelined (so it
        overlaps this thread's commit), inline otherwise."""
        if self._prep_pool is not None:
            return self._prep_pool.submit(self._prepare_ready, item)
        return self._prepare_ready(item)

    def _prepare_ready(self, item: tuple):
        return jax.block_until_ready(self._prepare(*item))

    def _commit_one(self, prep) -> None:
        faults.fire(self._fault_scope + "engine.commit")
        with self._lock:
            self.state = st = self._commit(self.state, prep)
            self._version += 1
            self._committed_seq += 1
            seq = self._committed_seq
        # Pace the pipeline outside the lock: queries snapshot the (futures
        # of the) new state immediately; the worker waits here while the
        # prepare pool hashes the next chunk.
        jax.block_until_ready(st)
        if (self._dur is not None
                and seq - self._last_snap_seq >= self._dur.snapshot_every):
            self._write_snapshot(st, seq)

    # --- durability --------------------------------------------------------

    def _write_snapshot(self, st, seq: int) -> None:
        """Background snapshot of the committed state at operation ``seq``
        (commit-worker thread).  The previous snapshot — durable by the
        time the checkpointer accepts a new one — releases its WAL
        segments (compaction) and old snapshot dirs."""
        faults.fire(self._fault_scope + "snapshot.save")
        root = self._dur.dir
        if self._snap_inflight is not None:
            self._ckpt.wait()
            self._wal.compact(self._snap_inflight - 1)
            persist.snapshot.prune(root, keep=self._dur.keep_snapshots)
        self._snap_inflight = seq
        persist.snapshot.async_save(self._ckpt, root, seq, st,
                                    fsync=self._dur.fsync)
        self._wal.rotate()
        self._last_snap_seq = seq

    def _durable_mutate(self, kind: int, arrays: dict,
                        fn: Callable[[Any], Any]) -> None:
        """Apply an out-of-band mutation (e.g. a turnstile delete) with WAL
        logging.  Pending chunks are flushed first so the WAL's append
        order equals the apply order (the recovery replay order); the
        record must be replayable by `_apply_wal_record`.

        The volatile path runs the *same* flush-first protocol (minus the
        WAL write): mutations consume a sequence number and apply after
        every queued chunk either way, so a volatile and a durable engine
        fed the same operation sequence stay bit-identical — and the
        pre-flush means no commit is concurrently advancing
        ``_committed_seq`` while we do."""
        with self._submit_lock:
            self._check_ingestable()
            self.flush()
            if self._wal is not None:
                # A failed append may have left a torn record mid-log, so
                # (like the chunk path) poison rather than invite a retry
                # that would append after garbage bytes; recovery truncates
                # the torn tail and the unacknowledged op is simply absent.
                # A *transient* fault rejected the op before any bytes
                # landed — cleanly retryable, the engine stays live.
                try:
                    self._wal.append([(self._seq, kind, arrays)])
                except BaseException as e:
                    if not faults.is_transient(e):
                        self._poison("wal append (mutation rejected)", e)
                    raise
            # Counters advance once the record is durable; if applying
            # `fn` then fails, the op is on disk and recovery will apply
            # it — the standard WAL-before-apply contract.
            self._seq += 1
            self._committed_seq += 1
            try:
                self._mutate_state(fn)
            except BaseException as e:
                # Durable case: the op is on disk but not in memory —
                # without this the next snapshot would be labelled as if it
                # applied and compaction could drop the record for good.
                # Poison like a failed commit; recovery replays the logged
                # op.  (Volatile engines have no log to drift from.)
                # The exception carries a structured acceptance marker so
                # the cluster's failover can decide resubmission from THIS
                # op's fate — the poison *reason* may describe an earlier
                # op (e.g. a background commit) and must not be consulted.
                if self._wal is not None:
                    self._poison("mutation apply (op accepted)", e)
                    e.wal_accepted = True
                raise
            # Mutations count toward the snapshot cadence like chunk
            # commits (a mutation-heavy workload must not grow the WAL and
            # the recovery replay without bound).  Safe here: the flush
            # above drained the worker and the submit lock blocks new
            # submissions, so no commit races the snapshot bookkeeping.
            if (self._dur is not None and self._committed_seq -
                    self._last_snap_seq >= self._dur.snapshot_every):
                with self._lock:
                    st = self.state
                self._write_snapshot(st, self._committed_seq)

    def recover(self) -> int:
        """Restore from the durability directory: load the newest snapshot,
        then replay the WAL tail through the engine's own two-phase
        prepare/commit path — the recovered state is bit-identical to the
        uninterrupted run (tests/test_persist.py).  Torn WAL tails (a crash
        mid-append) are truncated.  Must be called on a fresh engine,
        before any ingest; returns the number of WAL records replayed."""
        if self._dur is None:
            raise RuntimeError("recover() requires a DurabilityConfig")
        faults.fire(self._fault_scope + "engine.recover")
        with self._submit_lock:
            if self._seq or self._version or self._closed:
                raise RuntimeError("recover() must run on a fresh engine")
            root = self._dur.dir
            snap = persist.snapshot.latest_seq(root)
            if snap is not None:
                st = persist.snapshot.load(root, snap, self.state)
                with self._lock:
                    self.state = self._place_state(st)
                self._seq = self._committed_seq = snap
                self._version = snap
                self._last_snap_seq = snap
            n = 0
            # Streaming replay: one decoded record in memory at a time, so
            # recovering a long WAL tail doesn't double peak host memory.
            for rec in self._wal.iter_replay(after=self._committed_seq - 1):
                if rec.seq != self._committed_seq:
                    raise RuntimeError(
                        f"WAL gap: expected seq {self._committed_seq}, "
                        f"found {rec.seq}")
                if rec.kind == persist.KIND_CHUNK:
                    chunk = jnp.asarray(rec.arrays["xs"], jnp.float32)
                    item = self._make_chunk_item(chunk, rec.seq)
                    prep = self._prepare_ready(item)
                    with self._lock:
                        self.state = self._commit(self.state, prep)
                        self._version += 1
                else:
                    self._apply_wal_record(rec.kind, rec.arrays)
                self._committed_seq += 1
                self._seq = self._committed_seq
                n += 1
            self._wal.truncate_torn_tail()
            self._needs_recover = False
            with self._lock:
                st = self.state
            jax.block_until_ready(st)
            return n

    # --- snapshots, caching, queries ---------------------------------------

    def snapshot(self):
        """Atomically read ``(state, version)`` — the lock-consistent way to
        serve a query batch against one committed prefix."""
        with self._lock:
            return self.state, self._version

    def _query_snapshot_ctx(self):
        """Everything one query tick shares, captured in one consistent
        read (services with per-version caches override to resolve them
        here — e.g. the SW-AKDE grid — so a whole coalesced batch shares
        one cache entry and can never mix versions)."""
        return self.snapshot()

    @property
    def version(self) -> int:
        """Commits applied so far (every commit invalidates `cached`)."""
        with self._lock:
            return self._version

    # --- observability ------------------------------------------------------

    def health(self) -> dict:
        """One consistent health report (DESIGN §14): lifecycle state
        (``live`` / ``poisoned`` / ``needs_recover`` / ``closed``), the
        poison reason if any, durable progress (last committed op seq vs
        next to assign), and ingest-queue depth.  The cluster coordinator
        polls this to drive failover."""
        with self._cv:
            queue_depth = self._pending
            queued_rows = self._pending_rows
        state = ("closed" if self._closed
                 else "poisoned" if self._poisoned
                 else "needs_recover" if self._needs_recover
                 else "live")
        return {"state": state,
                "poison_reason": self._poison_reason,
                "last_committed_seq": self._committed_seq,
                "next_seq": self._seq,
                "version": self.version,
                "queue_depth": queue_depth,
                "queued_rows": queued_rows,
                "durable": self._dur is not None}

    def stats(self) -> dict:
        """`health()` plus the query-scheduler counters (when batching
        has served anything)."""
        out = self.health()
        if self._batcher is not None:
            out["batcher"] = self._batcher.stats()
        return out

    def cached(self, name: str, version: int, compute: Callable[[], Any]):
        """Memoise a pure function of the snapshot at ``version`` (e.g. the
        SW-AKDE grid-estimate table).  A commit bumps the version, so stale
        entries are never served; concurrent same-version computes are
        benign (identical values, last install wins)."""
        with self._lock:
            ent = self._snap_cache.get(name)
            if ent is not None and ent[0] == version:
                return ent[1]
        val = compute()                      # outside the lock: may be slow
        with self._lock:
            ent = self._snap_cache.get(name)
            if ent is None or ent[0] <= version:
                self._snap_cache[name] = (version, val)
        return val

    def _mutate_state(self, fn: Callable[[Any], Any]) -> None:
        """Apply an out-of-band state update atomically; bumps the version
        so snapshot caches invalidate.  Services route mutations through
        `_durable_mutate` (flush-first + seq accounting + WAL when
        durable); this is the raw apply step."""
        with self._lock:
            self.state = fn(self.state)
            self._version += 1

    def _query_blocks(self, fn: Callable[[jax.Array], Any], qs: jax.Array):
        """Run ``fn`` over ``qs`` in ``query_block``-row blocks and
        concatenate the result pytrees (B = 0 → one empty-engine call)."""
        qb = self._query_block
        out = [fn(qs[i:i + qb]) for i in range(0, qs.shape[0], qb)]
        if not out:
            return fn(qs)
        if len(out) == 1:
            return out[0]
        return jax.tree.map(lambda *parts: jnp.concatenate(parts), *out)


# The runtime is sketch-agnostic; the serving layer refers to it by either
# name (the engine *is* the streaming service base class).
StreamingService = SketchEngine
