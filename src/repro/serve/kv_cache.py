"""KV/state cache construction + shardings for batched decode.

Cache layout per family (stacked on the layer dim, scanned as xs/ys):
  dense/vlm : k/v (L, B, S, Hkv, dh)
  moe       : dense-layer k/v + moe-layer k/v; MLA stores the latent cache
              (L, B, S, d_c) + shared rotary key (L, B, S, r) instead
  hybrid    : mamba SSD state (G, E, B, H, N, P) + conv tails, plus the
              shared-attention block's per-group k/v (G, B, S, Hkv, dh)
  ssm       : mLSTM (pairs, B, H, P, P) matrix memories + sLSTM scalars
  encdec    : decoder self k/v + precomputed cross k/v (L, B, Te, Hkv, dh)

Sharding: batch over ("pod","data") when divisible; cache sequence over
"model" (and over ("data","model") when batch == 1, e.g. long_500k) — decode
attention contracts over the sharded S dim and XLA inserts the distributed
softmax reductions (flash-decode-style LSE combine).

Sketch attention (gemma long_500k): per-block SRP signatures
(L, B, nb, SIG_BITS) ride along with the cache (DESIGN.md §5.4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

SIG_BITS = 64
SKETCH_BLOCK = 512


def _shd(mesh, *axes):
    if mesh is None:
        return None
    ok = []
    for a in axes:
        if isinstance(a, tuple):
            a = tuple(x for x in a if x in mesh.axis_names) or None
        elif a is not None and a not in mesh.axis_names:
            a = None
        ok.append(a)
    return NamedSharding(mesh, P(*ok))


def cache_axes(cfg: ModelConfig, B: int, mesh: Optional[Mesh]):
    """(batch axes, seq axes) for cache tensors given the batch size."""
    if mesh is None:
        return None, None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    if B % max(dp_size, 1) == 0 and B >= dp_size:
        return dp, ("model",)
    # batch too small (long_500k B=1): replicate batch, shard seq harder
    return None, tuple(a for a in ("data", "model") if a in mesh.axis_names)


def init_cache(cfg: ModelConfig, B: int, s_max: int,
               mesh: Optional[Mesh] = None, sketch: bool = False) -> dict:
    dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    dh = cfg.resolved_head_dim
    ba, sa = cache_axes(cfg, B, mesh)

    def kv(L, S):
        shape = (L, B, S, cfg.n_kv_heads, dh)
        sh = _shd(mesh, None, ba, sa, None, None)
        z = jnp.zeros(shape, dt)
        return z if sh is None else jax.device_put(z, sh)

    cache: dict = {"length": jnp.zeros((), jnp.int32)}

    if cfg.family in ("dense", "vlm"):
        cache["k"], cache["v"] = kv(cfg.n_layers, s_max), kv(cfg.n_layers, s_max)
    elif cfg.family == "moe":
        nd, nm = cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers
        if nd:
            cache["dk"], cache["dv"] = kv(nd, s_max), kv(nd, s_max)
        if cfg.use_mla:
            csh = _shd(mesh, None, ba, sa, None)
            c = jnp.zeros((nm, B, s_max, cfg.mla_d_c), dt)
            kr = jnp.zeros((nm, B, s_max, cfg.mla_rope_dim), dt)
            cache["c"] = c if csh is None else jax.device_put(c, csh)
            cache["kr"] = kr if csh is None else jax.device_put(kr, csh)
        else:
            cache["k"], cache["v"] = kv(nm, s_max), kv(nm, s_max)
    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        E = cfg.attn_every
        d_in = cfg.ssm_expand * cfg.d_model
        H = d_in // cfg.ssm_head_dim
        conv_ch = d_in + 2 * cfg.ssm_state
        ssm_sh = _shd(mesh, None, None, ba, "model", None, None)
        st = jnp.zeros((G, E, B, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32)
        cache["ssm_state"] = st if ssm_sh is None else jax.device_put(st, ssm_sh)
        cache["conv_buf"] = jnp.zeros((G, E, B, cfg.ssm_conv - 1, conv_ch), dt)
        cache["k"], cache["v"] = kv(G, s_max), kv(G, s_max)
    elif cfg.family == "ssm":
        pairs = cfg.n_layers // 2
        d_in = 2 * cfg.d_model
        Pm = d_in // cfg.n_heads
        cache["mlstm_C"] = jnp.zeros((pairs, B, cfg.n_heads, Pm, Pm), jnp.float32)
        cache["mlstm_n"] = jnp.zeros((pairs, B, cfg.n_heads, Pm), jnp.float32)
        cache["mlstm_m"] = jnp.full((pairs, B, cfg.n_heads), -jnp.inf, jnp.float32)
        for nm_ in ("c", "n", "h", "m"):
            init = -jnp.inf if nm_ == "m" else 0.0
            cache[f"slstm_{nm_}"] = jnp.full((pairs, B, cfg.d_model), init, jnp.float32)
    elif cfg.family == "encdec":
        cache["k"], cache["v"] = kv(cfg.n_layers, s_max), kv(cfg.n_layers, s_max)
        cache["xk"] = kv(cfg.n_layers, cfg.enc_frames)
        cache["xv"] = kv(cfg.n_layers, cfg.enc_frames)
    else:
        raise ValueError(cfg.family)

    if sketch:
        import os
        L_sig = cache["k"].shape[0] if "k" in cache else cfg.n_layers
        if os.environ.get("REPRO_SPLIT_LOCAL_DECODE") == "1" \
                and cfg.local_global_period > 0 and cfg.local_window > 0:
            # split-scan decode keeps signatures for global layers only
            L_sig = cfg.n_layers // cfg.local_global_period
        nb = s_max // SKETCH_BLOCK
        cache["block_sigs"] = jnp.zeros((L_sig, B, nb, SIG_BITS), jnp.bool_)
    return cache


def cache_specs(cache: dict, cfg: ModelConfig, B: int, mesh: Mesh) -> dict:
    """NamedShardings matching init_cache's placement (for jit in_shardings)."""
    ba, sa = cache_axes(cfg, B, mesh)

    def _div(axes, dim):
        if axes is None:
            return None
        sz = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            sz *= mesh.shape.get(a, 1)
        return axes if dim % sz == 0 else None

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        nd = leaf.ndim
        if name in ("k", "v", "dk", "dv", "xk", "xv"):
            # cross caches (xk/xv) have Te=1500 — shard seq only if divisible
            return _shd(mesh, None, _div(ba, leaf.shape[1]),
                        _div(sa, leaf.shape[2]), None, None)
        if name in ("c", "kr"):
            return _shd(mesh, None, ba, sa, None)
        if name == "ssm_state":
            return _shd(mesh, None, None, ba, "model", None, None)
        if name == "block_sigs":
            return _shd(mesh, None, ba, None, None)
        return _shd(mesh, *([None] * nd))
    return jax.tree_util.tree_map_with_path(spec, cache)


def cache_bytes(cache: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
