"""Batched single-token decode (`serve_step`) for every architecture family.

decode_* / long_* dry-run shapes lower THIS function (one new token against
a kv_cache of seq_len), not train_step.  Layer stacks scan with the cache as
scan xs/ys (the MaxText pattern — keeps HLO O(1) in depth and lets XLA alias
the cache update in place).

Sketch attention (the paper's S-ANN adapted to decode, DESIGN.md §5.4): when
enabled, *global* attention layers prune KV blocks whose SRP signature does
not collide with the query signature — the jnp semantics here; the Pallas
kernel (`repro.kernels.sketch_decode_attn`) is the TPU hot path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers, mla, moe, model as model_lib, ssm, xlstm
from repro.parallel.sharding import NULL_CTX, make_ctx
from .kv_cache import SIG_BITS, SKETCH_BLOCK

_SIG_SEED = 42
_MIN_MATCH_FRAC = 0.25


def rope_token(x, position, theta):
    """x (B, 1, H, dh) rotated at scalar position `position`."""
    if theta <= 0:
        return x
    B = x.shape[0]
    pos = jnp.full((B, 1), position, jnp.int32)
    return layers.rope(x, pos, theta)


def decode_attention(q, k_cache, v_cache, length, *, window=0, softcap=0.0,
                     extra_mask=None, pos_offset=0):
    """q (B, 1, H, dh); caches (B, S, Hkv, dh); attend over [0, length].
    pos_offset: global position of cache row 0 (windowed local-layer reads
    pass a dynamic offset)."""
    B, _, H, dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qs = q.reshape(B, Hkv, G, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qs, k_cache.astype(jnp.float32)) * dh**-0.5
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = pos_offset + jnp.arange(S)
    mask = pos <= length
    if isinstance(window, jax.Array):
        mask = mask & ((window <= 0) | (pos > length - window))
    elif window > 0:
        mask = mask & (pos > length - window)
    mask = jnp.broadcast_to(mask[None, None, None, :], s.shape) \
        if extra_mask is None else (mask[None, None, None, :] & extra_mask)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, -1e30))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H * dh)


def _sig_proj(dh: int):
    return jax.random.normal(jax.random.PRNGKey(_SIG_SEED), (dh, SIG_BITS))


def _sig_bits(x, proj):
    """x (..., dh) → (..., SIG_BITS) bool SRP signature."""
    return (x.astype(jnp.float32) @ proj) >= 0.0


def sketch_block_mask(q, sigs, length):
    """q (B,1,H,dh), sigs (B, nb, SIG_BITS) → extra_mask (B,1,1,S) for
    decode_attention: positions in blocks with < min_match colliding bits
    are pruned (paper §3 bucket collision, block granularity)."""
    B, _, H, dh = q.shape
    proj = _sig_proj(dh)
    q_sig = _sig_bits(q.mean(axis=2)[:, 0], proj)               # (B, bits)
    match = jnp.einsum("bnk,bk->bn", sigs.astype(jnp.int32),
                       q_sig.astype(jnp.int32))                 # (B, nb)
    live = match >= int(SIG_BITS * _MIN_MATCH_FRAC)
    nb = sigs.shape[1]
    pos_block = jnp.arange(nb * SKETCH_BLOCK) // SKETCH_BLOCK
    # always keep the most recent block (contains the current token)
    cur_block = length // SKETCH_BLOCK
    live = live | (jnp.arange(nb) == cur_block)[None]
    mask = live[:, pos_block]                                   # (B, S)
    return mask[:, None, None, :]


def _update_sigs(sigs, k_new, length):
    """OR the new key's signature into its block. sigs (B, nb, bits);
    k_new (B, 1, Hkv, dh)."""
    proj = _sig_proj(k_new.shape[-1])
    bits = _sig_bits(k_new.mean(axis=2)[:, 0], proj)            # (B, bits)
    blk = length // SKETCH_BLOCK
    cur = jax.lax.dynamic_index_in_dim(sigs, blk, axis=1, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(
        sigs, cur | bits, blk, axis=1)


def _attn_decode_block(p, x, kc, vc, length, cfg: ModelConfig, *, window,
                       sigs=None, rope_theta=None):
    """Shared dense-attention decode sub-block.  Returns (x, kc, vc, sigs)."""
    dh = cfg.resolved_head_dim
    B = x.shape[0]
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.n_heads, dh)
    k = (h @ p["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, dh)
    v = (h @ p["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    q = rope_token(q, length, theta)
    k = rope_token(k, length, theta)
    kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, length, 0, 0))
    vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, length, 0, 0))
    extra = None
    if sigs is not None:
        sigs = _update_sigs(sigs, k, length)
        is_global = window <= 0 if not isinstance(window, jax.Array) \
            else (window <= 0)
        mask = sketch_block_mask(q, sigs, length)
        if isinstance(window, jax.Array):
            extra = jnp.where(is_global, mask, jnp.ones_like(mask))
        elif is_global:
            extra = mask
    a = decode_attention(q, kc, vc, length, window=window,
                         softcap=cfg.attn_softcap, extra_mask=extra)
    a = (a @ p["attn"]["wo"])
    if "ln1_post" in p:
        a = layers.rms_norm(a, p["ln1_post"], cfg.norm_eps)
    return x + a.astype(x.dtype), kc, vc, sigs


def _attn_decode_block_local(p, x, kc, vc, length, cfg: ModelConfig):
    """Local-layer decode: reads only the last `local_window` cache rows
    (static slice size, dynamic start) — a 512x traffic cut at 500k for
    gemma-style 5:1 stacks (§Perf hillclimb 3)."""
    W = min(cfg.local_window, kc.shape[1])
    dh = cfg.resolved_head_dim
    B = x.shape[0]
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.n_heads, dh)
    k = (h @ p["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, dh)
    v = (h @ p["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
    q = rope_token(q, length, cfg.rope_theta)
    k = rope_token(k, length, cfg.rope_theta)
    kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, length, 0, 0))
    vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, length, 0, 0))
    start = jnp.clip(length - W + 1, 0, kc.shape[1] - W)
    kw = lax.dynamic_slice(kc, (0, start, 0, 0),
                           (B, W, cfg.n_kv_heads, dh))
    vw = lax.dynamic_slice(vc, (0, start, 0, 0),
                           (B, W, cfg.n_kv_heads, dh))
    a = decode_attention(q, kw, vw, length, window=cfg.local_window,
                         softcap=cfg.attn_softcap, pos_offset=start)
    a = a @ p["attn"]["wo"]
    if "ln1_post" in p:
        a = layers.rms_norm(a, p["ln1_post"], cfg.norm_eps)
    return x + a.astype(x.dtype), kc, vc


def _mlp_block(p, x, cfg):
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    m = layers.mlp(p["mlp"], h,
                   act=jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu)
    if "ln2_post" in p:
        m = layers.rms_norm(m, p["ln2_post"], cfg.norm_eps)
    return x + m


def make_serve_step(cfg: ModelConfig, mesh=None, sketch: bool = False):
    ctx = make_ctx(mesh)

    def serve_step(params, cache, tokens):
        """tokens (B, 1) int32 → (logits (B, 1, vocab) fp32, new cache)."""
        length = cache["length"]
        x = layers.embed(params["embedding"], tokens, ctx)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

        import os
        split_decode = os.environ.get("REPRO_SPLIT_LOCAL_DECODE") == "1"
        # NOTE (§Perf hillclimb 3, REFUTED): windowed local-layer cache reads
        # regress when the cache seq dim is sharded over "model" — the
        # dynamic window slice spans shards and XLA reshards per layer
        # (gemma2 decode_32k memory term 2.24 s → 7.24 s). Off by default;
        # the Pallas sketch_decode_attn kernel is the real TPU pruning path.
        if split_decode and cfg.family in ("dense", "vlm") \
                and cfg.local_global_period > 0 and cfg.local_window > 0:
            # static local/global superblock split: local layers read only a
            # `local_window` slice of the cache (see §Perf hillclimb 3)
            per = cfg.local_global_period
            n_per = cfg.n_layers // per           # full periods
            tail = cfg.n_layers - n_per * per     # trailing local layers
            has_sig = sketch and "block_sigs" in cache

            def slice_layers(tree, lo, hi, reshape=None):
                def f(a):
                    s = a[lo:hi]
                    return s.reshape(reshape + s.shape[1:]) if reshape else s
                return jax.tree.map(f, tree)

            blocks = params["blocks"]
            head = slice_layers(blocks, 0, n_per * per, (n_per, per))
            kc_h = cache["k"][: n_per * per].reshape(
                (n_per, per) + cache["k"].shape[1:])
            vc_h = cache["v"][: n_per * per].reshape(
                (n_per, per) + cache["v"].shape[1:])
            sigs_h = cache["block_sigs"] if has_sig else jnp.zeros(
                (n_per, 1), jnp.bool_)

            def period_body(x, inp):
                ps, kcs, vcs, sg = inp
                def local_one(x, inp2):
                    pl, kl, vl = inp2
                    x, kl, vl = _attn_decode_block_local(
                        pl, x, kl, vl, length, cfg)
                    x = _mlp_block(pl, x, cfg)
                    return x, (kl, vl)
                loc = jax.tree.map(lambda a: a[: per - 1], ps)
                x, (nkl, nvl) = lax.scan(
                    local_one, x, (loc, kcs[: per - 1], vcs[: per - 1]))
                pg = jax.tree.map(lambda a: a[per - 1], ps)
                x, kg, vg, sg2 = _attn_decode_block(
                    pg, x, kcs[per - 1], vcs[per - 1], length, cfg,
                    window=jnp.int32(0), sigs=sg if has_sig else None)
                x = _mlp_block(pg, x, cfg)
                # local/global caches stacked separately — a per-period
                # concatenate would copy the whole cache every step
                return x, (nkl, nvl, kg, vg, sg2 if has_sig else sg)

            x, (nkl_h, nvl_h, nkg_h, nvg_h, nsig) = lax.scan(
                period_body, x, (head, kc_h, vc_h, sigs_h))
            nk = jnp.concatenate([nkl_h, nkg_h[:, None]], axis=1).reshape(
                (n_per * per,) + cache["k"].shape[1:])
            nv = jnp.concatenate([nvl_h, nvg_h[:, None]], axis=1).reshape(
                (n_per * per,) + cache["v"].shape[1:])
            if tail:
                tail_p = slice_layers(blocks, n_per * per, cfg.n_layers)
                def local_tail(x, inp2):
                    pl, kl, vl = inp2
                    x, kl, vl = _attn_decode_block_local(
                        pl, x, kl, vl, length, cfg)
                    x = _mlp_block(pl, x, cfg)
                    return x, (kl, vl)
                x, (nk_t, nv_t) = lax.scan(
                    local_tail, x,
                    (tail_p, cache["k"][n_per * per:], cache["v"][n_per * per:]))
                nk = jnp.concatenate([nk, nk_t])
                nv = jnp.concatenate([nv, nv_t])
            cache = dict(cache, k=nk, v=nv)
            if has_sig:
                cache = dict(cache, block_sigs=nsig)

        elif cfg.family in ("dense", "vlm"):
            wins = model_lib.window_pattern(cfg)
            has_sig = sketch and "block_sigs" in cache
            if has_sig:
                def body(x, inp):
                    p, kc, vc, win, sg = inp
                    x, kc, vc, sg = _attn_decode_block(
                        p, x, kc, vc, length, cfg, window=win, sigs=sg)
                    x = _mlp_block(p, x, cfg)
                    return x, (kc, vc, sg)
                x, (nk, nv, nsig) = lax.scan(
                    body, x, (params["blocks"], cache["k"], cache["v"], wins,
                              cache["block_sigs"]))
                cache = dict(cache, k=nk, v=nv, block_sigs=nsig)
            else:
                def body2(x, inp):
                    p, kc, vc, win = inp
                    x, kc, vc, _ = _attn_decode_block(
                        p, x, kc, vc, length, cfg, window=win, sigs=None)
                    x = _mlp_block(p, x, cfg)
                    return x, (kc, vc)
                x, (nk, nv) = lax.scan(
                    body2, x, (params["blocks"], cache["k"], cache["v"], wins))
                cache = dict(cache, k=nk, v=nv)

        elif cfg.family == "moe":
            B = tokens.shape[0]
            if cfg.n_dense_layers:
                def dbody(x, inp):
                    p, kc, vc = inp
                    x, kc, vc, _ = _attn_decode_block(
                        p, x, kc, vc, length, cfg, window=0)
                    x = _mlp_block(p, x, cfg)
                    return x, (kc, vc)
                x, (ndk, ndv) = lax.scan(
                    dbody, x, (params["dense_blocks"], cache["dk"], cache["dv"]))
                cache = dict(cache, dk=ndk, dv=ndv)

            cf = max(cfg.capacity_factor, 8.0)  # tiny T at decode
            if cfg.use_mla:
                def mbody(x, inp):
                    p, cc, krc = inp
                    x, cc, krc = _mla_decode_block(p, x, cc, krc, length, cfg)
                    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
                    m, _ = moe.moe(p["moe"], h, topk=cfg.moe_topk,
                                   capacity_factor=cf, ctx=ctx)
                    return x + m, (cc, krc)
                x, (nc, nkr) = lax.scan(
                    mbody, x, (params["moe_blocks"], cache["c"], cache["kr"]))
                cache = dict(cache, c=nc, kr=nkr)
            else:
                def mbody(x, inp):
                    p, kc, vc = inp
                    x, kc, vc, _ = _attn_decode_block(
                        p, x, kc, vc, length, cfg, window=0)
                    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
                    m, _ = moe.moe(p["moe"], h, topk=cfg.moe_topk,
                                   capacity_factor=cf, ctx=ctx)
                    return x + m, (kc, vc)
                x, (nk, nv) = lax.scan(
                    mbody, x, (params["moe_blocks"], cache["k"], cache["v"]))
                cache = dict(cache, k=nk, v=nv)

        elif cfg.family == "hybrid":
            def gbody(x, inp):
                pm, st, cb, kc, vc = inp
                def one(carry, inp2):
                    x = carry
                    p, st_l, cb_l = inp2
                    h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
                    o, nc = ssm.mamba2_decode_step(
                        p["mixer"], h, ssm.SSMCache(st_l, cb_l),
                        state=cfg.ssm_state, expand=cfg.ssm_expand,
                        head_dim=cfg.ssm_head_dim, norm_eps=cfg.norm_eps)
                    return x + o, (nc.state, nc.conv_buf)
                x, (nst, ncb) = lax.scan(one, x, (pm, st, cb))
                p = params["shared_attn"]
                x, kc, vc, _ = _attn_decode_block(
                    p, x, kc, vc, length, cfg, window=0)
                x = _mlp_block(p, x, cfg)
                return x, (nst, ncb, kc, vc)
            x, (nst, ncb, nk, nv) = lax.scan(
                gbody, x, (params["mamba_blocks"], cache["ssm_state"],
                           cache["conv_buf"], cache["k"], cache["v"]))
            cache = dict(cache, ssm_state=nst, conv_buf=ncb, k=nk, v=nv)

        elif cfg.family == "ssm":
            d_in = 2 * cfg.d_model
            Pm = d_in // cfg.n_heads
            def pbody(x, inp):
                # x (B, d); the cells consume (B, 1, d) sequences of length 1
                (pm, psl, C, n, m, sc, sn, sh, sm) = inp
                h = layers.rms_norm(x, pm["ln"], cfg.norm_eps)[:, None]
                o, st = xlstm.mlstm(pm["mixer"], h, n_heads=cfg.n_heads,
                                    norm_eps=cfg.norm_eps,
                                    state=xlstm.MLSTMState(C, n, m))
                x = x + o[:, 0]
                h = layers.rms_norm(x, psl["ln"], cfg.norm_eps)[:, None]
                o2, st2 = xlstm.slstm(psl["mixer"], h, n_heads=cfg.n_heads,
                                      norm_eps=cfg.norm_eps,
                                      state=xlstm.SLSTMState(sc, sn, sh, sm))
                x = x + o2[:, 0]
                hf = layers.rms_norm(x, psl["ln_ffn"], cfg.norm_eps)
                x = x + layers.mlp(psl["ffn"], hf)
                return x, (st.C, st.n, st.m, st2.c, st2.n, st2.h, st2.m)
            x1 = x[:, 0]
            x1, (nC, nn, nm, nsc, nsn, nsh, nsm) = lax.scan(
                pbody, x1,
                (params["mlstm_blocks"], params["slstm_blocks"],
                 cache["mlstm_C"], cache["mlstm_n"], cache["mlstm_m"],
                 cache["slstm_c"], cache["slstm_n"], cache["slstm_h"],
                 cache["slstm_m"]))
            x = x1[:, None]
            cache = dict(cache, mlstm_C=nC, mlstm_n=nn, mlstm_m=nm,
                         slstm_c=nsc, slstm_n=nsn, slstm_h=nsh, slstm_m=nsm)

        elif cfg.family == "encdec":
            # absolute sinusoidal position of the current token
            sin_tab = model_lib._sinusoid(cache["k"].shape[2], cfg.d_model, x.dtype)
            x = x + lax.dynamic_slice_in_dim(sin_tab[0], length, 1, axis=0)[None]
            def ebody(x, inp):
                p, kc, vc, xk, xv = inp
                x, kc, vc, _ = _attn_decode_block(
                    p, x, kc, vc, length, cfg, window=0, rope_theta=0.0)
                # cross attention over the precomputed encoder cache
                B = x.shape[0]
                dh = cfg.resolved_head_dim
                h = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
                q = (h @ p["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, dh)
                a = decode_attention(q, xk, xv, jnp.int32(xk.shape[1] - 1))
                x = x + (a @ p["xattn"]["wo"]).astype(x.dtype)
                x = _mlp_block(p, x, cfg)
                return x, (kc, vc)
            x, (nk, nv) = lax.scan(
                ebody, x, (params["dec_blocks"], cache["k"], cache["v"],
                           cache["xk"], cache["xv"]))
            cache = dict(cache, k=nk, v=nv)
        else:
            raise ValueError(cfg.family)

        x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = layers.unembed(params["embedding"], x, ctx, cfg.final_softcap)
        cache = dict(cache, length=length + 1)
        return logits, cache

    return serve_step


def _mla_decode_block(p, x, cc, krc, length, cfg: ModelConfig):
    """Absorbed MLA decode: score against the latent cache directly."""
    B = x.shape[0]
    H, dh, r = cfg.n_heads, cfg.resolved_head_dim, cfg.mla_rope_dim
    d_c = cfg.mla_d_c
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    pos = jnp.full((B, 1), length, jnp.int32)
    c_new, kr_new = mla.mla_latent(p["attn"], h, pos, rope_dim=r,
                                   rope_theta=cfg.rope_theta,
                                   norm_eps=cfg.norm_eps)
    cc = lax.dynamic_update_slice(cc, c_new.astype(cc.dtype), (0, length, 0))
    krc = lax.dynamic_update_slice(krc, kr_new.astype(krc.dtype), (0, length, 0))
    q_nope, q_rope = mla.mla_queries(
        p["attn"], h, pos, n_heads=H, head_dim=dh, rope_dim=r,
        rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
    w_uk = p["attn"]["w_uk"].reshape(d_c, H, dh)
    q_abs = jnp.einsum("bqhd,chd->bhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))                    # (B,H,d_c)
    s = jnp.einsum("bhc,bsc->bhs", q_abs, cc.astype(jnp.float32)) \
        + jnp.einsum("bqhr,bsr->bhs", q_rope.astype(jnp.float32),
                     krc.astype(jnp.float32))
    s = s / math.sqrt(dh + r)
    S = cc.shape[1]
    mask = jnp.arange(S) <= length
    s = jnp.where(mask[None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsc->bhc", w, cc.astype(jnp.float32))   # (B,H,d_c)
    w_uv = p["attn"]["w_uv"].reshape(d_c, H, dh)
    o = jnp.einsum("bhc,chd->bhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * dh) @ p["attn"]["w_o"]
    return x + o.astype(x.dtype), cc, krc


def encode_cross_cache(params, cfg: ModelConfig, frames, cache):
    """Run the encoder once and fill the per-layer cross-attention k/v cache
    (whisper-style serving prefill)."""
    from repro.models.model import _scan_stack, _sinusoid

    B, Te, _ = frames.shape
    enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))
    x = frames.astype(jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                      else jnp.float32)
    x = x + _sinusoid(Te, cfg.d_model, x.dtype)

    def enc_block(p, x, _):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = layers.attention(p["attn"], h, enc_pos, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=False, norm_eps=cfg.norm_eps)
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, act=jax.nn.gelu)

    enc, _ = _scan_stack(enc_block, params["enc_blocks"], x,
                         jnp.zeros((cfg.n_enc_layers,), jnp.int32), cfg.remat)
    enc = layers.rms_norm(enc, params["enc_ln_f"], cfg.norm_eps)
    dh = cfg.resolved_head_dim

    def per_layer(p):
        xk = (enc @ p["xattn"]["wk"]).reshape(B, Te, cfg.n_kv_heads, dh)
        xv = (enc @ p["xattn"]["wv"]).reshape(B, Te, cfg.n_kv_heads, dh)
        return xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16)

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return dict(cache, xk=xk, xv=xv)
