"""TenantFleet: many small sketches behind one stacked device state.

The core tenant-axis machinery (`repro.core.fleet`) turns T independent
sketches into ONE stacked pytree with vmapped ingest/query — this module
adds the operational layer that makes "T" elastic:

  * **LRU hot-set** — at most ``hot_slots`` tenants live in the stacked
    device pytree at once (slot map tenant_id → row).  Touching a tenant
    (ingest or query) activates it: a free slot if any, else the
    least-recently-used *unpinned* tenant is evicted — its row is pulled
    to host and spilled through the existing `repro.persist.snapshot`
    layer (``<dir>/tenants/t_<id>/step_<seq>``) — and the activated
    tenant's state is recovered on demand (newest spill, else empty).
  * **Mixed-chunk ingest** — ``ingest(xs, tids)`` takes one chunk tagged
    with per-point tenant ids.  Chunks whose *distinct* tenant set exceeds
    ``hot_slots`` are split (in stream order) into sub-chunks that fit;
    each sub-chunk is one operation: one WAL record, one routed vmapped
    commit (`core.fleet`), one device dispatch.
  * **Durability** — with ``snapshot_dir`` set, every operation appends a
    `persist.KIND_TENANT_CHUNK` WAL record (the engine's chunk framing
    plus one extra ``tids`` array) before committing, and the hot stacked
    state + slot/LRU maps are snapshotted every ``snapshot_every``
    operations.  ``recover()`` = newest fleet snapshot + WAL-tail replay
    through this same ingest path.

Determinism contract (what makes recovery bit-identical): every
state-changing decision in the ingest path — chunk splitting, slot
assignment, LRU victims, spill contents, S-ANN per-tenant chunk keys
(``fold_in(fold_in(base, seq), tenant_id)``) — is a pure function of the
WAL op sequence.  Queries may *also* activate/evict tenants (they are not
WAL-logged), which can leave the hot-set membership and spill files ahead
of what a replay reproduces; that is safe because a spill written at
global seq ``s`` always holds exactly the tenant's state after its
WAL-recorded ingests with seq <= ``s`` (per-tenant history is
WAL-determined), and activation always loads the newest spill with seq <=
the current op seq — so replayed activations can never observe a
"future" or torn tenant state (DESIGN.md §15.3).
"""
from __future__ import annotations

import dataclasses
import pathlib
import threading
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.core import fleet, lsh, race, sann, swakde

_KINDS = ("race", "swakde", "sann")


@dataclasses.dataclass(frozen=True)
class TenantFleetConfig:
    """Knobs for a `TenantFleet`.

    ``kind`` selects the sketch family; the LSH family, empty row and
    ingest/query paths follow.  ``hot_slots`` is the stacked-state tenant
    capacity T (device memory = T x one sketch); every sketch in the fleet
    shares one set of LSH params derived from ``seed``.  The sketch
    hyper-parameters mirror the single-sketch service configs: ``L / W /
    k`` for RACE and SW-AKDE (+ ``window`` / ``eh_eps`` / ``w`` /
    ``heavy_cell_cap`` for SW-AKDE), and the `core.sann.SANNConfig`
    fields for S-ANN.  ``snapshot_dir`` opts into WAL + snapshot
    durability exactly like the engine services."""
    kind: str
    dim: int
    hot_slots: int = 8
    seed: int = 0
    # RACE / SW-AKDE
    L: Optional[int] = None
    W: int = 64
    k: Optional[int] = None
    w: float = 1.0
    window: int = 1024
    eh_eps: float = 0.2
    heavy_cell_cap: int = 0
    # S-ANN
    n_max: int = 1024
    eta: float = 0.0
    r: float = 0.5
    c: float = 2.0
    # durability
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 64
    wal_fsync: bool = False

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind={self.kind!r}: expected one of {_KINDS}")
        if self.hot_slots < 1:
            raise ValueError(f"hot_slots={self.hot_slots} (< 1)")


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


class TenantFleet:
    """Elastic multi-tenant sketch service over one stacked device state.

    Synchronous by design: the perf win of the fleet is *dispatch
    amortization* (one vmapped commit instead of T), not pipelining —
    layering the engine's two-phase prepare thread per tenant back on top
    would reintroduce exactly the per-tenant overhead this removes.  All
    public methods are thread-safe under one lock."""

    def __init__(self, cfg: TenantFleetConfig):
        self.cfg = cfg
        self._lock = threading.RLock()
        key = jax.random.PRNGKey(cfg.seed)
        self._base_key = jax.random.fold_in(key, 1)  # per-op S-ANN keys
        T = cfg.hot_slots
        if cfg.kind == "race":
            L = cfg.L or 8
            k = cfg.k or 4
            self._params = lsh.init_srp(
                jax.random.fold_in(key, 0), cfg.dim, L, k, cfg.W)
            self._empty = race.race_init(L, cfg.W)
        elif cfg.kind == "swakde":
            L = cfg.L or 8
            k = cfg.k or 2
            self._scfg = swakde.SWAKDEConfig(
                L=L, W=cfg.W, window=cfg.window, eh_eps=cfg.eh_eps,
                heavy_cell_cap=cfg.heavy_cell_cap)
            self._params = lsh.init_pstable(
                jax.random.fold_in(key, 0), cfg.dim, L, k, cfg.w, cfg.W)
            self._empty = swakde.swakde_init(self._scfg)
        else:
            base = sann.SANNConfig(
                dim=cfg.dim, n_max=cfg.n_max, eta=cfg.eta, r=cfg.r,
                c=cfg.c, w=cfg.w, L=cfg.L, k=cfg.k)
            self._sann_cfg, self._params, self._empty = sann.sann_init(
                base, jax.random.fold_in(key, 0))
        self._stacked = fleet.fleet_broadcast(self._empty, T)
        # slot bookkeeping: tenant_id -> row, LRU order (oldest first),
        # and the per-slot external ids (-1 = free) mirrored as an array
        # for the S-ANN key schedule.
        self._slots: dict[int, int] = {}
        self._lru: OrderedDict[int, None] = OrderedDict()
        self._free = list(range(T - 1, -1, -1))       # pop() -> slot 0 first
        self._ext = np.full((T,), -1, np.int64)
        self._cold: dict[int, object] = {}            # host-state cache
        self._seq = 0                                 # applied ingest ops
        self._ingest_jit: dict = {}
        self._query_jit: dict = {}
        # stats
        self.activations = 0
        self.spills = 0
        self.splits = 0
        # durability
        self._wal = None
        self._root: Optional[pathlib.Path] = None
        self._needs_recover = False
        self._last_snap = 0
        if cfg.snapshot_dir is not None:
            self._root = pathlib.Path(cfg.snapshot_dir)
            self._wal = persist.WriteAheadLog(
                self._root / "wal", fsync=cfg.wal_fsync)
            self._needs_recover = (
                persist.snapshot.latest_seq(self._root) is not None
                or self._wal.has_records())

    # --- properties --------------------------------------------------------

    @property
    def seq(self) -> int:
        return self._seq

    @property
    def hot_tenants(self) -> list[int]:
        with self._lock:
            return list(self._lru)

    @property
    def known_tenants(self) -> set[int]:
        with self._lock:
            known = set(self._slots) | set(self._cold)
            if self._root is not None and (self._root / "tenants").exists():
                for d in (self._root / "tenants").glob("t_*"):
                    known.add(int(d.name[2:]))
            return known

    # --- slot management ---------------------------------------------------

    def _tenant_dir(self, tid: int) -> pathlib.Path:
        return self._root / "tenants" / f"t_{tid}"

    def _spill_seqs(self, tid: int) -> list[int]:
        if self._root is None:
            return []
        d = self._tenant_dir(tid)
        if not d.exists():
            return []
        out = []
        for p in d.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def _spill(self, tid: int) -> None:
        """Evict ``tid``: pull its row to host, cache it, and (durable
        mode) write a per-tenant snapshot labelled with the current op
        seq.  Deterministic content ⇒ an existing spill at this exact seq
        (a replay re-eviction) is simply kept."""
        slot = self._slots.pop(tid)
        self._lru.pop(tid)
        row = jax.device_get(fleet.fleet_row(self._stacked, slot))
        self._cold[tid] = row
        self._free.append(slot)
        self._ext[slot] = -1
        self.spills += 1
        if self._root is not None:
            d = self._tenant_dir(tid)
            if not persist.snapshot.snapshot_path(d, self._seq).exists():
                persist.snapshot.save(d, self._seq, row,
                                      fsync=self.cfg.wal_fsync)

    def _load_row(self, tid: int):
        """Tenant state on activation: warm host cache, else newest spill
        with seq <= the current op seq (the replay-safe bound — see module
        docstring), else a fresh empty sketch."""
        if tid in self._cold:
            return self._cold.pop(tid)
        best = None
        for s in self._spill_seqs(tid):
            if s <= self._seq:
                best = s
        if best is not None:
            return persist.snapshot.load(self._tenant_dir(tid), best,
                                         self._empty)
        return self._empty

    def _activate(self, tids: list[int]) -> None:
        """Make every tenant in ``tids`` hot (len(tids) <= hot_slots),
        evicting LRU victims outside ``tids`` as needed."""
        pinned = set(tids)
        for tid in tids:
            if tid in self._slots:
                self._lru.move_to_end(tid)
                continue
            if not self._free:
                victim = next(t for t in self._lru if t not in pinned)
                self._spill(victim)
            slot = self._free.pop()
            row = self._load_row(tid)
            self._stacked = fleet.fleet_set_row(self._stacked, slot, row)
            self._slots[tid] = slot
            self._lru[tid] = None
            self._ext[slot] = tid
            self.activations += 1

    def _plan_ops(self, tids: np.ndarray) -> list[np.ndarray]:
        """Split a mixed batch (stream order) into index blocks whose
        distinct tenant sets fit in ``hot_slots`` — a pure function of the
        id sequence, so replay re-splits identically."""
        T = self.cfg.hot_slots
        blocks, start, seen = [], 0, set()
        for i, t in enumerate(tids.tolist()):
            if t not in seen:
                if len(seen) == T:
                    blocks.append(np.arange(start, i))
                    start, seen = i, set()
                seen.add(t)
        blocks.append(np.arange(start, len(tids)))
        if len(blocks) > 1:
            self.splits += len(blocks) - 1
        return blocks

    # --- ingest ------------------------------------------------------------

    def _get_ingest(self, cap: int):
        kind = self.cfg.kind
        key = None if kind == "race" else cap
        fn = self._ingest_jit.get(key)
        if fn is not None:
            return fn
        if kind == "race":
            fn = jax.jit(lambda st, xs, tids: fleet.race_fleet_ingest(
                st, self._params, xs, tids))
        elif kind == "swakde":
            fn = jax.jit(lambda st, xs, tids: fleet.swakde_fleet_ingest(
                st, self._params, xs, tids, self._scfg, cap))
        else:
            def _sann(st, xs, tids, seq, exts):
                ck = jax.random.fold_in(self._base_key, seq)
                keys = jax.vmap(
                    lambda e: jax.random.fold_in(ck, e))(exts)
                return fleet.sann_fleet_ingest(
                    st, self._params, xs, tids, keys, self._sann_cfg, cap)
            fn = jax.jit(_sann)
        self._ingest_jit[key] = fn
        return fn

    def _apply_chunk(self, xs: np.ndarray, tids: np.ndarray) -> None:
        """One WAL-recorded operation: activate the chunk's tenants and
        run one routed vmapped commit.  ``self._seq`` is the op's seq for
        spill labels and the S-ANN key schedule."""
        uniq = list(dict.fromkeys(tids.tolist()))     # stream order
        self._activate(uniq)
        slot_ids = np.asarray([self._slots[t] for t in tids.tolist()],
                              np.int32)
        counts = np.bincount(slot_ids, minlength=self.cfg.hot_slots)
        cap = _next_pow2(int(counts.max()))
        fn = self._get_ingest(cap)
        xs_j = jnp.asarray(xs, jnp.float32)
        t_j = jnp.asarray(slot_ids)
        if self.cfg.kind == "sann":
            exts = jnp.asarray(np.maximum(self._ext, 0), jnp.int32)
            self._stacked = fn(self._stacked, xs_j, t_j,
                               jnp.int32(self._seq), exts)
        else:
            self._stacked = fn(self._stacked, xs_j, t_j)
        self._seq += 1

    def ingest(self, xs, tids) -> None:
        """Ingest one mixed chunk: ``xs (B, dim)`` float32, ``tids (B,)``
        int tenant ids (arbitrary non-negative ints).  Splits into
        hot-set-sized operations, WAL-logs each (durable mode), and
        commits each with one vmapped dispatch."""
        xs = np.asarray(xs, np.float32)
        tids = np.asarray(tids, np.int64)
        if xs.ndim != 2 or xs.shape[0] != tids.shape[0]:
            raise ValueError(f"xs {xs.shape} vs tids {tids.shape}")
        if tids.size and tids.min() < 0:
            raise ValueError("tenant ids must be non-negative")
        if xs.shape[0] == 0:
            return
        with self._lock:
            if self._needs_recover:
                raise RuntimeError(
                    f"{self._root!r} holds recoverable fleet state; call "
                    "recover() before ingesting")
            for idx in self._plan_ops(tids):
                cx, ct = xs[idx], tids[idx]
                if self._wal is not None:
                    self._wal.append(
                        [(self._seq, persist.KIND_TENANT_CHUNK,
                          {"xs": cx, "tids": ct})])
                self._apply_chunk(cx, ct)
            self._maybe_snapshot()

    # --- queries -----------------------------------------------------------

    def _get_query(self, name: str, fn):
        """Jit ``fn(stacked, qs, slot_ids)`` once per query kind.  The
        stacked state is an explicit argument — a closure would freeze the
        state captured at first trace into the compiled executable."""
        jfn = self._query_jit.get(name)
        if jfn is None:
            jfn = self._query_jit[name] = jax.jit(fn)
        return jfn

    def _query_blocks(self, qs, tids, run):
        """Shared query driver: activate each block's tenants, run the
        fused fleet query on slot ids, scatter results back to request
        order.  ``run(qs_block, slot_ids)`` returns one result (or a tuple
        of results) with leading axis B."""
        qs = np.asarray(qs, np.float32)
        tids = np.asarray(tids, np.int64)
        if qs.shape[0] != tids.shape[0]:
            raise ValueError(f"qs {qs.shape} vs tids {tids.shape}")
        with self._lock:
            outs = []
            for idx in self._plan_ops(tids):
                block = tids[idx]
                self._activate(list(dict.fromkeys(block.tolist())))
                slot_ids = jnp.asarray(
                    [self._slots[t] for t in block.tolist()], jnp.int32)
                outs.append((idx, run(jnp.asarray(qs[idx]), slot_ids)))
        if len(outs) == 1:
            return outs[0][1]
        parts = [np.asarray(o) if not isinstance(o, tuple)
                 else tuple(np.asarray(x) for x in o) for _, o in outs]
        if isinstance(parts[0], tuple):
            merged = []
            for j in range(len(parts[0])):
                buf = np.empty((len(tids),) + parts[0][j].shape[1:],
                               parts[0][j].dtype)
                for (idx, _), p in zip(outs, parts):
                    buf[idx] = p[j]
                merged.append(buf)
            return tuple(merged)
        buf = np.empty((len(tids),) + parts[0].shape[1:], parts[0].dtype)
        for (idx, _), p in zip(outs, parts):
            buf[idx] = p
        return buf

    def query(self, qs, tids):
        """Per-request sketch estimates: RACE collision estimates,
        SW-AKDE window Ŷ, or S-ANN (c, r)-NN `SANNResult` fields — each
        request served from its own tenant's sketch, all in one fused
        vmapped read."""
        kind = self.cfg.kind
        if kind == "race":
            jfn = self._get_query("query", lambda st, q, t:
                                  fleet.race_fleet_query(st, self._params,
                                                         q, t))
        elif kind == "swakde":
            jfn = self._get_query("query", lambda st, q, t:
                                  fleet.swakde_fleet_query(
                                      st, self._params, q, t, self._scfg))
        else:
            jfn = self._get_query("query", lambda st, q, t: tuple(
                fleet.sann_fleet_query(st, self._params, q, t,
                                       self._sann_cfg)))
            out = self._query_blocks(
                qs, tids, lambda q, t: jfn(self._stacked, q, t))
            return sann.SANNResult(*out)
        return self._query_blocks(
            qs, tids, lambda q, t: jfn(self._stacked, q, t))

    def density(self, qs, tids):
        """Normalised per-tenant KDE reads (RACE / SW-AKDE)."""
        kind = self.cfg.kind
        if kind == "race":
            jfn = self._get_query("density", lambda st, q, t:
                                  fleet.race_fleet_kde(st, self._params,
                                                       q, t))
        elif kind == "swakde":
            jfn = self._get_query("density", lambda st, q, t:
                                  fleet.swakde_fleet_kde(
                                      st, self._params, q, t, self._scfg))
        else:
            raise ValueError("density() is for race/swakde fleets")
        return self._query_blocks(
            qs, tids, lambda q, t: jfn(self._stacked, q, t))

    def query_topk(self, qs, tids, topk: int = 50):
        """Per-tenant top-k retrieval (S-ANN fleets): ``(ids (B, k),
        dists (B, k))`` in request order."""
        if self.cfg.kind != "sann":
            raise ValueError("query_topk() is for sann fleets")
        jfn = self._get_query(f"topk{topk}", lambda st, q, t: tuple(
            fleet.sann_fleet_query_topk(st, self._params, q, t,
                                        self._sann_cfg, topk)))
        return self._query_blocks(
            qs, tids, lambda q, t: jfn(self._stacked, q, t))

    # --- durability --------------------------------------------------------

    def _snapshot_like(self):
        T = self.cfg.hot_slots
        return {"stacked": fleet.fleet_broadcast(self._empty, T),
                "ext": np.zeros((T,), np.int32),
                "lru": np.zeros((T,), np.int32)}

    def _maybe_snapshot(self) -> None:
        if (self._root is None
                or self._seq - self._last_snap < self.cfg.snapshot_every):
            return
        self.snapshot()

    def snapshot(self) -> None:
        """Synchronous fleet snapshot (hot stacked state + slot/LRU maps)
        at the current op seq; compacts the WAL behind it and prunes
        per-tenant spills it supersedes."""
        if self._root is None:
            return
        with self._lock:
            lru = np.full((self.cfg.hot_slots,), -1, np.int32)
            order = list(self._lru)
            lru[:len(order)] = order
            persist.snapshot.save(
                self._root, self._seq,
                {"stacked": self._stacked,
                 "ext": self._ext.astype(np.int32), "lru": lru},
                fsync=self.cfg.wal_fsync)
            self._last_snap = self._seq
            self._wal.compact(self._seq - 1)
            persist.snapshot.prune(self._root, keep=2)
            self._prune_spills(self._seq)

    def _prune_spills(self, snap_seq: int) -> None:
        """Per tenant, spills older than the newest spill with seq <=
        ``snap_seq`` can never be loaded again (every future activation
        bound is >= ``snap_seq``) — delete them."""
        tdir = self._root / "tenants"
        if not tdir.exists():
            return
        for d in tdir.glob("t_*"):
            seqs = sorted(s for s in (
                int(p.name.split("_")[1]) for p in d.glob("step_*")))
            covered = [s for s in seqs if s <= snap_seq]
            if len(covered) > 1:
                import shutil
                for s in covered[:-1]:
                    shutil.rmtree(d / f"step_{s}", ignore_errors=True)

    def recover(self) -> int:
        """Load the newest fleet snapshot and replay the WAL tail through
        the normal ingest path; returns the number of replayed ops.
        Bit-identical to the uninterrupted run (see module docstring)."""
        if self._root is None:
            return 0
        with self._lock:
            if self._seq:
                raise RuntimeError("recover() must run on a fresh fleet")
            snap = persist.snapshot.latest_seq(self._root)
            if snap is not None:
                tree = persist.snapshot.load(self._root, snap,
                                             self._snapshot_like())
                self._stacked = jax.device_put(tree["stacked"])
                self._ext = np.asarray(tree["ext"]).astype(np.int64)
                self._seq = self._last_snap = snap
                self._slots = {int(t): s for s, t in enumerate(self._ext)
                               if t >= 0}
                self._lru = OrderedDict(
                    (int(t), None) for t in tree["lru"] if t >= 0)
                self._free = [s for s in range(self.cfg.hot_slots - 1, -1, -1)
                              if self._ext[s] < 0]
            n = 0
            for rec in self._wal.iter_replay(after=self._seq - 1):
                if rec.seq != self._seq:
                    raise RuntimeError(
                        f"WAL gap: expected seq {self._seq}, got {rec.seq}")
                if rec.kind != persist.KIND_TENANT_CHUNK:
                    raise RuntimeError(f"unexpected WAL kind {rec.kind}")
                self._apply_chunk(np.asarray(rec.arrays["xs"], np.float32),
                                  np.asarray(rec.arrays["tids"], np.int64))
                n += 1
            self._wal.truncate_torn_tail()
            self._needs_recover = False
            jax.block_until_ready(self._stacked)
            return n

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
