"""Shared model layers: RMSNorm, RoPE, blockwise flash attention, MLP.

Attention is a pure-JAX blockwise (flash) implementation — lax.scan over
query blocks with an inner online-softmax scan over KV blocks — so the
32k-prefill shapes never materialise an (S, S) score tensor.  On TPU the
decode path additionally routes through the Pallas sketch/flash-decode
kernel (`repro.kernels.sketch_decode_attn`).

All layers are functional: ``init_*(key, cfg) -> params`` and pure apply
functions.  Layer stacks are scanned (params stacked on axis 0), which keeps
HLO size O(1) in depth — see DESIGN.md §6.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import NULL_CTX, ShardingCtx


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh), positions: (..., S) → rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq        # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


from .flash import flash_attention  # noqa: E402 — custom-VJP blockwise flash


# ---------------------------------------------------------------------------
# Attention layer (GQA + qk-norm + softcap + local/global) with KV cache
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qk_norm: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    sc = lambda fan_in: 1.0 / jnp.sqrt(fan_in)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * sc(d_model)).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * sc(d_model)).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * sc(d_model)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model)) * sc(n_heads * head_dim)).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


def attention(
    params: dict,
    x: jax.Array,                 # (B, S, d)
    positions: jax.Array,         # (B, S)
    *,
    n_heads: int, n_kv_heads: int, head_dim: int,
    rope_theta: float, qk_norm: bool = False,
    causal: bool = True, window: int = 0, softcap: float = 0.0,
    norm_eps: float = 1e-6,
    ctx: ShardingCtx = NULL_CTX,
    cross_kv: Optional[tuple] = None,    # (k, v) for cross-attention
):
    """Full-sequence (train/prefill) attention.  Returns (out, (k, v)) — the
    produced k/v feed KV-cache initialisation in the serve path; single-token
    decode lives in repro.serve.serve_step."""
    B, S, d = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, S, n_kv_heads, head_dim)
        v = (x @ params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    else:
        k, v = cross_kv
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    if cross_kv is None and rope_theta > 0:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    q = ctx.constrain(q, "batch", None, "heads", None)

    out = flash_attention(
        q, k, v, causal=causal and cross_kv is None, window=window,
        softcap=softcap)
    out = out.reshape(B, S, n_heads * head_dim)
    out = out @ params["wo"]
    return ctx.constrain(out, "batch", None, None), (k, v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    sc_in = 1.0 / jnp.sqrt(d_model)
    sc_out = 1.0 / jnp.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * sc_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * sc_in).astype(dtype)
    return p


def mlp(params: dict, x: jax.Array, ctx: ShardingCtx = NULL_CTX,
        act=jax.nn.silu) -> jax.Array:
    h = x @ params["w_up"]
    if "w_gate" in params:
        h = act(x @ params["w_gate"]) * h
    else:
        h = act(h)
    h = ctx.constrain(h, "batch", None, "ffn")
    return (h @ params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    ks = jax.random.split(key, 2)
    p = {"embed": (jax.random.normal(ks[0], (vocab, d_model)) * 0.02).astype(dtype)}
    if not tie:
        p["unembed"] = (jax.random.normal(ks[1], (d_model, vocab))
                        * (1.0 / jnp.sqrt(d_model))).astype(dtype)
    return p


def embed(params: dict, tokens: jax.Array, ctx: ShardingCtx = NULL_CTX) -> jax.Array:
    x = params["embed"][tokens]
    return ctx.constrain(x, "batch", None, None)


def unembed(params: dict, x: jax.Array, ctx: ShardingCtx = NULL_CTX,
            softcap: float = 0.0) -> jax.Array:
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    logits = (x @ w).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return ctx.constrain(logits, "batch", None, "vocab")
