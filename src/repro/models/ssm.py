"""Mamba2 (SSD) blocks — the zamba2 hybrid backbone.

Chunked SSD algorithm [arXiv:2405.21060]: within a chunk the recurrence is
an attention-like quadratic form with a decay mask; across chunks only the
(H, N, P) boundary state is carried by a lax.scan — so memory is
O(S·Q + S²/Q·...per-chunk), never O(S²), and decode is an O(1) state update
(the property that qualifies zamba2 for the long_500k shape).

Single group (B/C shared across heads), per-head scalar decay A — the
standard Mamba2 parameterisation.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import NULL_CTX, ShardingCtx
from .layers import rms_norm


def init_mamba2(key, d_model: int, state: int, conv: int, expand: int,
                head_dim: int, dtype) -> dict:
    d_in = expand * d_model
    H = d_in // head_dim
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d_model)
    in_dim = 2 * d_in + 2 * state + H
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, in_dim)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv, d_in + 2 * state)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in + 2 * state,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),  # softplus->1
        "norm_w": jnp.zeros((d_in,), dtype),
        "w_out": (jax.random.normal(ks[2], (d_in, d_model))
                  * (1.0 / math.sqrt(d_in))).astype(dtype),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, C), w (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4); unrolled adds, no gather
        out = out + pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, N, P) fp32 — SSD boundary state
    conv_buf: jax.Array    # (B, K-1, C) — causal conv tail


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk: int):
    """xh (B,S,H,P), Bm/Cm (B,S,N), dt (B,S,H) fp32, A (H,) negative.

    Returns (y (B,S,H,P) fp32, final state (B,H,N,P))."""
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    xc = xh.reshape(Bsz, nc, Q, H, Pd).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H)
    la = dtc * A[None, None, None, :]                  # log decay, <= 0
    # move chunk axis first for scan
    xc, Bc, Cc, dtc, la = (jnp.moveaxis(t, 1, 0) for t in (xc, Bc, Cc, dtc, la))

    iq = lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jq = lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = iq >= jq

    import os
    naive = os.environ.get("REPRO_SSD_NAIVE") == "1"  # §Perf A/B toggle

    def chunk_step(state, inp):
        x, Bk, Ck, dtk, lak = inp                      # (B,Q,...) for one chunk
        cum = jnp.cumsum(lak, axis=1)                  # (B,Q,H) inclusive
        if naive:  # pre-hillclimb baseline: fp32 G + separate dt contraction
            G = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
            G = jnp.where(tril[None, :, :, None], G, 0.0)
            scores = jnp.einsum("bqn,bjn->bqj", Ck, Bk)
            y_intra = jnp.einsum("bqj,bqjh,bjh,bjhp->bqhp", scores, G, dtk, x)
            y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp", Ck, state, jnp.exp(cum))
            dec_end = jnp.exp(cum[:, -1:, :] - cum)
            s_new = state * jnp.exp(cum[:, -1, :])[:, :, None, None] \
                + jnp.einsum("bjn,bjh,bjhp->bhnp", Bk, dec_end * dtk, x)
            return s_new, y_intra + y_inter
        xd = x * dtk[..., None]                        # fold dt once: (B,Q,H,P)
        # intra-chunk: one decay-weighted score tensor in bf16 — the fp32
        # (B,Q,Q,H) G tensor dominated the zamba2 memory term (§Perf)
        G = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,Q,Q,H)
        W = (jnp.where(tril[None, :, :, None], G, 0.0)
             * jnp.einsum("bqn,bjn->bqj", Ck, Bk)[..., None]).astype(jnp.bfloat16)
        y_intra = jnp.einsum("bqjh,bjhp->bqhp", W,
                             xd.astype(jnp.bfloat16)).astype(jnp.float32)
        # inter-chunk: y_t += exp(cum_t) * C_t @ S_prev
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp",
                             Ck, state, jnp.exp(cum))
        # state update: S = exp(cum_Q) S + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
        dec_end = jnp.exp(cum[:, -1:, :] - cum)                # (B,Q,H)
        s_new = state * jnp.exp(cum[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bjn,bjhp->bhnp", Bk, dec_end[..., None] * xd)
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    step = chunk_step if naive else jax.checkpoint(chunk_step, prevent_cse=False)
    s_final, yc = lax.scan(step, s0, (xc, Bc, Cc, dtc, la))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, Pd)
    return y, s_final


def mamba2(params: dict, x: jax.Array, *, state: int, conv: int, expand: int,
           head_dim: int, chunk: int, norm_eps: float = 1e-6,
           ctx: ShardingCtx = NULL_CTX,
           cache: Optional[SSMCache] = None):
    """Full-sequence Mamba2 mixer.  Returns (out (B,S,d), final SSMCache)."""
    B, S, d = x.shape
    d_in = expand * d
    H = d_in // head_dim
    proj = x @ params["w_in"]
    z, xr, Bm, Cm, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + state, 2 * d_in + 2 * state], axis=-1)
    xbc_raw = jnp.concatenate([xr, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv1d(xbc_raw, params["conv_w"], params["conv_b"]))
    xr, Bm, Cm = jnp.split(xbc, [d_in, d_in + state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    xh = xr.reshape(B, S, H, head_dim)
    xh = ctx.constrain(xh, "batch", None, "heads", None)
    y, s_final = _ssd_chunked(xh, Bm, Cm, dt, A, chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], norm_eps)
    out = y @ params["w_out"]
    cache_out = None
    if S >= conv - 1:
        cache_out = SSMCache(state=s_final,
                             conv_buf=xbc_raw[:, S - (conv - 1):, :])
    return ctx.constrain(out, "batch", None, None), cache_out


def mamba2_decode_step(params: dict, x: jax.Array, cache: SSMCache, *,
                       state: int, expand: int, head_dim: int,
                       norm_eps: float = 1e-6):
    """Single-token decode: O(1) state update.  x (B, 1, d)."""
    B, _, d = x.shape
    d_in = expand * d
    H = d_in // head_dim
    proj = x[:, 0] @ params["w_in"]
    z, xr, Bm, Cm, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + state, 2 * d_in + 2 * state], axis=-1)
    xbc_raw = jnp.concatenate([xr, Bm, Cm], axis=-1)      # (B, C) pre-conv
    window = jnp.concatenate([cache.conv_buf, xbc_raw[:, None, :]], axis=1)  # (B,K,C)
    conv = (window * params["conv_w"][None]).sum(axis=1) + params["conv_b"]
    xbc = jax.nn.silu(conv)
    xr, Bm, Cm = jnp.split(xbc, [d_in, d_in + state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                   # (B,H)
    xh = xr.reshape(B, H, head_dim).astype(jnp.float32)
    s = cache.state * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm.astype(jnp.float32), xh * dt[..., None])
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), s)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], norm_eps)
    out = (y @ params["w_out"])[:, None, :]
    new_tail = jnp.concatenate(
        [cache.conv_buf[:, 1:], xbc_raw[:, None]], axis=1)
    return out, SSMCache(state=s, conv_buf=new_tail)
