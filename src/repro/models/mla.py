"""Multi-head Latent Attention (deepseek-v3) [arXiv:2412.19437].

KV is compressed to a d_c-dim latent (plus one shared rotary key); queries
optionally go through their own d_cq latent.  Training materialises per-head
k/v from the latent and reuses the blockwise flash attention; decode (in
repro.serve) uses the *absorbed* form — scores against the latent cache
directly — which makes the per-token cost O(S · (d_c + rope)) instead of
O(S · H · dh): the property that makes the MLA cache practical at 32k.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import NULL_CTX, ShardingCtx
from .layers import flash_attention, rms_norm, rope


def init_mla(key, d_model: int, n_heads: int, head_dim: int, d_c: int,
             d_cq: int, rope_dim: int, dtype) -> dict:
    ks = jax.random.split(key, 8)
    sc = lambda f: 1.0 / math.sqrt(f)
    H, dh, r = n_heads, head_dim, rope_dim
    p = {
        "w_dkv": (jax.random.normal(ks[0], (d_model, d_c + r)) * sc(d_model)).astype(dtype),
        "kv_norm": jnp.zeros((d_c,), dtype),
        "w_uk": (jax.random.normal(ks[1], (d_c, H * dh)) * sc(d_c)).astype(dtype),
        "w_uv": (jax.random.normal(ks[2], (d_c, H * dh)) * sc(d_c)).astype(dtype),
        "w_o": (jax.random.normal(ks[3], (H * dh, d_model)) * sc(H * dh)).astype(dtype),
    }
    if d_cq:
        p["w_dq"] = (jax.random.normal(ks[4], (d_model, d_cq)) * sc(d_model)).astype(dtype)
        p["q_norm"] = jnp.zeros((d_cq,), dtype)
        p["w_uq"] = (jax.random.normal(ks[5], (d_cq, H * (dh + r))) * sc(d_cq)).astype(dtype)
    else:
        p["w_q"] = (jax.random.normal(ks[6], (d_model, H * (dh + r))) * sc(d_model)).astype(dtype)
    return p


def mla_latent(params: dict, x: jax.Array, positions, *, rope_dim: int,
               rope_theta: float, norm_eps: float = 1e-6):
    """Compute the (latent, rotary-key) pair that the decode cache stores."""
    d_c = params["kv_norm"].shape[0]
    ckr = x @ params["w_dkv"]
    c, kr = ckr[..., :d_c], ckr[..., d_c:]
    c = rms_norm(c, params["kv_norm"], norm_eps)
    kr = rope(kr[..., None, :], positions, rope_theta)[..., 0, :]  # shared head
    return c, kr


def mla_queries(params: dict, x: jax.Array, positions, *, n_heads: int,
                head_dim: int, rope_dim: int, rope_theta: float,
                norm_eps: float = 1e-6):
    B, S, _ = x.shape
    H, dh, r = n_heads, head_dim, rope_dim
    if "w_dq" in params:
        cq = rms_norm(x @ params["w_dq"], params["q_norm"], norm_eps)
        q = (cq @ params["w_uq"]).reshape(B, S, H, dh + r)
    else:
        q = (x @ params["w_q"]).reshape(B, S, H, dh + r)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_attention(params: dict, x: jax.Array, positions, *, n_heads: int,
                  head_dim: int, rope_dim: int, rope_theta: float,
                  norm_eps: float = 1e-6, ctx: ShardingCtx = NULL_CTX):
    """Training/prefill path: materialise per-head k/v, flash-attend.

    Returns (out, (latent c, rotary key kr)) — the cache pair."""
    B, S, _ = x.shape
    H, dh, r = n_heads, head_dim, rope_dim
    c, kr = mla_latent(params, x, positions, rope_dim=r, rope_theta=rope_theta,
                       norm_eps=norm_eps)
    q_nope, q_rope = mla_queries(
        params, x, positions, n_heads=H, head_dim=dh, rope_dim=r,
        rope_theta=rope_theta, norm_eps=norm_eps)
    k_nope = (c @ params["w_uk"]).reshape(B, S, H, dh)
    v = (c @ params["w_uv"]).reshape(B, S, H, dh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, r))],
                        axis=-1)
    q = ctx.constrain(q, "batch", None, "heads", None)
    out = flash_attention(q, k, v, causal=True)      # dv = dh < dqk = dh + r
    out = out.reshape(B, S, H * dh) @ params["w_o"]
    return ctx.constrain(out, "batch", None, None), (c, kr)
