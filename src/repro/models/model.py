"""Model assembly: every assigned architecture behind one init/forward API.

  init_model(cfg, key)                  -> params pytree
  forward(params, cfg, batch, ctx)      -> (logits fp32, aux dict)

``batch`` is a dict: "tokens" (B, S_text) int32 always; "patches"
(B, n_patches, d_vit) for VLM; "frames" (B, enc_frames, d_model) for the
audio enc-dec (both are stub-frontend embeddings per the assignment).

Every stack is lax.scan-over-layers with stacked params (HLO depth O(1)),
rematerialised per layer.  Heterogeneous stacks scan over *superblocks*:
gemma local:global patterns use a per-layer traced window array; zamba2
scans (6 mamba + shared-attn) groups; xlstm scans (mLSTM, sLSTM+FFN) pairs.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import NULL_CTX, ShardingCtx
from . import layers, mla, moe, ssm, xlstm

VIT_DIM = 1024  # stub InternViT output dim


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def window_pattern(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding windows: 0 = global.  gemma2: 1:1, gemma3: 5:1."""
    if cfg.local_global_period <= 0 or cfg.local_window <= 0:
        return jnp.zeros((cfg.n_layers,), jnp.int32) + jnp.int32(cfg.local_window)
    pat = []
    for i in range(cfg.n_layers):
        is_global = (i % cfg.local_global_period) == cfg.local_global_period - 1
        pat.append(0 if is_global else cfg.local_window)
    return jnp.asarray(pat, jnp.int32)


# ---------------------------------------------------------------------------
# dense / vlm block
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig, dt):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": layers.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm, dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt),
    }
    if cfg.sandwich_norm:  # gemma family: pre+post block norms
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dt)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _dense_block(p, x, positions, window, cfg: ModelConfig, ctx):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, _ = layers.attention(
        p["attn"], h, positions,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm, causal=True, window=window,
        softcap=cfg.attn_softcap, norm_eps=cfg.norm_eps, ctx=ctx)
    if "ln1_post" in p:
        a = layers.rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    m = layers.mlp(p["mlp"], h, ctx,
                   act=jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu)
    if "ln2_post" in p:
        m = layers.rms_norm(m, p["ln2_post"], cfg.norm_eps)
    return x + m


def _scan_stack(block_fn, stacked, x, per_layer, remat: bool,
                constrain_fn=None):
    """constrain_fn shards the carried residual (e.g. Megatron-SP over seq)
    so the per-layer saved activation is sharded, not replicated."""
    def body(carry, inp):
        x, aux = carry
        if constrain_fn is not None:
            x = constrain_fn(x)
        p, extra = inp
        out = block_fn(p, x, extra)
        if isinstance(out, tuple):
            x, a = out
            aux = aux + a
        else:
            x = out
        if constrain_fn is not None:
            x = constrain_fn(x)
        return (x, aux), None

    f = jax.checkpoint(body, prevent_cse=False) if remat else body
    (x, aux), _ = lax.scan(f, (x, jnp.zeros((), jnp.float32)), (stacked, per_layer))
    return x, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig, key: jax.Array) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 16)
    params: dict = {
        "embedding": layers.init_embedding(keys[0], cfg.vocab, cfg.d_model, dt,
                                           cfg.tie_embeddings),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
    }

    if cfg.family in ("dense", "vlm"):
        lk = jax.random.split(keys[1], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: _init_dense_block(k, cfg, dt))(lk)
        if cfg.family == "vlm":
            params["patch_proj"] = (jax.random.normal(keys[2], (VIT_DIM, cfg.d_model))
                                    / math.sqrt(VIT_DIM)).astype(dt)

    elif cfg.family == "moe":
        nd, nm = cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers
        if nd:
            lk = jax.random.split(keys[1], nd)
            params["dense_blocks"] = jax.vmap(
                lambda k: _init_dense_block(k, cfg, dt))(lk)
        lk = jax.random.split(keys[2], nm)

        def init_moe_block(k):
            ks = jax.random.split(k, 3)
            p = {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "moe": moe.init_moe(ks[0], cfg.d_model, cfg.n_experts,
                                    cfg.n_shared_experts, cfg.d_ff_expert, dt),
            }
            if cfg.use_mla:
                p["attn"] = mla.init_mla(
                    ks[1], cfg.d_model, cfg.n_heads, cfg.resolved_head_dim,
                    cfg.mla_d_c, cfg.mla_d_cq, cfg.mla_rope_dim, dt)
            else:
                p["attn"] = layers.init_attention(
                    ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, cfg.qk_norm, dt)
            return p

        params["moe_blocks"] = jax.vmap(init_moe_block)(lk)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": (jax.random.normal(keys[3], (2 * cfg.d_model, cfg.d_model))
                         / math.sqrt(2 * cfg.d_model)).astype(dt),
                "block": _init_dense_block(keys[4], cfg, dt),
                "ln": jnp.zeros((cfg.d_model,), dt),
            }

    elif cfg.family == "hybrid":
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        groups = cfg.n_layers // cfg.attn_every
        lk = jax.random.split(keys[1], cfg.n_layers)

        def init_mamba_layer(k):
            return {
                "ln": jnp.zeros((cfg.d_model,), dt),
                "mixer": ssm.init_mamba2(k, cfg.d_model, cfg.ssm_state,
                                         cfg.ssm_conv, cfg.ssm_expand,
                                         cfg.ssm_head_dim, dt),
            }

        stacked = jax.vmap(init_mamba_layer)(lk)
        params["mamba_blocks"] = jax.tree.map(
            lambda a: a.reshape(groups, cfg.attn_every, *a.shape[1:]), stacked)
        params["shared_attn"] = _init_dense_block(keys[2], cfg, dt)

    elif cfg.family == "ssm":   # xlstm: (mLSTM, sLSTM) pairs
        assert cfg.n_layers % 2 == 0
        pairs = cfg.n_layers // 2
        mk = jax.random.split(keys[1], pairs)
        sk = jax.random.split(keys[2], pairs)
        fk = jax.random.split(keys[3], pairs)
        params["mlstm_blocks"] = jax.vmap(
            lambda k: {"ln": jnp.zeros((cfg.d_model,), dt),
                       "mixer": xlstm.init_mlstm(k, cfg.d_model, cfg.n_heads, dt)})(mk)
        params["slstm_blocks"] = jax.vmap(
            lambda k, k2: {"ln": jnp.zeros((cfg.d_model,), dt),
                           "mixer": xlstm.init_slstm(k, cfg.d_model, cfg.n_heads, dt),
                           "ln_ffn": jnp.zeros((cfg.d_model,), dt),
                           "ffn": layers.init_mlp(k2, cfg.d_model,
                                                  4 * cfg.d_model // 3, dt)})(sk, fk)

    elif cfg.family == "encdec":
        ek = jax.random.split(keys[1], cfg.n_enc_layers)

        def init_enc(k):
            ks = jax.random.split(k, 2)
            return {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "attn": layers.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                              cfg.n_kv_heads, cfg.resolved_head_dim,
                                              False, dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, gated=False),
            }

        params["enc_blocks"] = jax.vmap(init_enc)(ek)
        params["enc_ln_f"] = jnp.zeros((cfg.d_model,), dt)
        dk = jax.random.split(keys[2], cfg.n_layers)

        def init_dec(k):
            ks = jax.random.split(k, 3)
            return {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "attn": layers.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                              cfg.n_kv_heads, cfg.resolved_head_dim,
                                              False, dt),
                "ln_x": jnp.zeros((cfg.d_model,), dt),
                "xattn": layers.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                               cfg.n_kv_heads, cfg.resolved_head_dim,
                                               False, dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt, gated=False),
            }

        params["dec_blocks"] = jax.vmap(init_dec)(dk)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params: dict, cfg: ModelConfig, batch: dict,
            ctx: ShardingCtx = NULL_CTX, return_hidden: bool = False):
    """return_hidden=True skips the unembedding and yields the final normed
    hidden states (the train loss uses chunked unembed+CE to avoid (B,S,V)
    fp32 materialisation)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]

    if cfg.family == "encdec":
        return _forward_encdec(params, cfg, batch, ctx, return_hidden)

    x = layers.embed(params["embedding"], tokens, ctx)
    if cfg.scale_embeddings:   # gemma
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.family == "vlm":
        patches = batch["patches"] @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux = jnp.zeros((), jnp.float32)
    sp = (lambda t: ctx.constrain(t, "batch", "seq_sp", None)) \
        if cfg.seq_parallel else None

    if cfg.family in ("dense", "vlm"):
        wins = window_pattern(cfg)
        x, _ = _scan_stack(
            lambda p, x, w: _dense_block(p, x, positions, w, cfg, ctx),
            params["blocks"], x, wins, cfg.remat, constrain_fn=sp)

    elif cfg.family == "moe":
        if cfg.n_dense_layers:
            zeros = jnp.zeros((cfg.n_dense_layers,), jnp.int32)
            x, _ = _scan_stack(
                lambda p, x, w: _dense_block(p, x, positions, w, cfg, ctx),
                params["dense_blocks"], x, zeros, cfg.remat, constrain_fn=sp)

        def moe_block(p, x, _):
            h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, _ = mla.mla_attention(
                    p["attn"], h, positions, n_heads=cfg.n_heads,
                    head_dim=cfg.resolved_head_dim, rope_dim=cfg.mla_rope_dim,
                    rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps, ctx=ctx)
            else:
                a, _ = layers.attention(
                    p["attn"], h, positions, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                    rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                    causal=True, norm_eps=cfg.norm_eps, ctx=ctx)
            x = x + a
            h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
            m, a_loss = moe.moe(p["moe"], h, topk=cfg.moe_topk,
                                capacity_factor=cfg.capacity_factor, ctx=ctx,
                                fsdp_over_pod=cfg.fsdp_over_pod)
            return x + m, a_loss

        nm = cfg.n_layers - cfg.n_dense_layers
        x, aux = _scan_stack(moe_block, params["moe_blocks"], x,
                             jnp.zeros((nm,), jnp.int32), cfg.remat,
                             constrain_fn=sp)
        aux = aux * cfg.moe_aux_coef / max(nm, 1)

    elif cfg.family == "hybrid":
        def group(ps, x, _):
            p_mamba, = (ps,)

            def one(x, p):
                h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
                o, _ = ssm.mamba2(p["mixer"], h, state=cfg.ssm_state,
                                  conv=cfg.ssm_conv, expand=cfg.ssm_expand,
                                  head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                                  norm_eps=cfg.norm_eps, ctx=ctx)
                return x + o, None

            x, _ = lax.scan(one, x, p_mamba)
            # shared attention block (weights shared across groups — closure)
            return _dense_block(params["shared_attn"], x, positions,
                                jnp.int32(0), cfg, ctx)

        x, _ = _scan_stack(group, params["mamba_blocks"], x,
                           jnp.zeros((cfg.n_layers // cfg.attn_every,), jnp.int32),
                           cfg.remat)

    elif cfg.family == "ssm":
        def pair(ps, x, _):
            pm, psl = ps
            h = layers.rms_norm(x, pm["ln"], cfg.norm_eps)
            o, _ = xlstm.mlstm(pm["mixer"], h, n_heads=cfg.n_heads,
                               norm_eps=cfg.norm_eps, ctx=ctx)
            x = x + o
            h = layers.rms_norm(x, psl["ln"], cfg.norm_eps)
            o, _ = xlstm.slstm(psl["mixer"], h, n_heads=cfg.n_heads,
                               norm_eps=cfg.norm_eps, ctx=ctx)
            x = x + o
            h = layers.rms_norm(x, psl["ln_ffn"], cfg.norm_eps)
            return x + layers.mlp(psl["ffn"], h, ctx)

        pairs = cfg.n_layers // 2
        x, _ = _scan_stack(
            lambda ps, x, w: pair(ps, x, w),
            (params["mlstm_blocks"], params["slstm_blocks"]), x,
            jnp.zeros((pairs,), jnp.int32), cfg.remat)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    out_aux = {"moe_aux": aux}
    mtp_hidden = None
    if cfg.family == "moe" and cfg.mtp_depth and "mtp" in params:
        mtp_hidden = _mtp_head(params, cfg, x, tokens, positions, ctx)
    if cfg.family == "vlm":
        x = x[:, batch["patches"].shape[1]:]  # text positions only
    if return_hidden:
        if mtp_hidden is not None:
            out_aux["mtp_hidden"] = mtp_hidden
        return x, out_aux
    logits = layers.unembed(params["embedding"], x, ctx, cfg.final_softcap)
    if mtp_hidden is not None:
        out_aux["mtp_logits"] = layers.unembed(
            params["embedding"], mtp_hidden, ctx, cfg.final_softcap)
    return logits, out_aux


def _mtp_head(params, cfg, h_final, tokens, positions, ctx):
    """DSv3-style depth-1 MTP: combine h_t with emb(token_{t+1}), one extra
    block → hidden states that predict token_{t+2} via the shared unembed."""
    p = params["mtp"]
    emb_next = layers.embed(params["embedding"], jnp.roll(tokens, -1, axis=1), ctx)
    h = jnp.concatenate([layers.rms_norm(h_final, p["ln"], cfg.norm_eps),
                         emb_next.astype(h_final.dtype)], axis=-1)
    h = h @ p["proj"]
    return _dense_block(p["block"], h, positions, jnp.int32(0), cfg, ctx)


def _forward_encdec(params, cfg: ModelConfig, batch, ctx,
                    return_hidden: bool = False):
    frames = batch["frames"]             # (B, T_enc, d_model) — stub frontend
    tokens = batch["tokens"]
    B, S = tokens.shape
    Te = frames.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))
    dec_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    x = frames.astype(_dtype(cfg)) + _sinusoid(Te, cfg.d_model, _dtype(cfg))

    def enc_block(p, x, _):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, _ = layers.attention(p["attn"], h, enc_pos, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=False, norm_eps=cfg.norm_eps, ctx=ctx)
        x = x + a
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, ctx, act=jax.nn.gelu)

    enc, _ = _scan_stack(enc_block, params["enc_blocks"], x,
                         jnp.zeros((cfg.n_enc_layers,), jnp.int32), cfg.remat)
    enc = layers.rms_norm(enc, params["enc_ln_f"], cfg.norm_eps)

    y = layers.embed(params["embedding"], tokens, ctx)
    y = y + _sinusoid(S, cfg.d_model, y.dtype)

    def dec_block(p, y, _):
        h = layers.rms_norm(y, p["ln1"], cfg.norm_eps)
        a, _ = layers.attention(p["attn"], h, dec_pos, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=True, norm_eps=cfg.norm_eps, ctx=ctx)
        y = y + a
        h = layers.rms_norm(y, p["ln_x"], cfg.norm_eps)
        kx = (enc @ p["xattn"]["wk"]).reshape(B, Te, cfg.n_kv_heads, cfg.resolved_head_dim)
        vx = (enc @ p["xattn"]["wv"]).reshape(B, Te, cfg.n_kv_heads, cfg.resolved_head_dim)
        a, _ = layers.attention(p["xattn"], h, dec_pos, n_heads=cfg.n_heads,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=False, norm_eps=cfg.norm_eps, ctx=ctx,
                                cross_kv=(kx, vx))
        y = y + a
        h = layers.rms_norm(y, p["ln2"], cfg.norm_eps)
        return y + layers.mlp(p["mlp"], h, ctx, act=jax.nn.gelu)

    y, _ = _scan_stack(dec_block, params["dec_blocks"], y,
                       jnp.zeros((cfg.n_layers,), jnp.int32), cfg.remat)
    y = layers.rms_norm(y, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return y, {"moe_aux": jnp.zeros((), jnp.float32)}
    logits = layers.unembed(params["embedding"], y, ctx, cfg.final_softcap)
    return logits, {"moe_aux": jnp.zeros((), jnp.float32)}


@functools.lru_cache(maxsize=32)
def _sinusoid_np(S: int, d: int):
    import numpy as np
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return out


def _sinusoid(S: int, d: int, dtype):
    return jnp.asarray(_sinusoid_np(S, d), dtype)[None]
