"""Blockwise flash attention with a custom VJP (pure JAX).

Forward saves only (q, k, v, o, lse); the backward recomputes block scores
and runs the standard two-pass FlashAttention backward (pass 1: dq over
query blocks; pass 2: dk/dv over key blocks).  This removes the scan-carry
residual blowup of naive AD (which stacks the (B,H,bq,dv) accumulator for
every KV step: ~17 GB/layer at 4k for a 4B model) and is the memory-term
baseline fix recorded in EXPERIMENTS.md §Perf.

Supports GQA (Hkv <= H), dv != dqk (MLA), causal masking, static *or
traced* sliding windows (0 = global), tanh softcap, and a q_offset for
chunked prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b -= 1
    return b


def _mask(qi, kj, bq, bk, causal, window, q_offset):
    qpos = q_offset + qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    mask &= (window <= 0) | (kpos > qpos - window)
    return mask


def _scores(qblk, kblk, scale, softcap):
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                   kblk.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s_raw = s
        s = softcap * jnp.tanh(s / softcap)
        return s, s_raw
    return s, s


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, window, causal, softcap, q_offset, bq, bk):
    o, _ = _fwd_impl(q, k, v, window, causal, softcap, q_offset, bq, bk)
    return o


def _fwd_impl(q, k, v, window, causal, softcap, q_offset, bq, bk):
    B, Sq, Hkv, G, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[-1]
    nq, nk = Sq // bq, Sk // bk
    scale = dh ** -0.5

    qb = q.reshape(B, nq, bq, Hkv, G, dh).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, bk, Hkv, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, Hkv, dv).transpose(1, 0, 3, 2, 4)

    def kv_step(carry, inp):
        m, l, acc, qi, qblk = carry
        kj, kblk, vblk = inp
        s, _ = _scores(qblk, kblk, scale, softcap)
        s = jnp.where(_mask(qi, kj, bq, bk, causal, window, q_offset), s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        alpha = jnp.where(jnp.isnan(alpha), 0.0, alpha)
        p = jnp.exp(s - m_new)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                       vblk.astype(jnp.float32))
        return (m_new, l, acc, qi, qblk), None

    def q_block(qi, qblk):
        m0 = jnp.full((B, Hkv, G, bq, 1), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, dv), jnp.float32)
        (m, l, acc, _, _), _ = lax.scan(kv_step, (m0, l0, a0, qi, qblk),
                                        (jnp.arange(nk), kb, vb))
        o = jnp.where(l > 0, acc / jnp.maximum(l, 1e-30), 0.0)
        lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]      # (B,Hkv,G,bq)
        lse = jnp.where(jnp.isinf(m[..., 0]), -jnp.inf, lse)
        return o, lse

    def outer(_, inp):
        qi, qblk = inp
        return None, q_block(qi, qblk)

    _, (ob, lseb) = lax.scan(outer, None, (jnp.arange(nq), qb))
    o = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hkv, G, dv).astype(q.dtype)
    lse = lseb.transpose(1, 0, 4, 2, 3).reshape(B, Sq, Hkv, G)
    return o, lse


def _flash_fwd(q, k, v, window, causal, softcap, q_offset, bq, bk):
    o, lse = _fwd_impl(q, k, v, window, causal, softcap, q_offset, bq, bk)
    return o, (q, k, v, window, o, lse)


def _flash_bwd(causal, softcap, q_offset, bq, bk, res, do):
    q, k, v, window, o, lse = res
    B, Sq, Hkv, G, dh = q.shape
    Sk = k.shape[1]
    dv = v.shape[-1]
    nq, nk = Sq // bq, Sk // bk
    scale = dh ** -0.5

    qb = q.reshape(B, nq, bq, Hkv, G, dh).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, bk, Hkv, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, Hkv, dv).transpose(1, 0, 3, 2, 4)
    dob = do.reshape(B, nq, bq, Hkv, G, dv).transpose(1, 0, 3, 4, 2, 5)
    lseb = lse.reshape(B, nq, bq, Hkv, G).transpose(1, 0, 3, 4, 2)
    # delta_i = rowsum(do_i * o_i)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    deltab = delta.reshape(B, nq, bq, Hkv, G).transpose(1, 0, 3, 4, 2)

    def block_ds(qi, kj, qblk, kblk, vblk, lse_q, do_q, dl_q):
        """Recompute p and ds for one (q, k) block pair.  Returns (p, ds)."""
        s_cap, _ = _scores(qblk, kblk, scale, softcap)
        mask = _mask(qi, kj, bq, bk, causal, window, q_offset)
        s_cap = jnp.where(mask, s_cap, -jnp.inf)
        p = jnp.exp(s_cap - lse_q[..., None])
        p = jnp.where(jnp.isnan(p) | jnp.isinf(p), 0.0, p)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_q.astype(jnp.float32),
                        vblk.astype(jnp.float32))
        ds = p * (dp - dl_q[..., None])
        if softcap > 0.0:
            ds = ds * (1.0 - (jnp.where(mask, s_cap, 0.0) / softcap) ** 2)
        ds = jnp.where(mask, ds, 0.0) * scale
        return p, ds

    # ---- pass 1: dq, scanning query blocks
    def dq_block(_, inp):
        qi, qblk, lse_q, do_q, dl_q = inp

        def step(dq, inp2):
            kj, kblk, vblk = inp2
            _, ds = block_ds(qi, kj, qblk, kblk, vblk, lse_q, do_q, dl_q)
            dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                                 kblk.astype(jnp.float32))
            return dq, None

        dq0 = jnp.zeros((B, Hkv, G, bq, dh), jnp.float32)
        dq, _ = lax.scan(step, dq0, (jnp.arange(nk), kb, vb))
        return None, dq

    _, dqb = lax.scan(dq_block, None, (jnp.arange(nq), qb, lseb, dob, deltab))
    dq = dqb.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hkv, G, dh)

    # ---- pass 2: dk, dv, scanning key blocks
    def dkv_block(_, inp):
        kj, kblk, vblk = inp

        def step(carry, inp2):
            dk, dvv = carry
            qi, qblk, lse_q, do_q, dl_q = inp2
            p, ds = block_ds(qi, kj, qblk, kblk, vblk, lse_q, do_q, dl_q)
            dvv = dvv + jnp.einsum("bhgqk,bhgqd->bhkd", p,
                                   do_q.astype(jnp.float32))
            dk = dk + jnp.einsum("bhgqk,bhgqd->bhkd", ds,
                                 qblk.astype(jnp.float32))
            return (dk, dvv), None

        dk0 = jnp.zeros((B, Hkv, bk, dh), jnp.float32)
        dv0 = jnp.zeros((B, Hkv, bk, dv), jnp.float32)
        (dk, dvv), _ = lax.scan(
            step, (dk0, dv0), (jnp.arange(nq), qb, lseb, dob, deltab))
        return None, (dk, dvv)

    _, (dkb, dvb) = lax.scan(dkv_block, None, (jnp.arange(nk), kb, vb))
    dk = dkb.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, dh)
    dvv = dvb.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, dv)

    dwin = np.zeros(window.shape, jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dvv.astype(v.dtype), dwin)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,            # (B, Sq, H, dh)
    k: jax.Array,            # (B, Sk, Hkv, dh)
    v: jax.Array,            # (B, Sk, Hkv, dv)
    *,
    causal: bool = True,
    window=0,                # python int or traced int32 scalar; 0 = global
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(k.shape[1], block_k)
    win = window if isinstance(window, jax.Array) else jnp.int32(window)
    qg = q.reshape(B, Sq, Hkv, G, dh)
    o = _flash(qg, k, v, win, causal, float(softcap), int(q_offset), bq, bk)
    return o.reshape(B, Sq, H, v.shape[-1])
