"""Mixture-of-Experts layers (deepseek-moe-16b, deepseek-v3-671b).

Fine-grained MoE: ``n_shared`` always-on experts + ``n_experts`` routed
experts with top-k softmax gating and a Switch-style load-balance aux loss.

Two execution paths with identical semantics (equivalence-tested):
  * ``dense``  — every expert computed for every token, mask-combined.
    Exact, dropless; used on a single device (smoke tests, references).
  * ``ep``     — expert parallelism: experts sharded over the mesh "model"
    axis inside a shard_map.  Activations are replicated across the model
    axis (they already are, in this framework's sharding scheme), so each
    model shard computes only the tokens routed to ITS experts via a
    rank-in-expert capacity dispatch (cumsum-based, no sort), and a single
    psum over "model" combines expert outputs.
    Baseline combine = all-reduce; the all-to-all dispatch variant is a
    §Perf hillclimb (see EXPERIMENTS.md).

Capacity: C = ceil(T_local * topk / n_experts * capacity_factor); overflow
tokens are dropped for that expert (standard dropping implementation).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import NULL_CTX, ShardingCtx
from . import layers


def init_moe(key, d_model: int, n_experts: int, n_shared: int,
             d_ff_expert: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    sc_in = 1.0 / math.sqrt(d_model)
    sc_out = 1.0 / math.sqrt(d_ff_expert)
    p = {
        "router": jax.random.normal(ks[0], (d_model, n_experts), jnp.float32) * sc_in,
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff_expert)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff_expert)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, d_ff_expert, d_model)) * sc_out).astype(dtype),
    }
    if n_shared:
        p["shared"] = layers.init_mlp(ks[4], d_model, n_shared * d_ff_expert, dtype)
    return p


def _route(router_w, x_flat, topk: int):
    """Returns (weights (T,k) fp32, expert ids (T,k) int32, aux loss scalar)."""
    logits = x_flat.astype(jnp.float32) @ router_w            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, topk)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance: E * sum_e f_e * p_e
    E = router_w.shape[1]
    f = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(idx.size, 1)
    pbar = probs.mean(0)
    aux = E * jnp.sum(f * pbar)
    return w, idx, aux


def _expert_ffn(p, xs):
    """xs (E_local, C, d) → (E_local, C, d), SwiGLU per expert."""
    h = jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"]))
    return jnp.einsum("ecf,efd->ecd", h * g, p["w_down"])


def _dispatch_compute_combine(p_local, x_flat, w, idx, e_lo, E_local: int, C):
    """Capacity dispatch for experts in [e_lo, e_lo + E_local) with local
    weights.  ``e_lo`` may be traced (lax.axis_index under shard_map);
    ``E_local`` is static.  Sort-based rank-in-expert (O(T k) memory — the
    earlier cumsum-matrix variant materialised (T·k, E_local) int32 = 33 GB
    on dsv3) and per-top-k-slot scatter/gather loops (avoids (T·k, d)
    intermediates).  x_flat (T, d); w/idx (T, k) → y (T, d) fp32."""
    T, d = x_flat.shape
    k = idx.shape[1]
    a_e = idx.reshape(-1)                       # (T*k,) global expert ids
    local = (a_e >= e_lo) & (a_e < e_lo + E_local)
    le = jnp.clip(a_e - e_lo, 0, E_local - 1)
    key = jnp.where(local, le, E_local)         # non-local → overflow group
    order = jnp.argsort(key)
    counts = jnp.zeros((E_local + 1,), jnp.int32).at[key].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[key[order]]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted)
    ok = local & (rank < C)
    pos = jnp.where(ok, le * C + rank, E_local * C)  # (T*k,) dump slot at end

    pos_k = pos.reshape(T, k)

    def scatter_step(buf, pos_j):
        return buf.at[pos_j].add(x_flat), None

    buf = jnp.zeros((E_local * C + 1, d), x_flat.dtype)
    buf, _ = jax.lax.scan(jax.checkpoint(scatter_step, prevent_cse=False),
                          buf, pos_k.T)
    ys = _expert_ffn(p_local, buf[:-1].reshape(E_local, C, d))
    ys = jnp.concatenate([ys.reshape(E_local * C, d),
                          jnp.zeros((1, d), ys.dtype)])  # dump row reads 0

    def combine_step(y, inp):
        pos_j, w_j = inp
        return y + ys[pos_j].astype(jnp.float32) * w_j[:, None], None

    # scan (not an unrolled loop): XLA reuses one fp32 (T, d) accumulator
    # instead of keeping k multiply-fusion outputs alive (~14 GB on dsv3)
    y = jnp.zeros((T, d), jnp.float32)
    y, _ = jax.lax.scan(jax.checkpoint(combine_step, prevent_cse=False),
                        y, (pos_k.T, w.T))
    return y


def moe_dense(params, x, *, topk: int, capacity_factor: float,
              ctx: ShardingCtx = NULL_CTX):
    """Single-device (or auto-sharded) reference path."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    x_flat = x.reshape(B * S, d)
    w, idx, aux = _route(params["router"], x_flat, topk)
    C = max(1, math.ceil(B * S * topk / E * capacity_factor))
    y = _dispatch_compute_combine(params, x_flat, w, idx, jnp.int32(0), E, C)
    y = y.astype(x.dtype).reshape(B, S, d)
    if "shared" in params:
        y = y + layers.mlp(params["shared"], x, ctx)
    return y, aux


def moe_ep(params, x, *, topk: int, capacity_factor: float, ctx: ShardingCtx,
           fsdp_over_pod: bool = False):
    """Expert-parallel path: experts sharded on the mesh "model" axis.

    In-specs MATCH the stored FSDP layout (experts also sharded over "data"
    on their d dim) and the ZeRO-style weight all-gather happens explicitly
    *inside*, per layer — otherwise XLA hoists a resharding all-gather of the
    whole stacked-layer array into every scan iteration (measured 5.8 TB/dev
    on dsv3; see EXPERIMENTS.md §Dry-run)."""
    mesh = ctx.mesh
    B, S, d = x.shape
    E = params["router"].shape[1]
    M = mesh.shape["model"]
    assert E % M == 0, (E, M)
    E_local = E // M
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    has_data = "data" in mesh.axis_names and mesh.shape["data"] > 1
    fsdp_ax = ("data", "pod") if (fsdp_over_pod and "pod" in mesh.axis_names) \
        else "data"
    # match param_specs: w_gate/w_up are (E, d, f) → P("model", fsdp, None);
    # w_down is (E, f, d) with FSDP on its (larger) d dim → P("model", None, fsdp)
    wg_spec = P("model", fsdp_ax, None) if has_data else P("model", None, None)
    wd_spec = P("model", None, fsdp_ax) if has_data else P("model", None, None)

    def inner(router_w, wg, wu, wd, x_local):
        Bl, Sl, _ = x_local.shape
        if has_data:  # ZeRO gather of this layer's local experts
            wg = lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        x_flat = x_local.reshape(Bl * Sl, d)
        w, idx, aux = _route(router_w, x_flat, topk)
        C = max(1, math.ceil(Bl * Sl * topk / E * capacity_factor))
        m = lax.axis_index("model")
        p_local = {"w_gate": wg, "w_up": wu, "w_down": wd}
        y = _dispatch_compute_combine(
            p_local, x_flat, w, idx, m * E_local, E_local, C)
        # bf16 combine psum: halves the dominant per-layer all-reduce
        # (§Perf hillclimb 2); per-token sums have <= topk+1 terms
        y = lax.psum(y.astype(jnp.bfloat16), "model").astype(jnp.float32)
        # per-shard load-balance estimate, averaged across the whole mesh
        # (Switch-style; differs from the global product by O(1/shards))
        aux = lax.pmean(aux, ("model",) + batch_axes)
        return y.reshape(Bl, Sl, d).astype(x_local.dtype), aux

    y, aux = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), wg_spec, wg_spec, wd_spec,
                  P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
    if "shared" in params:
        y = y + layers.mlp(params["shared"], x, ctx)
    return y, aux


def moe(params, x, *, topk: int, capacity_factor: float,
        ctx: ShardingCtx = NULL_CTX, fsdp_over_pod: bool = False):
    """Dispatching entry point: EP when a model axis exists, dense otherwise."""
    if ctx.mesh is not None and "model" in ctx.mesh.axis_names \
            and ctx.mesh.shape["model"] > 1 \
            and params["router"].shape[1] % ctx.mesh.shape["model"] == 0:
        return moe_ep(params, x, topk=topk, capacity_factor=capacity_factor,
                      ctx=ctx, fsdp_over_pod=fsdp_over_pod)
    return moe_dense(params, x, topk=topk, capacity_factor=capacity_factor, ctx=ctx)
