"""xLSTM blocks (xlstm-125m): stabilised mLSTM (matrix memory) + sLSTM.

Both cells are *exact sequential* recurrences via lax.scan over time — the
architecture's native form (the published CUDA kernels fuse the same math).
Decode is the same cell applied to one step, so long_500k decode is O(1)
state, which is what qualifies xlstm for that shape.

mLSTM: per-head matrix memory C (Pv, Pk), normaliser n (Pk), stabiliser m.
sLSTM: per-head scalar-memory cell with block-diagonal recurrent weights.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import NULL_CTX, ShardingCtx
from .layers import rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, dtype, expand: int = 2) -> dict:
    d_in = expand * d_model
    P = d_in // n_heads
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d_model)
    sci = 1.0 / math.sqrt(d_in)
    return {
        "w_up": (jax.random.normal(ks[0], (d_model, d_in)) * sc).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (d_model, d_in)) * sc).astype(dtype),
        "w_q": (jax.random.normal(ks[2], (d_in, d_in)) * sci).astype(dtype),
        "w_k": (jax.random.normal(ks[3], (d_in, d_in)) * sci).astype(dtype),
        "w_v": (jax.random.normal(ks[4], (d_in, d_in)) * sci).astype(dtype),
        "w_if": (jax.random.normal(ks[5], (d_in, 2 * n_heads)) * sci).astype(dtype),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,)), 3.0 * jnp.ones((n_heads,))]).astype(jnp.float32),
        "norm_w": jnp.zeros((d_in,), dtype),
        "w_down": (jax.random.normal(ks[6], (d_in, d_model)) * sci).astype(dtype),
    }


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, Pv, Pk) fp32
    n: jax.Array   # (B, H, Pk) fp32
    m: jax.Array   # (B, H) fp32


def mlstm_init_state(B: int, H: int, P: int) -> MLSTMState:
    return MLSTMState(
        C=jnp.zeros((B, H, P, P), jnp.float32),
        n=jnp.zeros((B, H, P), jnp.float32),
        m=jnp.full((B, H), -jnp.inf, jnp.float32),
    )


def _mlstm_cell(state: MLSTMState, q, k, v, i_raw, f_raw):
    """One stabilised mLSTM step.  q/k/v (B,H,P) fp32; gates (B,H) fp32."""
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    m_new = jnp.where(jnp.isinf(state.m), i_raw, m_new)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(logf + state.m - m_new)
    f = jnp.where(jnp.isinf(state.m), 0.0, f)
    C = f[..., None, None] * state.C + i[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f[..., None] * state.n + i[..., None] * k
    h_num = jnp.einsum("bhvk,bhk->bhv", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = h_num / h_den[..., None]
    return MLSTMState(C=C, n=n, m=m_new), h


def mlstm(params: dict, x: jax.Array, *, n_heads: int, expand: int = 2,
          norm_eps: float = 1e-6, ctx: ShardingCtx = NULL_CTX,
          state: Optional[MLSTMState] = None):
    """Full-sequence mLSTM mixer.  x (B,S,d) → (out, final state)."""
    B, S, d = x.shape
    d_in = expand * d
    H = n_heads
    P = d_in // H
    up = x @ params["w_up"]
    gate = x @ params["w_gate"]
    q = (up @ params["w_q"]).reshape(B, S, H, P).astype(jnp.float32) / math.sqrt(P)
    k = (up @ params["w_k"]).reshape(B, S, H, P).astype(jnp.float32) / math.sqrt(P)
    v = (up @ params["w_v"]).reshape(B, S, H, P).astype(jnp.float32)
    ifg = (up @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    i_raw, f_raw = jnp.split(ifg.reshape(B, S, 2 * H), 2, axis=-1)

    if state is None:
        state = mlstm_init_state(B, H, P)

    def step(s, inp):
        qt, kt, vt, it, ft = inp
        s, h = _mlstm_cell(s, qt, kt, vt, it, ft)
        return s, h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_raw, f_raw))
    state, hs = lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    h = rms_norm(h, params["norm_w"], norm_eps) * jax.nn.silu(gate)
    return h @ params["w_down"], state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    P = d_model // n_heads
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d_model)
    scp = 1.0 / math.sqrt(P)
    return {
        # 4 gates (i, f, z, o): input and block-diag recurrent weights
        "w_x": (jax.random.normal(ks[0], (d_model, 4 * d_model)) * sc).astype(dtype),
        "w_r": (jax.random.normal(ks[1], (4, n_heads, P, P)) * scp).astype(dtype),
        "b": jnp.zeros((4, d_model), jnp.float32).at[1].set(3.0),  # forget-bias
        "norm_w": jnp.zeros((d_model,), dtype),
        "w_out": (jax.random.normal(ks[2], (d_model, d_model)) * sc).astype(dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d) fp32
    n: jax.Array   # (B, d) fp32
    h: jax.Array   # (B, d) fp32
    m: jax.Array   # (B, d) fp32


def slstm_init_state(B: int, d: int) -> SLSTMState:
    z = jnp.zeros((B, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((B, d), -jnp.inf))


def _slstm_cell(state: SLSTMState, xw, params, H: int, P: int):
    """xw: (B, 4d) precomputed input projections for one step."""
    B = xw.shape[0]
    d = xw.shape[1] // 4
    hprev = state.h.reshape(B, H, P)
    rec = jnp.einsum("ghpq,bhq->gbhp", params["w_r"].astype(jnp.float32), hprev)
    gates = xw.reshape(B, 4, d).transpose(1, 0, 2) + rec.reshape(4, B, d) \
        + params["b"][:, None, :]
    i_raw, f_raw, z_raw, o_raw = gates
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    m_new = jnp.where(jnp.isinf(state.m), i_raw, m_new)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(logf + state.m - m_new)
    f = jnp.where(jnp.isinf(state.m), 0.0, f)
    c = f * state.c + i * jnp.tanh(z_raw)
    n = f * state.n + i
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm(params: dict, x: jax.Array, *, n_heads: int, norm_eps: float = 1e-6,
          ctx: ShardingCtx = NULL_CTX, state: Optional[SLSTMState] = None):
    B, S, d = x.shape
    P = d // n_heads
    xw = (x @ params["w_x"]).astype(jnp.float32)           # (B,S,4d)
    if state is None:
        state = slstm_init_state(B, d)

    def step(s, xwt):
        s = _slstm_cell(s, xwt, params, n_heads, P)
        return s, s.h

    state, hs = lax.scan(step, state, jnp.moveaxis(xw, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = rms_norm(h, params["norm_w"], norm_eps)
    out = h @ params["w_out"]
    # the sLSTM block's 4/3-factor post-FFN is added by the block assembly
    return out, state
