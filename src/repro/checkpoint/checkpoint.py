"""Sharded, atomic, async checkpointing with cross-mesh restore.

Format: one ``.npz`` per host (here: per process) holding every leaf as a
numpy array, plus a JSON manifest (step, tree structure, leaf paths).
Writes are atomic (tmp file + rename) and optionally run on a background
thread (``async_save``) so the training loop never blocks on disk.

Elastic scaling: ``restore`` takes the *target* shardings — leaves are
loaded as full arrays and re-placed with jax.device_put under the new mesh,
so a checkpoint saved on mesh A restores on mesh B (tested in
tests/test_checkpoint.py with different virtual-device meshes).
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                     for p in path) for path, _ in leaves]
    return keys, [l for _, l in leaves], treedef


def fsync_path(p: str | pathlib.Path) -> None:
    """fsync a file or directory by path — the POSIX dirent-durability
    idiom (a new/renamed file is only crash-durable once its parent
    directory is fsynced too).  Shared with the WAL (`repro.persist.wal`)."""
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(path: str | pathlib.Path, tree: Any, step: int,
         fsync: bool = False) -> None:
    """Atomic synchronous save.

    ``fsync=True`` additionally fsyncs the data/manifest files before their
    renames and the directory after — required when a caller treats a
    completed save as surviving *power loss* (e.g. the WAL-compaction rule
    in `repro.serve.engine`, which deletes log records once a snapshot
    covering them is durable).  The default (flush-only) survives process
    death."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    keys, leaves, _ = _flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(jax.device_get(l))
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)  # widen (exact); manifest restores dtype
        arrays[f"leaf_{i}"] = a
    manifest = {"step": int(step), "keys": keys,
                "dtypes": [str(getattr(l, "dtype",
                                       np.asarray(l).dtype)) for l in leaves],
                "shapes": [list(np.shape(l)) for l in leaves]}
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)   # savez appends .npz unless it already ends so
    if fsync:
        fsync_path(pathlib.Path(tmp))
    os.replace(tmp, path / "shard_0.npz")
    mtmp = path / "manifest.json.tmp"
    mtmp.write_text(json.dumps(manifest))
    if fsync:
        fsync_path(mtmp)
    os.replace(mtmp, path / "manifest.json")
    if fsync:
        fsync_path(path)          # the renames themselves ...
        fsync_path(path.parent)   # ... and this step dir's own dirent


class AsyncCheckpointer:
    """Background-thread writer: snapshot on the caller thread (device_get),
    serialize on the worker — the step loop resumes immediately.

    A failed background write is re-raised on the next ``wait()`` / ``save()``
    instead of dying silently in the worker thread — callers that rely on a
    checkpoint being durable (e.g. the WAL-compaction path in
    `repro.serve.engine`) must see the failure."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def _run(self, path, tree, step: int, fsync: bool) -> None:
        try:
            save(path, tree, step, fsync=fsync)
        except BaseException as e:      # surfaced on the next wait()
            self._error = e

    def save(self, path, tree, step: int, fsync: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self._thread = threading.Thread(
            target=self._run, args=(path, host_tree, step, fsync),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err


def latest_step(root: str | pathlib.Path) -> Optional[int]:
    root = pathlib.Path(root)
    steps = []
    for d in root.glob("step_*"):
        if (d / "manifest.json").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, tree_like: Any,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; re-shard onto
    ``shardings`` (pytree of NamedSharding) if given — this is the elastic
    rescale path (checkpoint saved on one mesh, restored on another)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "shard_0.npz")
    keys, leaves, treedef = _flatten(tree_like)
    assert keys == manifest["keys"], "checkpoint/tree structure mismatch"
    out = []
    sh_leaves = None
    if shardings is not None:
        _, sh_leaves, _ = _flatten(shardings)
    for i, like in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        dt = manifest["dtypes"][i]
        x = jax.numpy.asarray(arr)
        if str(x.dtype) != dt:
            x = x.astype(dt)           # narrow back (e.g. f32 → bf16, exact)
        if sh_leaves is not None:
            x = jax.device_put(x, sh_leaves[i])
        out.append(x)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
