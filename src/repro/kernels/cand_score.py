"""Pallas TPU kernel: candidate scoring for S-ANN queries.

After the bucket gather, the query must be scored against <= 3L candidate
vectors (paper Alg. 1).  This is a dense (M, d) tile workload: squared L2
distances computed in fp32 on the VPU, one tile of candidates per grid step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _kernel(q_ref, c_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)        # (1, d)
    c = c_ref[...].astype(jnp.float32)        # (TM, d)
    diff = c - q
    o_ref[...] = jnp.sum(diff * diff, axis=-1, keepdims=True)  # (TM, 1)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def cand_score(
    q: jax.Array,        # (d,)
    cands: jax.Array,    # (M, d)
    block_m: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # None = derive from the backend (interpret off on real TPUs), the same
    # policy ops.py applies — a literal True default silently handed direct
    # callers the Python-level Pallas emulator on TPU.
    interpret = resolve_interpret(interpret)
    M, d = cands.shape
    tm = min(block_m, M)
    out = pl.pallas_call(
        _kernel,
        grid=(pl.cdiv(M, tm),),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, 1), jnp.float32),
        interpret=interpret,
    )(q[None, :], cands)
    return out[:, 0]
