"""Pallas TPU kernel: SRP-LSH hashing (matmul + sign + universal fold).

The sketch hot path: every stream element / query is hashed by L*k signed
random projections.  On TPU this is one MXU matmul ``(TB, d) x (d, L*k)``
per tile of B, followed by VPU bit extraction and the multiply-shift fold —
all resident in VMEM.

Grid: 1-D over tiles of the batch dimension.  proj/mix are small enough
(d, L*k <= a few thousand) to pin entirely in VMEM per the BlockSpec below.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret

_MIX = 2654435761  # python int: materialised inside the kernel trace


def _kernel(x_ref, proj_ref, mix_ref, o_ref, *, n_buckets: int, L: int, k: int):
    x = x_ref[...].astype(jnp.float32)                 # (TB, d)
    proj = proj_ref[...].astype(jnp.float32)           # (d, L*k)
    y = jnp.dot(x, proj, preferred_element_type=jnp.float32)
    bits = (y >= 0.0).astype(jnp.uint32)               # (TB, L*k)
    mix = mix_ref[...].reshape(1, L * k).astype(jnp.uint32)
    prod = bits * mix
    acc = prod.reshape(x.shape[0], L, k).sum(axis=-1).astype(jnp.uint32)
    acc = acc * jnp.uint32(_MIX)
    o_ref[...] = (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_buckets", "block_b", "interpret"))
def srp_hash(
    x: jax.Array,            # (B, d)
    proj: jax.Array,         # (d, L*k)
    mix: jax.Array,          # (L, k) uint32
    n_buckets: int,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # None = derive from the backend, the same policy ops.py applies.
    interpret = resolve_interpret(interpret)
    B, d = x.shape
    L, k = mix.shape
    tb = min(block_b, B)
    grid = (pl.cdiv(B, tb),)
    out = pl.pallas_call(
        functools.partial(_kernel, n_buckets=n_buckets, L=L, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, L * k), lambda i: (0, 0)),
            pl.BlockSpec((L, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L), jnp.int32),
        interpret=interpret,
    )(x, proj, mix)
    return out
