"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition*; kernels must match it to
float tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MIX = jnp.uint32(2654435761)


def srp_hash_ref(x: jax.Array, proj: jax.Array, mix: jax.Array, n_buckets: int) -> jax.Array:
    """x (B, d), proj (d, L*k), mix (L, k) → codes (B, L) int32 in [0, n_buckets)."""
    B = x.shape[0]
    L, k = mix.shape
    y = x.astype(jnp.float32) @ proj.astype(jnp.float32)       # (B, L*k)
    bits = (y >= 0).astype(jnp.uint32).reshape(B, L, k)
    acc = (bits * mix[None]).sum(axis=-1) * _MIX
    return (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


def race_update_ref(counts: jax.Array, codes: jax.Array, sign: int = 1) -> jax.Array:
    """counts (L, W), codes (B, L) → counts + sign * histogram.

    Scatter-add (deterministic for integer counters) rather than a
    materialised (B, L, W) one-hot: O(B*L) memory instead of O(B*L*W)."""
    L, _ = counts.shape
    rows = jnp.broadcast_to(jnp.arange(L, dtype=codes.dtype), codes.shape)
    return counts.at[rows, codes].add(jnp.int32(sign))


def cand_score_ref(q: jax.Array, cands: jax.Array) -> jax.Array:
    """q (d,), cands (M, d) → squared L2 distances (M,) in fp32."""
    q = q.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    return jnp.sum((c - q[None, :]) ** 2, axis=-1)


def batch_score_ref(qs: jax.Array, cands: jax.Array) -> jax.Array:
    """qs (B, d), cands (B, M, d) → squared L2 distances (B, M) in fp32.

    Diff-based (no matmul identity): the batched form of `cand_score_ref`,
    bit-identical to vmapping it over the B axis."""
    q = qs.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    return jnp.sum((c - q[:, None, :]) ** 2, axis=-1)


def batch_score_topk_ref(qs: jax.Array, cands: jax.Array, ok: jax.Array,
                         k: int) -> tuple[jax.Array, jax.Array]:
    """Masked squared-L2 top-k per query (k = 1 ⇒ argmin): ``(d2 (B, k)
    ascending, idx (B, k) int32 into M)``.  Masked entries score inf; ties
    resolve to the lowest candidate index (`lax.top_k` semantics)."""
    d2 = jnp.where(ok, batch_score_ref(qs, cands), jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def sketch_decode_attn_ref(
    q: jax.Array,            # (Hkv, G, dh)
    k: jax.Array,            # (S, Hkv, dh)
    v: jax.Array,            # (S, Hkv, dh)
    block_live: jax.Array,   # (num_blocks,) bool — sketch-pruned block mask
    kv_len: jax.Array,       # () int32 — #valid cache positions
    block_size: int,
    softcap: float = 0.0,
) -> jax.Array:
    """Masked decode attention: softmax over positions whose block survives
    the sketch pruning AND lie within kv_len.  Returns (Hkv, G, dh) fp32."""
    S = k.shape[0]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum(
        "hgd,shd->hgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap and softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(S)
    live = block_live[pos // block_size] & (pos < kv_len)
    scores = jnp.where(live[None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # all-masked rows → zero output
    return jnp.einsum("hgs,shd->hgd", w, v.astype(jnp.float32))
