"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition*; kernels must match it to
float tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MIX = jnp.uint32(2654435761)


def srp_hash_ref(x: jax.Array, proj: jax.Array, mix: jax.Array, n_buckets: int) -> jax.Array:
    """x (B, d), proj (d, L*k), mix (L, k) → codes (B, L) int32 in [0, n_buckets)."""
    B = x.shape[0]
    L, k = mix.shape
    y = x.astype(jnp.float32) @ proj.astype(jnp.float32)       # (B, L*k)
    bits = (y >= 0).astype(jnp.uint32).reshape(B, L, k)
    acc = (bits * mix[None]).sum(axis=-1) * _MIX
    return (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


def race_update_ref(counts: jax.Array, codes: jax.Array, sign: int = 1) -> jax.Array:
    """counts (L, W), codes (B, L) → counts + sign * histogram.

    Scatter-add (deterministic for integer counters) rather than a
    materialised (B, L, W) one-hot: O(B*L) memory instead of O(B*L*W)."""
    L, _ = counts.shape
    rows = jnp.broadcast_to(jnp.arange(L, dtype=codes.dtype), codes.shape)
    return counts.at[rows, codes].add(jnp.int32(sign))


def cand_score_ref(q: jax.Array, cands: jax.Array) -> jax.Array:
    """q (d,), cands (M, d) → squared L2 distances (M,) in fp32."""
    q = q.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    return jnp.sum((c - q[None, :]) ** 2, axis=-1)


def batch_score_ref(qs: jax.Array, cands: jax.Array) -> jax.Array:
    """qs (B, d), cands (B, M, d) → squared L2 distances (B, M) in fp32.

    Diff-based (no matmul identity): the batched form of `cand_score_ref`,
    bit-identical to vmapping it over the B axis."""
    q = qs.astype(jnp.float32)
    c = cands.astype(jnp.float32)
    return jnp.sum((c - q[:, None, :]) ** 2, axis=-1)


def batch_score_topk_ref(qs: jax.Array, cands: jax.Array, ok: jax.Array,
                         k: int) -> tuple[jax.Array, jax.Array]:
    """Masked squared-L2 top-k per query (k = 1 ⇒ argmin): ``(d2 (B, k)
    ascending, idx (B, k) int32 into M)``.  Masked entries score inf; ties
    resolve to the lowest candidate index (`lax.top_k` semantics)."""
    d2 = jnp.where(ok, batch_score_ref(qs, cands), jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def swakde_segment_pass_ref(
    cell_ts: jax.Array,    # (R, G, levels, S) int32 — gathered EH rings
    cell_num: jax.Array,   # (R, G, levels) int32 — live buckets per level
    done: jax.Array,       # (R, G) int32 — arrivals already committed
    sorted_ts: jax.Array,  # (R, C) int32 — per-row stamps in sorted order
    seg_first: jax.Array,  # (R, G) int32 — first sorted position of segment
    seg_len: jax.Array,    # (R, G) int32 — arrivals hitting each segment
    *,
    window: int,
    maxb: int,
    n_levels: int,
    cap: int = 0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One closed-form sub-chunk commit pass over every (row, segment) pair.

    A segment is one cell's run of unit adds at ascending stamps
    ``sorted_ts[r, seg_first + done .. seg_first + seg_len)``.  Because the
    DGIM cascade's merge pattern depends only on per-level counts (stamps
    enter it solely through expiry), a run during which no bucket expires is
    binary-counter carry propagation — every level settles in one shot, like
    `core.eh.sum_eh_add` (Corollary 4.2), except the carried-up stamps are a
    bounded explicit prefix plus a stride-2^level window into ``sorted_ts``
    instead of a count of repeated ``t``s.

    The pass consumes the longest prefix of each segment's remaining
    arrivals that is expiry-free — every stamp live after expiring at the
    first arrival survives the last arrival's threshold, and the pass's own
    stamps span less than ``window`` — capped at ``cap`` arrivals when
    ``cap > 0`` (the heavy-cell cap: bounds per-pass work for hot cells).
    Segments with nothing to consume are left untouched (lazy expiry, like
    the per-point path).  Callers loop until ``done == seg_len``; the result
    is bit-identical (dead slots included) to replaying the arrivals through
    ``eh_add`` one by one.
    """
    R, G, LV, S = cell_ts.shape
    C = sorted_ts.shape[1]
    i32 = jnp.int32
    INT_MAX = jnp.iinfo(jnp.int32).max
    sidx = jnp.arange(S, dtype=i32)

    active = done < seg_len                                      # (R, G)
    start = seg_first + done                                     # (R, G)
    t_first = jnp.take_along_axis(
        sorted_ts, jnp.clip(start, 0, C - 1), axis=1)            # (R, G)

    # --- expire active cells at their first arrival ------------------------
    live = (sidx < cell_num[..., None]) & \
        (cell_ts > t_first[..., None, None] - window)            # (R,G,LV,S)
    cell_num = jnp.where(active[..., None],
                         live.sum(-1).astype(i32), cell_num)
    oldest = jnp.where(live, cell_ts, INT_MAX).min((-2, -1))     # (R, G)

    # --- expiry-free pass length -------------------------------------------
    # Arrivals are ascending per segment, so both conditions select a prefix:
    # no surviving bucket may expire (thr < oldest) and the pass's own first
    # stamp must outlive its last (thr < t_first).
    pos = jnp.arange(C, dtype=i32)[None, None, :]
    thr = sorted_ts[:, None, :] - window                         # (R, 1, C)
    in_seg = (pos >= start[..., None]) & \
        (pos < (seg_first + seg_len)[..., None])
    ok = (thr < oldest[..., None]) & (thr < t_first[..., None])
    p = (in_seg & ok).sum(-1).astype(i32)                        # (R, G)
    if cap:
        p = jnp.minimum(p, i32(cap))
    p = jnp.where(active, jnp.minimum(p, seg_len - done), 0)

    # --- the per-level closed form -----------------------------------------
    # Carried-up arrivals at each level: an explicit prefix P[:np] (old ring
    # stamps consumed by lower-level merges) followed by r stamps read from
    # sorted_ts at positions b, b+stride, b+2*stride, ...
    def queue(i, ts_l, m0, P, np_, b, stride):
        """Oldest-first queue lookup: reversed live ring ++ P ++ strided
        tail.  ``i`` (R, G, n) int32; out-of-range reads are clipped (the
        caller masks them)."""
        K = (m0 + np_)[..., None]
        ring = jnp.take_along_axis(
            ts_l, jnp.clip(m0[..., None] - 1 - i, 0, S - 1), -1)
        pre = jnp.take_along_axis(
            P, jnp.clip(i - m0[..., None], 0, S - 1), -1)
        tpos = jnp.clip(b[..., None] + (i - K) * stride[..., None], 0, C - 1)
        tail = jnp.take_along_axis(
            jnp.broadcast_to(sorted_ts[:, None, :], (R, G, C)), tpos, -1)
        return jnp.where(i < m0[..., None], ring,
                         jnp.where(i < K, pre, tail))

    def level_body(l, carry):
        cts, cnum, P, np_, b, stride, r = carry
        ts_l = jax.lax.dynamic_index_in_dim(cts, l, axis=2, keepdims=False)
        m0 = jax.lax.dynamic_index_in_dim(cnum, l, axis=2, keepdims=False)
        p_l = np_ + r                                            # arrivals
        K = m0 + np_
        total = m0 + p_l
        # Merge count: the level fills to maxb+1 once, then every second
        # arrival fires (the sum_eh_add saturation dynamics).  The top
        # level never merges.
        mu = jnp.where((total <= maxb) | (l == n_levels - 1),
                       0, 1 + (p_l - (maxb + 1 - m0)) // 2)
        new_num = total - 2 * mu
        # Final ring: the p_l arrivals newest-first, then the old ring
        # shifted — exactly the writes p_l sequential prepends perform.
        arr = queue(total[..., None] - 1 - sidx, ts_l, m0, P, np_, b, stride)
        old = jnp.take_along_axis(
            ts_l, jnp.clip(sidx - p_l[..., None], 0, S - 1), -1)
        new_ts = jnp.where(sidx < p_l[..., None], arr, old)
        # Carries up: merge j consumes queue[2j], queue[2j+1] and emits the
        # newer stamp queue[2j+1].  Those with 2j+1 < K become the explicit
        # prefix; the rest are a stride-doubled window into sorted_ts.
        np_n = jnp.minimum(mu, K // 2)
        r_n = mu - np_n
        P_n = queue(2 * sidx + 1, ts_l, m0, P, np_, b, stride)
        b_n = jnp.clip(b + (2 * np_n + 1 - K) * stride, 0, C - 1)
        cts = jax.lax.dynamic_update_index_in_dim(cts, new_ts, l, axis=2)
        cnum = jax.lax.dynamic_update_index_in_dim(cnum, new_num, l, axis=2)
        return (cts, cnum, P_n, np_n, b_n, stride * 2, r_n)

    init = (cell_ts, cell_num, jnp.zeros((R, G, S), i32),
            jnp.zeros((R, G), i32), jnp.clip(start, 0, C - 1),
            jnp.ones((R, G), i32), p)
    cell_ts, cell_num = jax.lax.fori_loop(0, LV, level_body, init)[:2]
    return cell_ts, cell_num, done + p


def sann_table_scatter_ref(
    tables: jax.Array,     # (L, n_buckets, bucket_cap) int32
    table_ptr: jax.Array,  # (L, n_buckets) int32 — per-bucket ring pointers
    s_l: jax.Array,        # (B*L,) int32 — row of each sorted append
    s_c: jax.Array,        # (B*L,) int32 — bucket code of each append
    rank: jax.Array,       # (B*L,) int32 — rank within its (row, code) run
    val: jax.Array,        # (B*L,) int32 — slot id to write (-1 tombstone)
    mask: jax.Array,       # (B*L,) bool — append lands in the final window
) -> jax.Array:
    """Sorted-segment ring append: entry i lands at ring position
    ``(table_ptr[s_l, s_c] + rank) % bucket_cap`` of its bucket; masked-out
    entries are dropped.  Entries are sorted by (row, code), so the writes
    of one bucket are contiguous — which is what the Pallas kernel tiles
    over."""
    L, n_buckets, bucket_cap = tables.shape
    ring_pos = (table_ptr[s_l, s_c] + rank) % bucket_cap
    flat = (s_l * n_buckets + s_c) * bucket_cap + ring_pos
    tsize = jnp.int32(tables.size)
    return tables.reshape(-1).at[
        jnp.where(mask, flat, tsize)].set(val, mode="drop").reshape(
        tables.shape)


def sketch_decode_attn_ref(
    q: jax.Array,            # (Hkv, G, dh)
    k: jax.Array,            # (S, Hkv, dh)
    v: jax.Array,            # (S, Hkv, dh)
    block_live: jax.Array,   # (num_blocks,) bool — sketch-pruned block mask
    kv_len: jax.Array,       # () int32 — #valid cache positions
    block_size: int,
    softcap: float = 0.0,
) -> jax.Array:
    """Masked decode attention: softmax over positions whose block survives
    the sketch pruning AND lie within kv_len.  Returns (Hkv, G, dh) fp32."""
    S = k.shape[0]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum(
        "hgd,shd->hgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap and softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(S)
    live = block_live[pos // block_size] & (pos < kv_len)
    scores = jnp.where(live[None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # all-masked rows → zero output
    return jnp.einsum("hgs,shd->hgd", w, v.astype(jnp.float32))
