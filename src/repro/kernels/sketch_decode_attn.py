"""Pallas TPU kernel: sketch-pruned flash-decode attention.

The TPU-native adaptation of the paper's bucket probing (DESIGN.md §5.4):
instead of gathering scattered candidate keys, the KV cache is viewed in
``block_size``-token blocks; a per-block LSH signature (OR of SRP bits of
the keys in the block) is compared against the query signature, yielding a
*live block list*.  The kernel visits **only** live blocks via scalar
prefetch (the grid's block index map reads the live-block id array), and
runs a standard flash-decode accumulation (running max / denominator) per
KV head with GQA group-size G queries.

With all blocks live this degrades gracefully to plain flash-decode, which
is what the non-sketch architectures use for their decode shapes.

Grid: (Hkv, nb_max) — nb_max is the static live-list capacity; entries
beyond ``n_live`` are skipped with pl.when (no DMA wasted on TPU since the
index map clamps to block 0 and Mosaic elides revisited loads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from jax.experimental.pallas import tpu as pltpu

from .dispatch import resolve_interpret


def _kernel(
    bids_ref, nlive_ref, kvlen_ref,            # scalar-prefetch refs
    q_ref, k_ref, v_ref,                       # VMEM blocks
    o_ref,
    m_scr, l_scr, acc_scr,
    *, block_size: int, softcap: float, nb_max: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j < nlive_ref[0])
    def _():
        q = q_ref[0].astype(jnp.float32)               # (G, dh)
        k = k_ref[:, 0, :].astype(jnp.float32)         # (BS, dh)
        v = v_ref[:, 0, :].astype(jnp.float32)
        scale = 1.0 / (q.shape[-1] ** 0.5)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, BS)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        bid = bids_ref[j]
        pos = bid * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        s = jnp.where(pos < kvlen_ref[0], s, -jnp.inf)

        m_prev = m_scr[...]                            # (G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(jnp.isnan(alpha), 0.0, alpha)  # -inf - -inf guard
        p = jnp.exp(s - m_new)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nb_max - 1)
    def _():
        l = l_scr[...]
        o_ref[0] = jnp.where(l > 0.0, acc_scr[...] / jnp.maximum(l, 1e-30), 0.0)


@functools.partial(
    jax.jit, static_argnames=("block_size", "softcap", "interpret"))
def sketch_decode_attn(
    q: jax.Array,           # (Hkv, G, dh)
    k: jax.Array,           # (S, Hkv, dh)
    v: jax.Array,           # (S, Hkv, dh)
    block_ids: jax.Array,   # (nb_max,) int32 — live block indices, -1 padded
    n_live: jax.Array,      # (1,) int32
    kv_len: jax.Array,      # (1,) int32
    block_size: int = 512,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # None = derive from the backend, the same policy ops.py applies.
    interpret = resolve_interpret(interpret)
    Hkv, G, dh = q.shape
    S = k.shape[0]
    assert S % block_size == 0, (S, block_size)
    nb_max = block_ids.shape[0]
    safe_bids = jnp.maximum(block_ids, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Hkv, nb_max),
        in_specs=[
            pl.BlockSpec((1, G, dh), lambda h, j, bids, nl, kl: (h, 0, 0)),
            pl.BlockSpec((block_size, 1, dh),
                         lambda h, j, bids, nl, kl: (bids[j], h, 0)),
            pl.BlockSpec((block_size, 1, dh),
                         lambda h, j, bids, nl, kl: (bids[j], h, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, dh), lambda h, j, bids, nl, kl: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, block_size=block_size, softcap=softcap, nb_max=nb_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, G, dh), jnp.float32),
        interpret=interpret,
    )(safe_bids, n_live, kv_len, q, k, v)


def live_blocks_from_sketch(
    q_sig: jax.Array,        # (bits,) bool — query SRP signature
    block_sigs: jax.Array,   # (num_blocks, bits) bool — OR-reduced per block
    kv_len: jax.Array,       # () int32
    block_size: int,
    min_match: int,
) -> tuple[jax.Array, jax.Array]:
    """Sketch pruning decision: a block is live iff >= min_match of the
    query's set signature bits are present in the block signature AND the
    block intersects [0, kv_len).  Returns (block_ids padded with -1, n_live).
    """
    nb = block_sigs.shape[0]
    match = (block_sigs & q_sig[None, :]).sum(-1)
    in_range = jnp.arange(nb) * block_size < kv_len
    live = (match >= min_match) & in_range
    # Stable compaction: live block ids first, -1 padding after.
    order = jnp.argsort(jnp.where(live, 0, 1), stable=True)
    ids = jnp.where(live[order], order, -1).astype(jnp.int32)
    return ids, live.sum().astype(jnp.int32)[None]
