"""Jitted public wrappers for the Pallas kernels.

Dispatch policy (single source of truth: `dispatch.py`):
  * on TPU backends → compiled Pallas kernels;
  * on CPU → the pure-jnp oracle (`ref.py`) by default, because Pallas
    interpret mode is a Python-level emulator (correct but slow) — set
    ``REPRO_FORCE_PALLAS=1`` to route through interpret-mode kernels
    (this is what tests/test_kernels.py does when comparing vs ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import batch_score as _bs
from . import cand_score as _cs
from . import ingest_commit as _ic
from . import race_update as _ru
from . import ref
from . import sketch_decode_attn as _sda
from . import srp_hash as _sh
from .dispatch import resolve_interpret, use_pallas as _use_pallas


def _interpret() -> bool:
    return resolve_interpret(None)


def srp_hash(x: jax.Array, proj: jax.Array, mix: jax.Array, n_buckets: int) -> jax.Array:
    if _use_pallas():
        return _sh.srp_hash(x, proj, mix, n_buckets, interpret=_interpret())
    return ref.srp_hash_ref(x, proj, mix, n_buckets)


def race_hist(codes: jax.Array, W: int) -> jax.Array:
    if _use_pallas():
        return _ru.race_hist(codes, W, interpret=_interpret())
    if W <= 128:
        # CPU mirror of the TPU kernel's one-hot compare + reduce (XLA CPU
        # scatters cost ~60ns/element; a fused compare-reduce over a small
        # W is several times faster).  Chunked over the batch so the
        # broadcast tile stays cache-resident.
        B, L = codes.shape
        cb = 128
        pad = (-B) % cb
        padded = jnp.pad(codes, ((0, pad), (0, 0)), constant_values=-1)
        iota = jnp.arange(W, dtype=codes.dtype)

        def step(acc, blk):
            return acc + (blk[:, :, None] == iota).sum(0, dtype=jnp.int32), None

        acc, _ = jax.lax.scan(step, jnp.zeros((L, W), jnp.int32),
                              padded.reshape(-1, cb, L))
        return acc
    return ref.race_update_ref(jnp.zeros((codes.shape[1], W), jnp.int32), codes)


def cand_score(q: jax.Array, cands: jax.Array) -> jax.Array:
    if _use_pallas():
        return _cs.cand_score(q, cands, interpret=_interpret())
    return ref.cand_score_ref(q, cands)


def batch_score_topk(qs: jax.Array, cands: jax.Array, ok: jax.Array,
                     k: int) -> tuple[jax.Array, jax.Array]:
    """Fused batched scorer: masked squared-L2 top-k of ``cands (B, M, d)``
    against ``qs (B, d)`` → ``(d2 (B, k) ascending, idx (B, k) int32)``.

    k = 1 is the argmin of the (c, r)-query path.  TPU: one Pallas pass
    (MXU matmul identity, running top-k across M tiles).  CPU: the
    diff-based oracle — bit-identical to the per-query `cand_score` path,
    which is what makes the fused engine exactly match the vmapped oracle
    in tests/test_query_batched.py."""
    if _use_pallas():
        return _bs.batch_score_topk(qs, cands, ok, k, interpret=_interpret())
    return ref.batch_score_topk_ref(qs, cands, ok, k)


def sketch_decode_attn(q, k, v, block_ids, n_live, kv_len,
                       block_size: int = 512, softcap: float = 0.0) -> jax.Array:
    if _use_pallas():
        return _sda.sketch_decode_attn(
            q, k, v, block_ids, n_live, kv_len,
            block_size=block_size, softcap=softcap, interpret=_interpret())
    nb = (k.shape[0] + block_size - 1) // block_size
    live = jnp.zeros((nb,), bool).at[jnp.maximum(block_ids, 0)].set(
        block_ids >= 0)
    return ref.sketch_decode_attn_ref(
        q, k, v, live, kv_len[0], block_size, softcap)


def swakde_segment_pass(cell_ts, cell_num, done, sorted_ts, seg_first,
                        seg_len, *, window: int, maxb: int, n_levels: int,
                        cap: int = 0):
    """One expiry-free closed-form commit pass over the prepared segments
    (ingest tentpole — see `ref.swakde_segment_pass_ref` for the contract).
    TPU: the tiled `ingest_commit.swakde_segment_pass` kernel; CPU: the
    oracle, which is the fast path (one fused pass replaces the per-add
    SumEH replay, independent of segment length)."""
    if _use_pallas():
        return _ic.swakde_segment_pass(
            cell_ts, cell_num, done, sorted_ts, seg_first, seg_len,
            window=window, maxb=maxb, n_levels=n_levels, cap=cap,
            interpret=_interpret())
    return ref.swakde_segment_pass_ref(
        cell_ts, cell_num, done, sorted_ts, seg_first, seg_len,
        window=window, maxb=maxb, n_levels=n_levels, cap=cap)


def sann_table_scatter(tables, table_ptr, s_l, s_c, rank, val, mask):
    """Sorted-segment ring append into the S-ANN hash tables (entries are
    sorted by (row, code); see `ref.sann_table_scatter_ref`)."""
    if _use_pallas():
        return _ic.sann_table_scatter(
            tables, table_ptr, s_l, s_c, rank, val, mask,
            interpret=_interpret())
    return ref.sann_table_scatter_ref(tables, table_ptr, s_l, s_c, rank,
                                      val, mask)


live_blocks_from_sketch = _sda.live_blocks_from_sketch
