"""Pallas TPU kernel: RACE histogram accumulation.

Given LSH codes (B, L) the sketch update increments ``counts[l, codes[b, l]]``
for every (b, l).  Scatter is hostile to the VPU, so the TPU-native form is a
**one-hot compare + reduce** per row tile, accumulated in a VMEM-resident
(1, W) output block that the sequential grid revisits across batch chunks —
a classic TPU histogram.

Grid: (L, ceil(B / CB)); the output row block is revisited for every batch
chunk (TPU grids execute sequentially, so accumulation is safe).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _kernel(codes_ref, o_ref, *, B: int, cb: int):
    bc = pl.program_id(1)

    @pl.when(bc == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]                                   # (CB, 1) int32
    W = o_ref.shape[1]
    row = bc * cb + jax.lax.broadcasted_iota(jnp.int32, (cb, 1), 0)
    valid = row < B                                          # mask batch padding
    buckets = jax.lax.broadcasted_iota(jnp.int32, (cb, W), 1)
    hit = (buckets == codes) & valid                         # (CB, W)
    o_ref[...] += hit.astype(jnp.int32).sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("W", "block_b", "interpret"))
def race_hist(
    codes: jax.Array,      # (B, L) int32
    W: int,
    block_b: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Histogram of codes per row: out[l, w] = #{b : codes[b, l] == w}."""
    # None = derive from the backend, the same policy ops.py applies.
    interpret = resolve_interpret(interpret)
    B, L = codes.shape
    cb = min(block_b, B)
    grid = (L, pl.cdiv(B, cb))
    return pl.pallas_call(
        functools.partial(_kernel, B=B, cb=cb),
        grid=grid,
        in_specs=[pl.BlockSpec((cb, 1), lambda l, bc: (bc, l))],
        out_specs=pl.BlockSpec((1, W), lambda l, bc: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((L, W), jnp.int32),
        interpret=interpret,
    )(codes)
