"""Pallas TPU kernel: fused batched candidate scoring for the query engine.

The batched query path (core.sann.sann_query_batch / sann_query_topk_batch)
gathers, for a whole block of B queries at once, each query's candidate
vectors — a ``(B, M, d)`` tensor.  This kernel scores the entire block in
one pass:

  * squared L2 via the MXU matmul identity ``‖q‖² + ‖c‖² − 2 qᵀc`` — one
    batched ``(TB, d) × (TB, TM, d)`` contraction per grid step instead of
    materialising the ``(TB, TM, d)`` difference tensor;
  * a masked **fused top-k** (k = 1 ⇒ argmin) carried across the M tiles in
    the revisited output block, so the full ``(B, M)`` distance matrix never
    leaves VMEM.

Grid: ``(B tiles, M tiles)`` with M innermost; the ``(TB, k)`` output block
is revisited across the M tiles (TPU grids run sequentially, so the running
top-k accumulation is safe — same pattern as `race_update.race_hist`).

Tie-breaking: within one M tile, equal distances resolve to the lowest
candidate index (like `lax.top_k` on the full matrix); across tiles, an
earlier tile's candidate wins over an equal-distance later one.  The only
divergence from the unfused oracle is therefore exact distance ties that
span tile boundaries — duplicate slots in S-ANN are deduplicated *before*
scoring, so the engine never depends on that order.

Numerics: the matmul identity loses ~1e-6 absolute on d2 to cancellation
(clamped at 0), vs the diff-based oracle `ref.batch_score_ref` — same
tolerance class as `cand_score` (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _kernel(q_ref, c_ref, ok_ref, od_ref, oi_ref, *, k: int, M: int, tm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        od_ref[...] = jnp.full_like(od_ref, jnp.inf)
        oi_ref[...] = jnp.zeros_like(oi_ref)

    q = q_ref[...].astype(jnp.float32)                    # (TB, d)
    c = c_ref[...].astype(jnp.float32)                    # (TB, TM, d)
    ok = ok_ref[...] != 0                                 # (TB, TM)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)           # (TB, 1)
    cn = jnp.sum(c * c, axis=-1)                          # (TB, TM)
    # One batched MXU contraction: qc[b, m] = q[b] · c[b, m].
    qc = jax.lax.dot_general(
        q, c, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (TB, TM)
    d2 = jnp.maximum(qn + cn - 2.0 * qc, 0.0)
    gidx = j * tm + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(ok & (gidx < M), d2, jnp.inf)          # mask + M padding

    # Merge the tile into the running (TB, k) top-k held in the output block.
    all_d = jnp.concatenate([od_ref[...], d2], axis=1)    # (TB, k + TM)
    all_i = jnp.concatenate([oi_ref[...], gidx], axis=1)
    neg, sel = jax.lax.top_k(-all_d, k)
    od_ref[...] = -neg
    oi_ref[...] = jnp.take_along_axis(all_i, sel, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k", "block_b", "block_m", "interpret"))
def batch_score_topk(
    qs: jax.Array,       # (B, d)
    cands: jax.Array,    # (B, M, d) — per-query candidate vectors
    ok: jax.Array,       # (B, M) bool — score mask (False ⇒ distance inf)
    k: int,
    block_b: int = 128,
    block_m: int = 256,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Masked squared-L2 top-k per query: ``(d2 (B, k) ascending, idx (B, k)
    int32 into M)``.  Fully-masked rows return d2 = inf, idx = 0."""
    interpret = resolve_interpret(interpret)
    B, M, d = cands.shape
    if B == 0 or M == 0:   # empty batch/candidates: no grid to launch
        return (jnp.full((B, k), jnp.inf, jnp.float32),
                jnp.zeros((B, k), jnp.int32))
    tb = min(block_b, B)
    tm = min(block_m, M)
    grid = (pl.cdiv(B, tb), pl.cdiv(M, tm))
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, M=M, tm=tm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, tm, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tb, tm), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ),
        interpret=interpret,
    )(qs, cands, ok.astype(jnp.int32))
    return out_d, out_i
