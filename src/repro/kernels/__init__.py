"""Pallas TPU kernels (compute hot-spots) + jnp oracles.

Modules: srp_hash, race_update, cand_score, batch_score, sketch_decode_attn;
`ops` is the dispatching public API, `ref` holds the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
