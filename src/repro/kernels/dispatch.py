"""Kernel dispatch policy — the single source of truth.

TPU backends run compiled Pallas kernels; on every other backend the
Pallas interpreter is a Python-level emulator (correct but slow), so the
pure-jnp oracles (`ref.py`) are preferred and interpret mode is only used
when explicitly requested.  ``REPRO_FORCE_PALLAS=1`` forces the Pallas
path off-TPU (interpret mode) — what tests/test_kernels.py uses to
compare kernels against the oracles.

Both `ops.py` (oracle-vs-kernel routing) and every kernel wrapper's
``interpret=None`` default resolve through here, so the policy cannot
drift between call sites.
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas() -> bool:
    """Route through the Pallas kernel (vs the jnp oracle)?"""
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return on_tpu()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → derive from the backend: compiled on real TPUs,
    interpret mode everywhere else."""
    return (not on_tpu()) if interpret is None else interpret
