"""Pallas ingest-commit kernels: the two-phase commit half as tiled passes.

Two kernels (DESIGN.md §12):

  * ``swakde_segment_pass`` — the closed-form segment-reduce SumEH commit:
    one (row_block, segment_block) tile walk over the per-row sorted cell
    segments emitted by `core.swakde.swakde_prepare_chunk`, applying the
    Corollary-4.2 cascade per segment in one fused pass (no per-add loop).
    The tile math *is* `kernels.ref.swakde_segment_pass_ref` — the kernel
    loads a tile, runs the identical closed form, and stores it back, so
    CPU-oracle and Pallas paths are one implementation.
  * ``sann_table_scatter`` — the sorted-segment ring append of
    `core.sann.sann_commit_chunk`: entries arrive sorted by (row, code), so
    each grid step owns one row's (n_buckets, bucket_cap) table block and
    replays its appends as one coalesced write pass.

Both kernels follow the established dispatch contract (`kernels/ops.py`):
TPU backends compile them; CPU runs the `ref.py` oracles (bit-identical —
the engine paths never change semantics); ``interpret=None`` derives
interpret mode from the backend, and tests/test_kernels.py pins
interpret-mode equality against the oracles.

TPU note: the EH tile shapes (levels, slots) are far below the fp32
(8, 128) native tile, so a production TPU deployment would pad slots up to
a lane multiple; the tiling structure (segments × levels ring walk,
row-contiguous scatter) is what these kernels establish.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .dispatch import resolve_interpret


def _seg_pass_kernel(cell_ts_ref, cell_num_ref, done_ref, sorted_ts_ref,
                     seg_first_ref, seg_len_ref,
                     ts_out_ref, num_out_ref, done_out_ref,
                     *, window: int, maxb: int, n_levels: int, cap: int):
    """One (row, segment_block) tile: run the closed-form pass on the tile."""
    cts, cnum, done = ref.swakde_segment_pass_ref(
        cell_ts_ref[...], cell_num_ref[...], done_ref[...],
        sorted_ts_ref[...], seg_first_ref[...], seg_len_ref[...],
        window=window, maxb=maxb, n_levels=n_levels, cap=cap)
    ts_out_ref[...] = cts
    num_out_ref[...] = cnum
    done_out_ref[...] = done


@functools.partial(
    jax.jit, static_argnames=("window", "maxb", "n_levels", "cap",
                              "block_g", "interpret"))
def swakde_segment_pass(cell_ts: jax.Array, cell_num: jax.Array,
                        done: jax.Array, sorted_ts: jax.Array,
                        seg_first: jax.Array, seg_len: jax.Array,
                        *, window: int, maxb: int, n_levels: int,
                        cap: int = 0, block_g: int = 8,
                        interpret: bool | None = None):
    """Tiled closed-form sub-chunk commit pass (see
    `ref.swakde_segment_pass_ref` for the contract): grid walks
    (row, segment_block) tiles; each tile holds its segments' EH rings and
    the whole row's sorted stamps (the strided carry windows read from it).
    """
    interpret = resolve_interpret(interpret)
    R, G, LV, S = cell_ts.shape
    C = sorted_ts.shape[1]
    bg = min(block_g, G)
    pad = (-G) % bg
    if pad:
        # Padding segments are empty (seg_len = 0) → the pass is identity.
        zi = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        cell_ts = jnp.pad(cell_ts, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cell_num = jnp.pad(cell_num, ((0, 0), (0, pad), (0, 0)))
        done, seg_first, seg_len = zi(done), zi(seg_first), zi(seg_len)
    Gp = G + pad
    grid = (R, Gp // bg)
    seg_spec = pl.BlockSpec((1, bg), lambda i, j: (i, j))
    out = pl.pallas_call(
        functools.partial(_seg_pass_kernel, window=window, maxb=maxb,
                          n_levels=n_levels, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bg, LV, S), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bg, LV), lambda i, j: (i, j, 0)),
            seg_spec,
            pl.BlockSpec((1, C), lambda i, j: (i, 0)),
            seg_spec,
            seg_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, bg, LV, S), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bg, LV), lambda i, j: (i, j, 0)),
            seg_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, Gp, LV, S), cell_ts.dtype),
            jax.ShapeDtypeStruct((R, Gp, LV), cell_num.dtype),
            jax.ShapeDtypeStruct((R, Gp), done.dtype),
        ],
        interpret=interpret,
    )(cell_ts, cell_num, done, sorted_ts, seg_first, seg_len)
    cts, cnum, dn = out
    if pad:
        cts, cnum, dn = cts[:, :G], cnum[:, :G], dn[:, :G]
    return cts, cnum, dn


def _sann_scatter_kernel(ptr_ref, s_l_ref, s_c_ref, rank_ref, val_ref,
                         mask_ref, tab_in_ref, tab_ref, *, bucket_cap: int):
    """One row's table block: replay the row's sorted appends in order.

    ``tab_in_ref`` is aliased onto ``tab_ref`` (input_output_aliases), so
    untouched buckets keep their prior contents without an explicit copy.
    """
    del tab_in_ref
    row = pl.program_id(0)
    E = s_l_ref.shape[0]

    def body(e, _):
        @pl.when(mask_ref[e] & (s_l_ref[e] == row))
        def _():
            c = s_c_ref[e]
            rp = (ptr_ref[0, c] + rank_ref[e]) % bucket_cap
            tab_ref[0, c, rp] = val_ref[e]
        return 0

    jax.lax.fori_loop(0, E, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sann_table_scatter(tables: jax.Array, table_ptr: jax.Array,
                       s_l: jax.Array, s_c: jax.Array, rank: jax.Array,
                       val: jax.Array, mask: jax.Array,
                       interpret: bool | None = None) -> jax.Array:
    """Sorted-segment ring append (see `ref.sann_table_scatter_ref`): one
    grid step per row; appends are sorted by (row, code), so each step's
    writes walk its block's buckets coalesced, in append order."""
    interpret = resolve_interpret(interpret)
    L, NB, cap = tables.shape
    E = s_l.shape[0]
    if E == 0:
        return tables
    return pl.pallas_call(
        functools.partial(_sann_scatter_kernel, bucket_cap=cap),
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, NB), lambda i: (i, 0)),
            pl.BlockSpec((E,), lambda i: (0,)),
            pl.BlockSpec((E,), lambda i: (0,)),
            pl.BlockSpec((E,), lambda i: (0,)),
            pl.BlockSpec((E,), lambda i: (0,)),
            pl.BlockSpec((E,), lambda i: (0,)),
            pl.BlockSpec((1, NB, cap), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, NB, cap), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, NB, cap), tables.dtype),
        input_output_aliases={6: 0},
        interpret=interpret,
    )(table_ptr, s_l, s_c, rank, val, mask, tables)
