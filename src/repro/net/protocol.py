"""Length-prefixed binary RPC wire protocol for the network cluster
(DESIGN.md §16).

One frame per message, reusing the WAL's framing idiom (`persist/wal.py`)
so the whole stack has exactly one on-the-wire record shape:

    frame  := header | body
    header := magic u32 | msg_id u64 | kind u8 | body_len u32 | crc32(body) u32
    body   := json_len u32 | json meta (utf-8) | .npz archive of arrays

The body carries a small JSON metadata dict (scalars: versions, query
kinds, error descriptions) plus an optional numpy ``.npz`` archive for
bulk payloads (ingest chunks, query batches, snapshot state leaves) —
npz preserves dtypes and byte layout exactly, which is what the cluster's
bit-exactness contract needs.  Everything is stdlib + numpy: no new
dependency.

Failure model — every malformed input fails LOUDLY with `ProtocolError`
instead of hanging or desyncing (tests/test_net.py):

  * truncated header/body (peer died mid-frame)  → "truncated frame"
  * wrong magic (not our protocol / desynced)    → "bad magic"
  * CRC mismatch (corrupt body)                  → "crc mismatch"
  * body_len > ``max_body``                      → rejected before any
    allocation or read of the oversized payload
  * HELLO version mismatch                       → rejected by both sides

`Channel` is the client side: one socket, one outstanding request
(request/reply in lockstep, serialized by a lock — the coordinator's
concurrency comes from having one channel per worker, not pipelining).
Sockets always carry a timeout; after any send/recv failure — including a
timeout — the channel marks itself *broken* and refuses further calls: a
late reply landing after a timed-out request would be attributed to the
next call and silently corrupt the framing, so a broken channel must be
torn down and rebuilt (the failover layer does exactly that).

Fault injection (`repro.persist.faults`): the coordinator side fires
``net.connect`` / ``net.send`` / ``net.recv`` (scoped ``worker_<w>/``)
around each operation.  ``net.send`` fires *before* any bytes go out, so
a ``drop`` there leaves the channel intact and cleanly retryable; any
fault after bytes went out breaks the channel like a real peer failure.
"""
from __future__ import annotations

import io
import json
import socket
import struct
import threading
import zlib
from typing import Optional, Tuple

import numpy as np

from repro.persist import faults

PROTOCOL_VERSION = 1

_MAGIC = 0x53524331  # "SRC1" — sketch RPC v1 framing
_HEADER = struct.Struct("<IQBII")
_JLEN = struct.Struct("<I")

# Hard frame cap: a body_len above this is rejected *before* reading or
# allocating the payload (a corrupt/hostile length field must not OOM the
# peer).  Generous for real traffic: the largest frames are worker state
# snapshots, and the dev-shape sketches are well under this.
MAX_BODY = 256 << 20

# Message kinds (client request / server reply share the space).
K_HELLO = 1          # version handshake -> OK {version, session, engine}
K_OK = 2             # generic success reply
K_ERR = 3            # failure reply {error, type, transient, wal_accepted}
K_INGEST = 4         # arrays {xs} -> OK (WAL-logged + queued on the worker)
K_FLUSH = 5          # wait for every queued chunk to commit
K_QUERY = 6          # {kind} arrays {qs} -> OK {num_leaves} arrays {l0..}
K_DELETE = 7         # arrays {x} -> OK (turnstile delete)
K_HEALTH = 8         # -> OK health() + {version, steps, count}
K_STATS = 9          # -> OK stats()
K_SNAPSHOT = 10      # -> OK {version, num_leaves} arrays {l0..lN}
K_RECOVER = 11       # -> OK {replayed}
K_SHUTDOWN = 12      # graceful stop: close engine, reply OK, exit
K_ADVANCE_CLOCK = 13  # {target} -> OK (SW-AKDE global stream clock)

KIND_NAMES = {
    K_HELLO: "hello", K_OK: "ok", K_ERR: "err", K_INGEST: "ingest",
    K_FLUSH: "flush", K_QUERY: "query", K_DELETE: "delete",
    K_HEALTH: "health", K_STATS: "stats", K_SNAPSHOT: "snapshot",
    K_RECOVER: "recover", K_SHUTDOWN: "shutdown",
    K_ADVANCE_CLOCK: "advance_clock",
}


class ProtocolError(RuntimeError):
    """A framing/handshake violation (torn frame, bad magic, CRC mismatch,
    oversized payload, version mismatch, desynced reply).  Never
    transient: the channel that raised it is no longer trustworthy."""


class RemoteError(RuntimeError):
    """A worker-side exception re-raised on the coordinator.  Carries the
    failover-relevant markers across the wire: ``transient`` (retry in
    place is allowed — `faults.is_transient`) and ``wal_accepted`` (the
    failed op's record hit the worker's WAL, so it must NOT be
    resubmitted — `ClusterService._mutate_live`)."""

    def __init__(self, msg: str, kind: str = "", transient: bool = False,
                 wal_accepted: bool = False):
        super().__init__(msg)
        self.remote_type = kind
        self.transient = transient
        self.wal_accepted = wal_accepted


def encode_body(meta: Optional[dict] = None,
                arrays: Optional[dict] = None) -> bytes:
    """meta (JSON-safe dict) + named numpy arrays -> body bytes."""
    mb = json.dumps(meta or {}).encode("utf-8")
    out = _JLEN.pack(len(mb)) + mb
    if arrays:
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
        out += buf.getvalue()
    return out


def decode_body(body: bytes) -> Tuple[dict, dict]:
    """Inverse of `encode_body` -> ``(meta, arrays)``."""
    if len(body) < _JLEN.size:
        raise ProtocolError(f"truncated frame body ({len(body)} bytes)")
    (jlen,) = _JLEN.unpack(body[:_JLEN.size])
    if _JLEN.size + jlen > len(body):
        raise ProtocolError(
            f"truncated frame body (meta wants {jlen} bytes, "
            f"{len(body) - _JLEN.size} present)")
    try:
        meta = json.loads(body[_JLEN.size:_JLEN.size + jlen] or b"{}")
    except ValueError as e:
        raise ProtocolError(f"frame meta is not JSON: {e}") from None
    rest = body[_JLEN.size + jlen:]
    arrays: dict = {}
    if rest:
        try:
            with np.load(io.BytesIO(rest)) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception as e:
            raise ProtocolError(f"frame arrays are not npz: {e}") from None
    return meta, arrays


def send_msg(sock: socket.socket, msg_id: int, kind: int,
             body: bytes) -> None:
    """Frame and send one message (blocking, honours the socket timeout)."""
    if len(body) > MAX_BODY:
        raise ProtocolError(
            f"frame body {len(body)} bytes exceeds MAX_BODY={MAX_BODY}")
    hdr = _HEADER.pack(_MAGIC, msg_id, kind, len(body), zlib.crc32(body))
    sock.sendall(hdr + body)


def _recv_exact(sock: socket.socket, n: int, what: str) -> bytes:
    chunks = []
    got = 0
    while got < n:
        part = sock.recv(min(n - got, 1 << 20))
        if not part:
            raise ProtocolError(
                f"truncated frame: peer closed mid-{what} "
                f"({got}/{n} bytes)")
        chunks.append(part)
        got += len(part)
    return b"".join(chunks)


def recv_msg(sock: socket.socket,
             max_body: int = MAX_BODY) -> Tuple[int, int, bytes]:
    """Receive one framed message -> ``(msg_id, kind, body)``.

    Every malformed input raises `ProtocolError` (see module docstring);
    an oversized ``body_len`` is rejected before the body is read."""
    head = _recv_exact(sock, _HEADER.size, "header")
    magic, msg_id, kind, blen, crc = _HEADER.unpack(head)
    if magic != _MAGIC:
        raise ProtocolError(f"bad magic 0x{magic:08x} (framing desync or "
                            "not a sketch-RPC peer)")
    if blen > max_body:
        raise ProtocolError(
            f"oversized frame: body_len={blen} exceeds max_body={max_body}")
    body = _recv_exact(sock, blen, "body")
    if zlib.crc32(body) != crc:
        raise ProtocolError(
            f"crc mismatch on {KIND_NAMES.get(kind, kind)} frame "
            f"(msg_id={msg_id})")
    return msg_id, kind, body


def check_hello(meta: dict) -> None:
    """Server-side HELLO validation: loud on a version mismatch."""
    got = meta.get("version")
    if got != PROTOCOL_VERSION:
        raise ProtocolError(
            f"protocol version mismatch: peer speaks {got!r}, "
            f"this worker speaks {PROTOCOL_VERSION}")


class Channel:
    """Client side of one coordinator→worker connection.

    ``call(kind, meta, arrays)`` sends one request and blocks for its
    reply (lockstep; serialized under an internal lock).  Worker-side
    failures come back as `RemoteError` with their failover markers;
    wire-level failures (timeout, reset, framing) mark the channel
    *broken* — every later call fails fast with `ProtocolError` until the
    failover layer rebuilds the worker.  A timeout in particular must
    break the channel: the reply may still arrive and would otherwise be
    paired with the next request."""

    def __init__(self, host: str, port: int, timeout_s: float = 120.0,
                 fault_scope: str = "", session: str = ""):
        self._lock = threading.Lock()
        self._timeout = float(timeout_s)
        self._scope = fault_scope
        self._msg_id = 0
        self._broken: Optional[str] = None
        self.remote = f"{host}:{port}"
        faults.fire(fault_scope + "net.connect")
        self._sock = socket.create_connection((host, port),
                                              timeout=self._timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            meta, _ = self.call(K_HELLO, {"version": PROTOCOL_VERSION,
                                          "session": session})
        except BaseException:
            self.close()
            raise
        if meta.get("version") != PROTOCOL_VERSION:
            self.close()
            raise ProtocolError(
                f"protocol version mismatch: worker speaks "
                f"{meta.get('version')!r}, coordinator speaks "
                f"{PROTOCOL_VERSION}")
        self.session = meta.get("session", "")
        self.engine_kind = meta.get("engine", "")

    @property
    def broken(self) -> Optional[str]:
        return self._broken

    def call(self, kind: int, meta: Optional[dict] = None,
             arrays: Optional[dict] = None,
             timeout_s: Optional[float] = None) -> Tuple[dict, dict]:
        """One request/reply round trip -> the reply's ``(meta, arrays)``."""
        with self._lock:
            if self._broken is not None:
                raise ProtocolError(
                    f"channel to {self.remote} is broken "
                    f"({self._broken}); rebuild the worker")
            self._msg_id += 1
            mid = self._msg_id
            # Fires before any bytes go out: a "drop" fault here models a
            # lost request — nothing was sent, the channel stays intact
            # and the caller may retry on it.
            faults.fire(self._scope + "net.send")
            try:
                if timeout_s is not None:
                    self._sock.settimeout(float(timeout_s))
                send_msg(self._sock, mid, kind, encode_body(meta, arrays))
                faults.fire(self._scope + "net.recv")
                rid, rkind, body = recv_msg(self._sock)
            except BaseException as e:
                if not faults.is_transient(e):
                    self._break(e)
                raise
            finally:
                if timeout_s is not None:
                    self._sock.settimeout(self._timeout)
            if rid != mid:
                e = ProtocolError(
                    f"desynced reply from {self.remote}: expected "
                    f"msg_id {mid}, got {rid}")
                self._break(e)
                raise e
            rmeta, rarrays = decode_body(body)
            if rkind == K_ERR:
                raise RemoteError(
                    f"worker {self.remote} failed on "
                    f"{KIND_NAMES.get(kind, kind)}: "
                    f"{rmeta.get('error', '?')}",
                    kind=rmeta.get("type", ""),
                    transient=bool(rmeta.get("transient", False)),
                    wal_accepted=bool(rmeta.get("wal_accepted", False)))
            return rmeta, rarrays

    def _break(self, exc: BaseException) -> None:
        self._broken = f"{type(exc).__name__}: {exc}"
        try:
            self._sock.close()
        except OSError:
            pass

    def close(self) -> None:
        with self._lock:
            if self._broken is None:
                self._broken = "closed"
            try:
                self._sock.close()
            except OSError:
                pass
