"""Network-native cluster (DESIGN.md §16): sketch workers as separate
processes behind a small length-prefixed binary RPC protocol over TCP —
stdlib sockets only, CRC-framed messages reusing the WAL framing idiom.

  * `protocol` — wire format, `Channel` (client side, versioned
    handshake, per-call timeouts, fail-loud framing);
  * `worker` — `WorkerServer` wrapping one engine, plus the
    spawn/run/reap process entry points;
  * `cluster` — `RemoteEngine` proxy + the three RPC coordinators, which
    subclass the in-process cluster services and stay bit-exact against
    them (tests/test_rpc_cluster.py).
"""
from __future__ import annotations

from . import cluster, protocol, worker  # noqa: F401
from .cluster import (RemoteEngine, RPCClusterKDEService,  # noqa: F401
                      RPCClusterRACEService, RPCClusterRetrievalService,
                      RPCConfig, rpc_cluster)
from .protocol import (PROTOCOL_VERSION, Channel, ProtocolError,  # noqa: F401
                       RemoteError)
from .worker import (WorkerServer, build_service, reap_process,  # noqa: F401
                     run_worker, spawn_worker)
