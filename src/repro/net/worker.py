"""Worker side of the network cluster: a `WorkerServer` wraps ONE sketch
service engine (`RetrievalService` / `KDEService` / `RACEService` —
unchanged) and speaks the `protocol` frames over a TCP socket, plus the
process entry points the coordinator spawns workers through.

Each worker process owns its engine outright: its commit worker + prepare
threads, its WAL + snapshots (under the cluster dir's ``worker_<w>``
subdirectory, exactly where the in-process cluster keeps them — so the
coordinator's WAL-tail salvage of a dead worker reads the same files),
and its own JAX runtime.  Ingest RPCs stream straight into the engine's
``ingest_async`` path, which WAL-logs at enqueue time *before* the OK
reply — an acknowledged chunk is replayable even if the process dies
immediately after (`wal.append` flushes per record).

The server is deliberately single-client/lockstep: the coordinator holds
one channel per worker and pipelines nothing, so request handling is a
simple read→dispatch→reply loop; a disconnected coordinator just drops
the connection and the server accepts the next one (a respawned
coordinator, or an operator poking at a worker).

Spawn path (`spawn_worker`): workers start via the multiprocessing
``spawn`` context — never ``fork``, which is unsafe once JAX has
initialised its runtime in the parent — as *daemon* children, so a dying
coordinator process can never leave orphan workers behind.  The child
binds an ephemeral port and hands it back over a pipe; service configs
travel as plain dicts (`dataclasses.asdict`) and are rebuilt in the
child, so the worker's engine is constructed from the exact same config
the in-process oracle would use.
"""
from __future__ import annotations

import os
import socket
import traceback
import uuid
from typing import Optional, Tuple

import numpy as np

from repro.net import protocol as P
from repro.persist import faults


def build_service(service_kind: str, cfg_dict: dict):
    """Rebuild a sketch service from its shipped config dict.  Imported
    lazily so the child process pays jax startup once, here."""
    import jax  # noqa: F401  (force backend init before the engine jits)

    if cfg_dict.get("mesh") is not None:
        raise ValueError("RPC workers are single-process engines; shard "
                         "inside the worker with num_shards, not mesh=")
    if service_kind == "retrieval":
        from repro.serve.retrieval import RetrievalConfig, RetrievalService
        return RetrievalService(RetrievalConfig(**cfg_dict))
    if service_kind == "kde":
        from repro.serve.kde_service import KDEService, KDEServiceConfig
        return KDEService(KDEServiceConfig(**cfg_dict))
    if service_kind == "race":
        from repro.serve.race_service import RACEService, RACEServiceConfig
        return RACEService(RACEServiceConfig(**cfg_dict))
    raise ValueError(f"unknown service kind {service_kind!r}")


class WorkerServer:
    """One engine behind one listening socket (see module docstring)."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0):
        self.engine = engine
        self.session = uuid.uuid4().hex[:12]
        self._stop = False
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(4)
        self.host, self.port = self._lsock.getsockname()[:2]

    def serve_forever(self) -> None:
        """Accept coordinator connections until a SHUTDOWN request (one at
        a time — the protocol is lockstep and the coordinator is the only
        intended client)."""
        try:
            while not self._stop:
                try:
                    conn, _ = self._lsock.accept()
                except OSError:
                    break
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                try:
                    self._serve_conn(conn)
                finally:
                    try:
                        conn.close()
                    except OSError:
                        pass
        finally:
            self._lsock.close()

    def _serve_conn(self, conn: socket.socket) -> None:
        while not self._stop:
            try:
                mid, kind, body = P.recv_msg(conn)
            except (P.ProtocolError, OSError):
                return          # peer gone / garbage: drop the connection
            try:
                meta, arrays = P.decode_body(body)
                rmeta, rarrays = self._handle(kind, meta, arrays)
                P.send_msg(conn, mid, P.K_OK,
                           P.encode_body(rmeta, rarrays))
            except BaseException as e:
                err = {"error": f"{e!r}", "type": type(e).__name__,
                       "transient": faults.is_transient(e),
                       "wal_accepted": bool(getattr(e, "wal_accepted",
                                                    False))}
                try:
                    P.send_msg(conn, mid, P.K_ERR, P.encode_body(err))
                except OSError:
                    return
                if self._stop:          # shutdown failed but still stops
                    return

    # --- request dispatch ---------------------------------------------------

    def _handle(self, kind: int, meta: dict,
                arrays: dict) -> Tuple[dict, dict]:
        eng = self.engine
        if kind == P.K_HELLO:
            P.check_hello(meta)
            return {"version": P.PROTOCOL_VERSION, "session": self.session,
                    "engine": type(eng).__name__}, {}
        if kind == P.K_INGEST:
            eng.ingest_async(np.asarray(arrays["xs"], np.float32))
            return {}, {}
        if kind == P.K_FLUSH:
            eng.flush()
            return {}, {}
        if kind == P.K_QUERY:
            import jax
            qkind = meta.get("kind") or eng._default_query_kind
            fn = eng._kind_fn(qkind)
            res = fn(eng._query_snapshot_ctx(),
                     np.asarray(arrays["qs"], np.float32))
            leaves = [np.asarray(x) for x in jax.tree.leaves(res)]
            return ({"num_leaves": len(leaves)},
                    {f"l{i}": a for i, a in enumerate(leaves)})
        if kind == P.K_DELETE:
            eng.delete(np.asarray(arrays["x"], np.float32))
            return {}, {}
        if kind == P.K_HEALTH:
            return self._health_meta(), {}
        if kind == P.K_STATS:
            return dict(eng.stats()), {}
        if kind == P.K_SNAPSHOT:
            import jax
            state, version = eng.snapshot()
            leaves = [np.asarray(x) for x in jax.tree.leaves(state)]
            return ({"version": int(version), "num_leaves": len(leaves)},
                    {f"l{i}": a for i, a in enumerate(leaves)})
        if kind == P.K_RECOVER:
            return {"replayed": int(eng.recover())}, {}
        if kind == P.K_ADVANCE_CLOCK:
            eng.advance_clock(int(meta["target"]))
            return {}, {}
        if kind == P.K_SHUTDOWN:
            # Close the engine *before* the OK goes out: the coordinator's
            # shutdown call returns only once the WAL handle and threads
            # are down, so `close()` on the cluster is a real barrier.
            self._stop = True
            eng.close()
            return {}, {}
        raise P.ProtocolError(f"unknown request kind {kind}")

    def _health_meta(self) -> dict:
        eng = self.engine
        out = dict(eng.health())
        out["version"] = int(eng.version)
        for extra in ("steps", "count", "stored"):
            try:
                v = getattr(eng, extra)
            except Exception:
                continue
            if isinstance(v, (int, np.integer)):
                out[extra] = int(v)
        return out


# --- process entry points ----------------------------------------------------

def run_worker(service_kind: str, cfg_dict: dict, host: str = "127.0.0.1",
               port: int = 0, announce=print) -> None:
    """Foreground worker (e.g. a second terminal via
    ``examples/serve_retrieval.py --rpc worker``): build the engine, bind,
    announce the port, serve until SHUTDOWN."""
    svc = build_service(service_kind, cfg_dict)
    srv = WorkerServer(svc, host=host, port=port)
    if announce is not None:
        announce(f"worker [{service_kind}] session {srv.session} "
                 f"listening on {srv.host}:{srv.port}")
    try:
        srv.serve_forever()
    finally:
        try:
            svc.close()
        except BaseException:
            pass


def _worker_main(conn, service_kind: str, cfg_dict: dict,
                 host: str) -> None:
    """Spawned-child main: build engine, bind an ephemeral port, hand it
    back over the pipe, serve.  Any startup failure travels back as a
    traceback instead of a silent dead child."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    try:
        svc = build_service(service_kind, cfg_dict)
        srv = WorkerServer(svc, host=host, port=0)
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc()))
        finally:
            conn.close()
        return
    conn.send(("ok", srv.port))
    conn.close()
    try:
        srv.serve_forever()
    finally:
        try:
            svc.close()
        except BaseException:
            pass


def spawn_worker(service_kind: str, cfg_dict: dict,
                 host: str = "127.0.0.1",
                 spawn_timeout_s: float = 300.0):
    """Start a worker process (spawn context, daemon) and wait for its
    port.  Returns ``(process, port)``; on failure the child is reaped
    before the error propagates (no orphan PIDs)."""
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    rx, tx = ctx.Pipe(duplex=False)
    proc = ctx.Process(target=_worker_main,
                       args=(tx, service_kind, cfg_dict, host),
                       daemon=True, name=f"sketch-worker-{service_kind}")
    proc.start()
    tx.close()
    try:
        if not rx.poll(spawn_timeout_s):
            raise TimeoutError(
                f"worker [{service_kind}] did not report a port within "
                f"{spawn_timeout_s}s")
        status, payload = rx.recv()
    except BaseException:
        reap_process(proc)
        raise
    finally:
        rx.close()
    if status != "ok":
        reap_process(proc)
        raise RuntimeError(
            f"worker [{service_kind}] failed to start:\n{payload}")
    return proc, int(payload)


def reap_process(proc, timeout_s: float = 5.0) -> None:
    """Make sure a worker process is gone: join, then terminate, then
    kill.  Safe on already-dead processes; never raises."""
    if proc is None:
        return
    try:
        proc.join(timeout_s)
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout_s)
        if proc.is_alive():
            proc.kill()
            proc.join(timeout_s)
    except BaseException:
        pass
