"""Coordinator side of the network cluster (DESIGN.md §16): the PR-5/PR-8
in-process cluster promoted to separate worker *processes* behind the RPC
front door.

`RPCClusterRetrievalService` / `RPCClusterKDEService` /
`RPCClusterRACEService` ARE the in-process coordinators — they subclass
`serve.cluster.Cluster*Service` and swap only the worker construction:
``make_worker(w)`` spawns (or connects to) a worker process and returns a
`RemoteEngine` proxy speaking the engine surface over one RPC channel.
Everything above the worker boundary is shared code, not a re-
implementation: the same splitmix64 content-hash partitioner routes
substreams (a pure function of row bytes — identical in every process),
the same merge-algebra fold combines worker snapshots, the same
LIVE/DEGRADED/DEAD failover machinery (DESIGN §14) retries transient RPC
faults in place, rebuilds a lost worker by **respawning its process** and
`recover()`-ing from its WAL, and — when respawn is impossible — declares
it DEAD and re-partitions its WAL tail to the survivors by reading the
dead worker's log straight off the shared filesystem.

Exactness (the PR-5 cluster stays the test oracle, tests/test_rpc_cluster
.py): the RPC cluster is *bit-exact* against the in-process cluster for
all three sketches because every divergence point is pinned —

  * partition: `hash_partition` hashes row bytes, not object identity;
  * per-worker configs: the identical `_worker_cfg(cfg, w, ...)` dict is
    shipped to the child and rebuilt, so worker w's engine (seed, salt,
    chunking, durability subdir) is the one the oracle builds in-process;
  * chunk schedule: the coordinator submits the same engine-chunk slices
    in the same round-robin order, and worker seq numbers (hence
    `fold_in(key, seq)` PRNG draws) are assigned in arrival order on one
    lockstep channel;
  * reads: worker snapshots travel as ``.npz`` leaves (dtype/byte exact)
    and are folded by the same jitted merge on the coordinator.

The coordinator keeps a local **template engine** (same config, no
durability, never ingested): it contributes the jitted query/merge
functions and sketch params the coordinator's read path needs (the
`_ref` hook of `ClusterService`) — params are a pure function of the
config seed, so the template's equal every worker's.

Lifecycle: workers spawn via the multiprocessing ``spawn`` context as
daemon children (a dying coordinator never leaves orphans), `close()`
SHUTDOWNs + reaps every process even when some fail, and a constructor
that fails mid-startup (e.g. worker 2 of 4 refuses connections) reaps the
already-spawned processes before re-raising — no leaked PIDs either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.net import protocol as P
from repro.net import worker as W
from repro.serve.cluster import (ClusterKDEService, ClusterRACEService,
                                 ClusterRetrievalService, FailoverConfig,
                                 _worker_cfg)
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

# `RemoteEngine._dur` sentinel: the failover layer only asks "is this
# worker durable?" (`old._dur is not None`) — the actual durability config
# lives in the worker process.
_REMOTE_DURABLE = object()


@dataclasses.dataclass(frozen=True)
class RPCConfig:
    """Network knobs for an RPC cluster.

    ``rpc_timeout_s`` bounds every request/reply round trip; a timed-out
    channel is *broken* (a late reply would desync the framing) and the
    worker goes through failover.  ``connect_retries``/``connect_backoff_s``
    retry the initial connect+handshake with exponential backoff.
    ``respawn`` — whether failover may restart a lost worker's process
    (False forces the DEAD + WAL-tail re-partition path).  ``peers`` —
    connect to externally-started workers (``run_worker`` in another
    terminal/host) instead of spawning: one ``(host, port)`` per worker.
    """
    host: str = "127.0.0.1"
    rpc_timeout_s: float = 300.0
    connect_retries: int = 3
    connect_backoff_s: float = 0.2
    spawn_timeout_s: float = 300.0
    respawn: bool = True
    peers: Optional[Sequence[Tuple[str, int]]] = None


class RemoteEngine:
    """Client-side proxy for one worker process, speaking the
    `SketchEngine` surface the cluster coordinator drives.

    Mutations (`ingest_async`, `flush`, `delete`, `advance_clock`,
    `recover`) are one RPC each; `snapshot()` pulls the worker's committed
    state as npz leaves and rebuilds the pytree against the template's
    treedef.  Worker-side failures arrive as `protocol.RemoteError`
    carrying the failover markers (``transient``, ``wal_accepted``), so
    `ClusterService._with_retries` / `_mutate_live` work unchanged.
    Channel-level failures mark the channel broken; the proxy then reads
    as poisoned and the coordinator's failover rebuilds it (respawn) or
    declares it dead (salvage)."""

    def __init__(self, channel: P.Channel, template, proc=None,
                 durable: bool = False):
        self._ch = channel
        self._tpl = template
        self.proc = proc
        self._chunk = template._chunk
        self._query_block = template._query_block
        self._dur = _REMOTE_DURABLE if durable else None
        self._closed = False
        self._last_health: Optional[dict] = None

    # --- engine surface -----------------------------------------------------

    def ingest_async(self, chunk) -> None:
        self._ch.call(P.K_INGEST,
                      arrays={"xs": np.asarray(chunk, np.float32)})

    def flush(self) -> None:
        self._ch.call(P.K_FLUSH)

    def delete(self, x) -> None:
        self._ch.call(P.K_DELETE, arrays={"x": np.asarray(x, np.float32)})

    def advance_clock(self, target: int) -> None:
        self._ch.call(P.K_ADVANCE_CLOCK, {"target": int(target)})

    def recover(self) -> int:
        meta, _ = self._ch.call(P.K_RECOVER)
        return int(meta["replayed"])

    def snapshot(self):
        meta, arrays = self._ch.call(P.K_SNAPSHOT)
        n = int(meta["num_leaves"])
        leaves = [jnp.asarray(arrays[f"l{i}"]) for i in range(n)]
        state = jax.tree.unflatten(jax.tree.structure(self._tpl.state),
                                   leaves)
        return state, int(meta["version"])

    def query(self, queries, kind: Optional[str] = None):
        """Direct worker-local query (not the merged cluster view) — the
        per-worker substream answer, mainly for tooling/tests."""
        meta, arrays = self._ch.call(
            P.K_QUERY, {"kind": kind},
            arrays={"qs": np.asarray(queries, np.float32)})
        return [arrays[f"l{i}"] for i in range(int(meta["num_leaves"]))]

    def _health_rpc(self) -> dict:
        meta, _ = self._ch.call(P.K_HEALTH)
        self._last_health = meta
        return meta

    def health(self) -> dict:
        """Worker health; a worker behind a broken channel reports itself
        poisoned (like an in-process poisoned engine still does) instead
        of raising — the coordinator's `health()` polls dead workers
        too."""
        if self._ch.broken is not None:
            return {"state": "poisoned",
                    "poison_reason": f"rpc channel broken: "
                                     f"{self._ch.broken}"}
        try:
            return self._health_rpc()
        except (P.ProtocolError, OSError) as e:
            return {"state": "poisoned",
                    "poison_reason": f"rpc health poll failed: {e!r}"}

    def stats(self) -> dict:
        meta, _ = self._ch.call(P.K_STATS)
        return meta

    def close(self) -> None:
        """Graceful SHUTDOWN + channel close + process reap.  Idempotent;
        the process is reaped even when the shutdown RPC fails, and a
        remote close failure re-raises afterwards (the cluster's close
        aggregates it)."""
        if self._closed:
            return
        self._closed = True
        err: Optional[BaseException] = None
        try:
            if self._ch.broken is None:
                self._ch.call(P.K_SHUTDOWN)
        except BaseException as e:
            err = e
        finally:
            self._ch.close()
            W.reap_process(self.proc)
        if err is not None and not isinstance(err, (P.ProtocolError,
                                                   OSError)):
            raise err

    # --- polled properties --------------------------------------------------

    @property
    def version(self) -> int:
        # Fail-stop read: a broken channel raises here (unlike health()).
        return int(self._health_rpc()["version"])

    @property
    def steps(self) -> int:
        return int(self._health_rpc().get("steps", 0))

    @property
    def count(self) -> int:
        return int(self._health_rpc().get("count", 0))

    @property
    def stored(self) -> int:
        return int(self._health_rpc().get("stored", 0))

    @property
    def sketch_bytes(self) -> int:
        return self._tpl.sketch_bytes      # same allocation, same config

    @property
    def _poisoned(self) -> bool:
        return self.health().get("state") == "poisoned"

    @property
    def _poison_reason(self) -> Optional[str]:
        if self._ch.broken is not None:
            return f"rpc channel broken: {self._ch.broken}"
        return (self._last_health or {}).get("poison_reason")


class _RPCClusterMixin:
    """Worker-construction override shared by the three RPC coordinators:
    spawn/connect with retry+backoff, template bookkeeping, startup and
    shutdown process reaping.  Subclasses set ``_service_kind`` and
    ``_worker_cfg_extra``."""

    _service_kind = ""

    def _rpc_setup(self, cfg, template, rpc: Optional[RPCConfig]) -> None:
        self._rpc = rpc or RPCConfig()
        if (self._rpc.peers is not None
                and getattr(cfg, "snapshot_dir", None) is None
                and not self._rpc.respawn):
            pass                           # nothing to validate further
        self._template = template
        self._base_cfg = cfg
        self._rpc_durable = getattr(cfg, "snapshot_dir", None) is not None
        self._procs: dict = {}
        self._remotes: dict = {}
        self._spawned_once: set = set()

    @property
    def _ref(self):
        return self._template

    def _worker_cfg_extra(self, w: int) -> dict:
        return dict(batch_queries=False)

    def _remote_worker(self, w: int) -> RemoteEngine:
        """``make_worker`` for the RPC cluster: start (or dial) worker
        ``w`` and return its proxy.  On a failover *rebuild* (the worker
        was built once already) this respawns the process — the old
        proxy's `close()` reaped the old one — unless ``respawn`` is off
        or the worker is an external peer, in which case the rebuild
        fails and the failover layer falls through to DEAD + salvage."""
        rc = self._rpc
        first = w not in self._spawned_once
        if not first and rc.peers is not None:
            raise RuntimeError(
                f"worker {w} is an external peer; the coordinator cannot "
                "respawn it")
        if not first and not rc.respawn:
            raise RuntimeError(
                f"worker {w} lost and respawn is disabled "
                "(RPCConfig.respawn=False)")
        self._spawned_once.add(w)
        wcfg = dataclasses.asdict(
            _worker_cfg(self._base_cfg, w, **self._worker_cfg_extra(w)))
        proc = None
        if rc.peers is not None:
            host, port = rc.peers[w]
        else:
            host = rc.host
            proc, port = W.spawn_worker(
                self._service_kind, wcfg, host=host,
                spawn_timeout_s=rc.spawn_timeout_s)
        try:
            ch = self._connect(host, port, scope=f"worker_{w}/")
        except BaseException:
            W.reap_process(proc)
            self._procs.pop(w, None)
            raise
        self._procs[w] = proc
        eng = RemoteEngine(ch, self._template, proc=proc,
                           durable=self._rpc_durable)
        self._remotes[w] = eng
        return eng

    def _connect(self, host: str, port: int, scope: str) -> P.Channel:
        rc = self._rpc
        delay = rc.connect_backoff_s
        for attempt in range(rc.connect_retries + 1):
            try:
                return P.Channel(host, port, timeout_s=rc.rpc_timeout_s,
                                 fault_scope=scope)
            except (OSError, P.ProtocolError):
                if attempt == rc.connect_retries:
                    raise
                time.sleep(delay)
                delay *= 2

    def _reap_all(self) -> None:
        """Kill/collect every worker process and channel this coordinator
        ever created — the mid-startup failure path (satellite: no orphan
        PIDs when a connect fails after some workers spawned) and the
        close() backstop."""
        for eng in list(self._remotes.values()):
            try:
                eng._ch.close()
            except BaseException:
                pass
        for w, proc in list(self._procs.items()):
            W.reap_process(proc)
        self._procs.clear()

    def close(self) -> None:
        try:
            super().close()
        finally:
            self._reap_all()
            try:
                self._template.close()
            except BaseException:
                pass


class RPCClusterRetrievalService(_RPCClusterMixin, ClusterRetrievalService):
    """N-process S-ANN cluster behind the RPC front door (bit-exact vs
    `ClusterRetrievalService`)."""

    _service_kind = "retrieval"

    def __init__(self, cfg: RetrievalConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 rpc: Optional[RPCConfig] = None):
        self._rpc_setup(cfg, RetrievalService(dataclasses.replace(
            cfg, snapshot_dir=None, batch_queries=False)), rpc)
        try:
            super().__init__(cfg, num_workers, merge_every=merge_every,
                             failover=failover,
                             make_worker=self._remote_worker)
        except BaseException:
            self._reap_all()
            raise

    def _worker_cfg_extra(self, w: int) -> dict:
        return dict(ingest_salt=w, batch_queries=False)


class RPCClusterKDEService(_RPCClusterMixin, ClusterKDEService):
    """N-process SW-AKDE cluster behind the RPC front door (bit-exact vs
    `ClusterKDEService`, including the ``global_clock`` stream-time
    option — clock advances are one RPC per worker per ingest call)."""

    _service_kind = "kde"

    def __init__(self, cfg: KDEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 global_clock: bool = False,
                 rpc: Optional[RPCConfig] = None):
        self._rpc_setup(cfg, KDEService(dataclasses.replace(
            cfg, snapshot_dir=None, batch_queries=False)), rpc)
        try:
            super().__init__(cfg, num_workers, merge_every=merge_every,
                             failover=failover, global_clock=global_clock,
                             make_worker=self._remote_worker)
        except BaseException:
            self._reap_all()
            raise


class RPCClusterRACEService(_RPCClusterMixin, ClusterRACEService):
    """N-process RACE cluster behind the RPC front door (bit-exact vs
    `ClusterRACEService` — and therefore vs a single engine over the
    whole stream)."""

    _service_kind = "race"

    def __init__(self, cfg: RACEServiceConfig, num_workers: int = 2,
                 merge_every: int = 8,
                 failover: Optional[FailoverConfig] = None,
                 rpc: Optional[RPCConfig] = None):
        self._rpc_setup(cfg, RACEService(dataclasses.replace(
            cfg, snapshot_dir=None, batch_queries=False)), rpc)
        try:
            super().__init__(cfg, num_workers, merge_every=merge_every,
                             failover=failover,
                             make_worker=self._remote_worker)
        except BaseException:
            self._reap_all()
            raise


_SERVICES: dict[str, Callable] = {
    "retrieval": RPCClusterRetrievalService,
    "kde": RPCClusterKDEService,
    "race": RPCClusterRACEService,
}


def rpc_cluster(service_kind: str, cfg, **kwargs):
    """Factory by sketch name: ``rpc_cluster("race", cfg, num_workers=4)``."""
    try:
        cls = _SERVICES[service_kind]
    except KeyError:
        raise ValueError(f"unknown service kind {service_kind!r}; expected "
                         f"one of {sorted(_SERVICES)}") from None
    return cls(cfg, **kwargs)
