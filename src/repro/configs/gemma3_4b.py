"""gemma3-4b — 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (kv=4) d_ff=10240 vocab=262144; window 1024 locals,
qk-norm, sandwich norms, no softcap (gemma3 replaced it with qk-norm).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    qk_norm=True, local_window=1024, local_global_period=6,
    rope_theta=1_000_000.0,
    sandwich_norm=True, scale_embeddings=True, mlp_act="gelu",
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, local_window=32, local_global_period=3)
