"""whisper-large-v3 — audio enc-dec backbone, conv frontend stubbed
[arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers, d_model=1280 20H (kv=20 = MHA) d_ff=5120
vocab=51866; the mel/conv frontend is a stub: input_specs() provides
precomputed frame embeddings (B, 1500, d_model).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    rope_theta=0.0,  # sinusoidal absolute positions
    enc_frames=1500, mlp_act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, enc_frames=32)
