"""gemma2-27b — local+global alternating, logit softcap [arXiv:2408.00118; hf].

46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000; 1:1 local:global with
4096-token sliding window; attn softcap 50, final softcap 30; sandwich norms.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab=256000, head_dim=128,
    attn_softcap=50.0, final_softcap=30.0,
    local_window=4096, local_global_period=2,
    sandwich_norm=True, scale_embeddings=True, mlp_act="gelu",
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, local_window=32)
