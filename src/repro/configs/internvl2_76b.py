"""internvl2-76b — InternViT (stub) + InternLM2-style 70B-class backbone
[arXiv:2404.16821; unverified].

80L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256; the vision frontend is
a stub: input_specs() provides 256 precomputed patch embeddings per example,
projected and prepended to the token sequence.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    n_patches=256,
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, n_patches=8)
