"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64; a single
shared transformer block is applied every 6 mamba layers (weights shared).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
    attn_every=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=8, ssm_head_dim=16, ssm_chunk=16,
        attn_every=2)
