"""granite-8b — llama-arch dense GQA, code model [arXiv:2405.04324; hf].

36L d_model=4096 32H (kv=8) d_ff=14336 vocab=49152.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, head_dim=128,
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256)
