"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16) expert_d_ff=1408 vocab=102400; first layer is
dense (d_ff=10944).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab=102400, head_dim=128,
    n_experts=64, n_shared_experts=2, moe_topk=6, d_ff_expert=1408,
    n_dense_layers=1,
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_experts=8, n_shared_experts=1, moe_topk=2,
        d_ff_expert=32, n_dense_layers=1)
