"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

61L d_model=7168 128H expert_d_ff=2048 vocab=129280; first 3 layers dense
(d_ff=18432); MLA latent d_c=512, q latent 1536, decoupled rope dim 64;
depth-1 multi-token prediction head.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, head_dim=128,
    n_experts=256, n_shared_experts=1, moe_topk=8, d_ff_expert=2048,
    n_dense_layers=3,
    use_mla=True, mla_d_c=512, mla_d_cq=1536, mla_rope_dim=64,
    mtp_depth=1,
    seq_parallel=True,
    grad_microbatches=8, grad_accum_dtype="bfloat16", fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_experts=8, n_shared_experts=1, moe_topk=2,
        d_ff_expert=32, n_dense_layers=1,
        mla_d_c=32, mla_d_cq=48, mla_rope_dim=8)
