"""qwen3-4b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B; hf].

36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    seq_parallel=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256)
