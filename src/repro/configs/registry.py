"""Architecture registry: --arch <id> → ModelConfig."""
from __future__ import annotations

from . import (deepseek_moe_16b, deepseek_v3_671b, gemma2_27b, gemma3_4b,
               granite_8b, internvl2_76b, qwen3_4b, whisper_large_v3,
               xlstm_125m, zamba2_2p7b)
from .base import ALL_SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "zamba2-2.7b": zamba2_2p7b,
    "qwen3-4b": qwen3_4b,
    "granite-8b": granite_8b,
    "gemma2-27b": gemma2_27b,
    "gemma3-4b": gemma3_4b,
    "whisper-large-v3": whisper_large_v3,
    "deepseek-moe-16b": deepseek_moe_16b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "xlstm-125m": xlstm_125m,
    "internvl2-76b": internvl2_76b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """DESIGN.md §3 applicability: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full attention — no sub-quadratic mechanism (DESIGN.md §3)"
    return True, ""
