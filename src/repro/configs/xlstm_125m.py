"""xlstm-125m — alternating sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H vocab=50304; d_ff=0 in the assignment (blocks carry their
own projections; the sLSTM block has a 4/3-factor post-FFN).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        vocab=256)
