"""Model configuration schema for all assigned architectures.

One flat dataclass covers the whole zoo; family-specific fields default off.
Every `src/repro/configs/<arch>.py` exports ``CONFIG`` (the exact published
shape) and ``smoke()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # attention variants
    qk_norm: bool = False
    attn_softcap: float = 0.0            # gemma2 logit softcapping
    final_softcap: float = 0.0
    local_window: int = 0                # sliding-window size for local layers
    local_global_period: int = 0         # every Nth layer is global (0 = all global)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    sandwich_norm: bool = False          # gemma pre+post block norms
    scale_embeddings: bool = False       # gemma sqrt(d_model) embedding scale
    mlp_act: str = "silu"                # "silu" (gated) | "gelu"

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0              # leading dense layers before MoE
    moe_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # MLA (deepseek-v3)
    use_mla: bool = False
    mla_d_c: int = 0                     # kv latent dim
    mla_d_cq: int = 0                    # q latent dim (0 = no q compression)
    mla_rope_dim: int = 0

    # MTP (deepseek-v3)
    mtp_depth: int = 0
    mtp_coef: float = 0.1

    # SSM / hybrid (zamba2-style mamba2 backbone)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0                  # hybrid: shared attn block period

    # xLSTM
    xlstm_pattern: Tuple[str, ...] = ()  # e.g. ("m", "s") repeated

    # encoder-decoder (whisper)
    n_enc_layers: int = 0                # >0 → enc-dec; n_layers = decoder layers
    enc_frames: int = 1500               # stub audio frontend sequence length

    # VLM (internvl2)
    n_patches: int = 0                   # stub vision frontend patch count

    # numerics
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # distribution hints
    remat: bool = True
    scan_layers: bool = True
    seq_parallel: bool = False   # Megatron-SP residual stream (d_model>=4096)
    grad_microbatches: int = 1   # gradient accumulation (peak-memory / overlap)
    grad_accum_dtype: str = "float32"   # bf16 halves the accumulator for 100B+
    fsdp_over_pod: bool = False  # ZeRO params across pods too (100B+ models)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step; everything here decodes."""
        return True

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic structure → runs the long_500k shape (DESIGN.md §3)."""
        return self.family in ("ssm", "hybrid") or self.local_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, V = self.d_model, self.vocab
        dh = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        n = emb
        if self.family == "ssm":       # xlstm
            d_in = self.ssm_expand * d
            per = 2 * d * d_in + d_in * d + 4 * d_in  # proj + gates (approx)
            return n + self.n_layers * per
        att = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) \
            + (self.n_heads * dh) * d
        if self.use_mla:
            att = d * self.mla_d_c + self.mla_d_c * self.n_heads * dh * 2 \
                + d * (self.mla_d_cq or d) // 1 + self.n_heads * dh * d
        mlp_dense = 3 * d * self.d_ff
        if self.n_experts:
            moe = self.n_experts * 3 * d * self.d_ff_expert \
                + self.n_shared_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            n_moe_layers = self.n_layers - self.n_dense_layers
            n += self.n_dense_layers * (att + mlp_dense) \
                + n_moe_layers * (att + moe)
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) \
                + d_in * d
            n_attn = max(1, self.n_layers // max(self.attn_every, 1)) if self.attn_every else 0
            n += self.n_layers * (mamba + 2 * d * d) + (att + mlp_dense)  # shared blk once
        else:
            n += self.n_layers * (att + mlp_dense)
        if self.is_encdec:
            n += self.n_enc_layers * (att + mlp_dense) + self.n_layers * att  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        all_experts = (self.n_layers - self.n_dense_layers) * self.n_experts \
            * 3 * self.d_model * self.d_ff_expert
        active_experts = (self.n_layers - self.n_dense_layers) * self.moe_topk \
            * 3 * self.d_model * self.d_ff_expert
        return full - all_experts + active_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
