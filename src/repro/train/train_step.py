"""The jitted training step: CE loss (+ MoE aux + MTP), grad, optimizer.

``make_train_step(cfg, optimizer, mesh)`` returns (train_step, init_state):
both pure functions suitable for jax.jit with explicit in/out shardings
(see launch/dryrun.py and train/trainer.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.optim.adam import Optimizer
from repro.parallel.sharding import ShardingCtx, make_ctx


class TrainState(NamedTuple):
    params: dict
    opt_state: object
    step: jax.Array


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token CE in fp32.  logits (B, T, V); targets (B, T) already
    aligned (target[t] is the label for position t)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def chunked_xent(hidden: jax.Array, emb_params: dict, targets: jax.Array,
                 softcap: float, ctx: ShardingCtx, chunk: int = 512) -> jax.Array:
    """CE computed per sequence-chunk with rematerialisation: the (B, S, V)
    fp32 logits / log-softmax tensors never exist at full size (they were
    ~12 GB/device of the dsv3 train peak; see EXPERIMENTS.md §Perf)."""
    from repro.models import layers

    B, S, d = hidden.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    hc = jnp.moveaxis(hidden.reshape(B, nc, c, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, c), 1, 0)

    def body(acc, inp):
        h, t = inp
        logits = layers.unembed(emb_params, h, ctx, softcap)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, t[..., None], axis=-1)[..., 0]
        return acc + nll.sum(), None

    total, _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), jnp.zeros((), jnp.float32),
        (hc, tc))
    return total / (B * S)


def loss_fn(params, cfg: ModelConfig, batch: dict, ctx: ShardingCtx):
    hidden, aux = model_lib.forward(params, cfg, batch, ctx,
                                    return_hidden=True)
    tokens = batch["tokens"]
    emb = params["embedding"]
    loss = chunked_xent(hidden[:, :-1], emb, tokens[:, 1:],
                        cfg.final_softcap, ctx)
    metrics = {"ce": loss}
    total = loss
    if aux.get("moe_aux") is not None:
        total = total + aux["moe_aux"]
        metrics["moe_aux"] = aux["moe_aux"]
    if "mtp_hidden" in aux and cfg.mtp_depth:
        # depth-1 MTP predicts token t+2 from position t
        mtp = chunked_xent(aux["mtp_hidden"][:, :-2], emb, tokens[:, 2:],
                           cfg.final_softcap, ctx)
        total = total + cfg.mtp_coef * mtp
        metrics["mtp_ce"] = mtp
    metrics["loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh=None):
    ctx = make_ctx(mesh)
    n_micro = max(1, cfg.grad_microbatches)

    def init_state(key) -> TrainState:
        params = model_lib.init_model(cfg, key)
        return TrainState(params=params, opt_state=optimizer.init(params),
                          step=jnp.zeros((), jnp.int32))

    def grads_and_metrics(params, batch):
        if n_micro == 1:
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, ctx), has_aux=True)(params)
            return grads, metrics

        # gradient accumulation: scan over microbatches — XLA's peak holds a
        # single microbatch's activation working set + the grad accumulator
        # (this is also where the accumulated-grad reduce can overlap the
        # next microbatch's compute on real hardware)
        def split(x):
            return jnp.moveaxis(
                x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), 0, 0)

        mbs = jax.tree.map(split, batch)

        acc_dt = jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16" \
            else jnp.float32

        def micro(carry, mb):
            g_acc, m_acc = carry
            (_, m), g = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, mb, ctx), has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(acc_dt), g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        m0 = jax.tree.map(
            lambda l: jnp.zeros(l.shape, l.dtype),
            jax.eval_shape(lambda p: loss_fn(p, cfg, jax.tree.map(
                lambda x: x[0], mbs), ctx)[1], params))
        (g, m), _ = jax.lax.scan(micro, (g0, m0), mbs)
        g = jax.tree.map(lambda x: (x.astype(jnp.float32) / n_micro), g)
        m = jax.tree.map(lambda x: x / n_micro, m)
        return g, m

    def train_step(state: TrainState, batch: dict):
        grads, metrics = grads_and_metrics(state.params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        metrics["grad_norm"] = gnorm
        return TrainState(params=new_params, opt_state=new_opt,
                          step=state.step + 1), metrics

    return train_step, init_state
