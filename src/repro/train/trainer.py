"""Resumable trainer: checkpoint/restart, straggler monitor, drift hooks.

Fault-tolerance contract (tested in tests/test_trainer.py):
  * checkpoints every ``ckpt_every`` steps via the async writer;
  * ``Trainer.run`` resumes bit-exact from the latest checkpoint (params,
    optimizer state, step counter AND data-stream position);
  * a simulated failure (killing the loop mid-run) followed by a fresh
    Trainer converges to the same state as an uninterrupted run;
  * per-step wall times feed a robust straggler detector (median absolute
    deviation) — on a real cluster this triggers hot-spare swap-in; here it
    surfaces in metrics and is unit-tested on synthetic timings.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DriftMonitor, TokenStream
from repro.optim.adam import OPTIMIZERS
from repro.train.train_step import make_train_step


class StragglerMonitor:
    """Flags steps (hosts, on a real cluster) whose duration exceeds
    median + k * MAD — robust to the heavy-tailed step-time distribution."""

    def __init__(self, k: float = 6.0, min_history: int = 16):
        self.k = k
        self.min_history = min_history
        self.times: list[float] = []

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        h = np.asarray(self.times[-256:])
        if len(h) < self.min_history:
            return False
        med = np.median(h)
        mad = np.median(np.abs(h - med)) + 1e-9
        return bool(dt > med + self.k * mad)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    optimizer: str = "adamw"
    lr: float = 3e-4
    log_every: int = 10
    monitor_drift: bool = True


class Trainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 cfg: TrainerConfig, mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.log = log_fn
        opt = OPTIMIZERS[cfg.optimizer](lr=cfg.lr)
        self._step_fn, self._init_fn = make_train_step(model_cfg, opt, mesh)
        self._step_jit = jax.jit(self._step_fn, donate_argnums=(0,))
        self.ckpt = ckpt_lib.AsyncCheckpointer()
        self.straggler = StragglerMonitor()
        self.monitor = DriftMonitor(data_cfg) if cfg.monitor_drift else None
        self.history: list[dict] = []

    def _ckpt_path(self, step: int) -> pathlib.Path:
        return pathlib.Path(self.cfg.ckpt_dir) / f"step_{step}"

    def run(self, key=None) -> dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        stream = TokenStream(self.data_cfg)
        state = self._init_fn(key)
        start = 0
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is not None:
            payload = {"state": state, "stream": stream.state()}
            payload, step = ckpt_lib.restore(self._ckpt_path(latest), payload)
            state, start = payload["state"], step
            stream.restore(payload["stream"])
            self.log(f"[trainer] resumed from step {start}")

        metrics = {}
        for step in range(start, self.cfg.steps):
            batch = {"tokens": jax.numpy.asarray(stream.next_batch())}
            drift = self.monitor.observe(np.asarray(batch["tokens"])) \
                if self.monitor else None
            t0 = time.time()
            state, metrics = self._step_jit(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            slow = self.straggler.observe(dt)
            rec = {"step": step + 1, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "dt": dt, "straggler": slow}
            if drift:
                rec["drift"] = drift
            self.history.append(rec)
            if (step + 1) % self.cfg.log_every == 0:
                self.log(f"[trainer] step {step+1} loss {rec['loss']:.4f} "
                         f"({dt*1e3:.0f} ms{' STRAGGLER' if slow else ''})")
            if (step + 1) % self.cfg.ckpt_every == 0 \
                    or step + 1 == self.cfg.steps:
                self.ckpt.save(self._ckpt_path(step + 1),
                               {"state": state, "stream": stream.state()},
                               step + 1)
        self.ckpt.wait()
        return {"state": state, "metrics": metrics, "history": self.history}
