"""Durability subsystem: snapshots + write-ahead log for the streaming
engines (DESIGN.md §11).

Two cooperating pieces, wired into `repro.serve.engine.SketchEngine`:

  * `snapshot` — full sketch-state checkpoints over the atomic/async
    `repro.checkpoint` layer, labelled by the engine's operation sequence
    number;
  * `wal` — a chunk-granular write-ahead log appended at ``ingest_async``
    enqueue time, so the stream tail past the newest snapshot is always
    replayable through the engine's own prepare/commit path.

``recover()`` (on the engine) = load latest snapshot + replay the WAL
tail; the result is bit-identical to the uninterrupted run
(tests/test_persist.py).
"""
from __future__ import annotations

import dataclasses

from . import faults, snapshot, wal  # noqa: F401
from .faults import (FaultError, FaultPlan, FaultSpec,  # noqa: F401
                     InjectedDisconnect, InjectedIOError)
from .wal import (KIND_CHUNK, KIND_CLOCK, KIND_DELETE,  # noqa: F401
                  KIND_TENANT_CHUNK, WALRecord, WriteAheadLog)


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Durability knobs for a `SketchEngine`.

    ``dir`` — root directory (snapshots in ``step_<seq>/``, WAL segments in
    ``wal/``).  ``snapshot_every`` — background snapshot cadence in
    committed operations (chunks + logged mutations); smaller = shorter
    recovery replay, more checkpoint I/O.  ``fsync`` — fsync every WAL
    append (power-loss durability) instead of flush-only (process-death
    durability; also applied to snapshots, which license WAL compaction).
    ``keep_snapshots`` — completed snapshots retained after compaction
    (min 1: the newest snapshot is what recovery starts from once its WAL
    records are compacted away).  ``fault_scope`` — prefix for this
    engine's fault-injection site names (`repro.persist.faults`); the
    cluster coordinator sets ``worker_<w>/`` so a `FaultPlan` can target
    one worker deterministically."""
    dir: str
    snapshot_every: int = 64
    fsync: bool = False
    keep_snapshots: int = 2
    fault_scope: str = ""

    def __post_init__(self):
        if self.snapshot_every < 1:
            raise ValueError(f"snapshot_every={self.snapshot_every} (< 1)")
        if self.keep_snapshots < 1:
            raise ValueError(
                f"keep_snapshots={self.keep_snapshots}: the newest snapshot "
                "must survive pruning — its covered WAL records are already "
                "compacted away")
