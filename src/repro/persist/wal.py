"""Chunk-granular write-ahead log for the streaming engines (DESIGN.md §11).

The WAL is the durability half of the engine's two-phase ingest contract:
`repro.serve.engine.SketchEngine` appends one record per ingest chunk *at
enqueue time* (before the chunk becomes visible to the commit worker), so
after a crash the uncommitted tail of the stream is replayable through the
exact same prepare/commit path — recovery is bit-identical to the
uninterrupted run because it *is* the same computation.

Format — append-only segment files ``wal_<index>.log`` under one directory,
each a sequence of CRC-framed records:

    record := header | body
    header := magic u32 | seq u64 | kind u8 | body_len u32 | crc32(body) u32
    body   := an ``.npz`` archive of the record's named numpy arrays

Record ``seq`` numbers are the engine's global operation sequence (chunks
and mutations share one counter) and are strictly increasing across the
whole log.  Replay is tolerant of a *torn tail*: a short or CRC-corrupt
record ends the replay (everything before it is intact), and
`truncate_torn_tail` drops the garbage so post-recovery appends extend the
good prefix.  ``fsync=False`` (the default) flushes to the OS on every
append — surviving process death; ``fsync=True`` additionally survives
host power loss at a per-append fsync cost.

Segments exist for compaction: `rotate()` seals the active segment (the
engine rotates at every snapshot) and `compact(upto)` deletes sealed
segments whose records are all covered by a durable snapshot.
"""
from __future__ import annotations

import io
import os
import pathlib
import struct
import threading
import zlib
from typing import Iterable, Iterator, NamedTuple, Optional

import numpy as np

from repro.checkpoint.checkpoint import fsync_path
from repro.persist import faults

_MAGIC = 0x53574C31  # "SWL1"
_HEADER = struct.Struct("<IQBII")

# Record kinds.  The engine owns CHUNK; services register their own
# mutation kinds (e.g. RetrievalService's delete-by-value).
KIND_CHUNK = 1
KIND_DELETE = 2
# Tenant-tagged mixed chunk (serve.tenant_fleet.TenantFleet): body carries
# the chunk plus its per-point tenant ids — same framing, one extra array.
KIND_TENANT_CHUNK = 3
# Coordinator-assigned logical clock advance (serve.kde_service /
# serve.cluster global-clock option): body carries the target clock.
KIND_CLOCK = 4


class WALRecord(NamedTuple):
    seq: int
    kind: int
    arrays: dict  # name -> np.ndarray


def _encode_body(arrays: dict) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
    return buf.getvalue()


def _decode_body(body: bytes) -> dict:
    with np.load(io.BytesIO(body)) as z:
        return {k: z[k] for k in z.files}


class WriteAheadLog:
    """Segmented append-only record log (see module docstring).

    Thread-safe: one internal lock serializes appends / rotation /
    compaction (the engine already orders appends under its submit lock;
    the WAL lock makes maintenance callable from the commit worker too).
    """

    def __init__(self, root: str | os.PathLike, fsync: bool = False,
                 fault_scope: str = ""):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._fsync = bool(fsync)
        self._fault_scope = fault_scope
        self._lock = threading.Lock()
        self._segments = sorted(self.root.glob("wal_*.log"))
        # max record seq per segment — learned from appends and/or replay;
        # compaction only deletes segments whose max is known and covered.
        self._seg_max: dict[pathlib.Path, int] = {}
        self._torn: Optional[tuple[pathlib.Path, int]] = None
        self._fh = None
        # fsync mode: the active segment's *dirent* must also be durable
        # before its first record is acknowledged (POSIX: a new file needs
        # its parent directory fsynced); done once per segment.
        self._dir_synced = False

    # --- write path --------------------------------------------------------

    def _next_index(self) -> int:
        if not self._segments:
            return 0
        return int(self._segments[-1].stem.split("_")[1]) + 1

    def _open_active(self):
        if self._fh is None:
            if not self._segments:
                self._segments.append(self.root / "wal_000000.log")
            self._fh = open(self._segments[-1], "ab")

    def append(self, records: Iterable[tuple[int, int, dict]]) -> None:
        """Durably append ``(seq, kind, arrays)`` records, in order.
        Returns only after the bytes are flushed (+fsynced if configured) —
        the engine calls this *before* publishing a chunk to its queue."""
        with self._lock:
            self._open_active()
            active = self._segments[-1]
            recs = list(records)

            def _tear():
                # Death mid-write: a valid header followed by a truncated
                # body — replay must stop here and `truncate_torn_tail`
                # must drop exactly these bytes.
                if recs:
                    seq, kind, arrays = recs[0]
                    body = _encode_body(arrays)
                    self._fh.write(_HEADER.pack(_MAGIC, seq, kind,
                                                len(body), zlib.crc32(body)))
                    self._fh.write(body[: max(len(body) // 2, 1)])
                    self._fh.flush()

            # Fires before any full record lands: an injected crash here
            # models process death just before the record is durable.
            faults.fire(self._fault_scope + "wal.append", tear=_tear)
            for seq, kind, arrays in recs:
                body = _encode_body(arrays)
                self._fh.write(_HEADER.pack(_MAGIC, seq, kind, len(body),
                                            zlib.crc32(body)))
                self._fh.write(body)
                self._seg_max[active] = int(seq)
            self._fh.flush()
            if self._fsync:
                os.fsync(self._fh.fileno())
                if not self._dir_synced:
                    fsync_path(self.root)
                    self._dir_synced = True

    def rotate(self) -> None:
        """Seal the active segment; the next append opens a fresh one.  The
        engine rotates at every snapshot so `compact` can delete whole
        segments once a later snapshot covers them."""
        faults.fire(self._fault_scope + "wal.rotate")
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            if self._segments and self._segments[-1].exists() \
                    and self._segments[-1].stat().st_size == 0:
                return  # active segment never written — reuse it
            self._segments.append(
                self.root / f"wal_{self._next_index():06d}.log")
            self._dir_synced = False

    def compact(self, upto: int) -> int:
        """Delete sealed segments whose every record has seq <= ``upto``
        (i.e. is covered by a durable snapshot).  The active segment is
        never deleted.  Returns the number of segments removed."""
        faults.fire(self._fault_scope + "wal.compact")
        removed = 0
        with self._lock:
            for p in list(self._segments[:-1]):
                mx = self._seg_max.get(p)
                if mx is not None and mx <= upto:
                    p.unlink(missing_ok=True)
                    self._segments.remove(p)
                    self._seg_max.pop(p, None)
                    removed += 1
        return removed

    # --- read path ---------------------------------------------------------

    def has_records(self) -> bool:
        return any(p.exists() and p.stat().st_size > 0 for p in self._segments)

    def iter_replay(self, after: int = -1) -> Iterator[WALRecord]:
        """Stream every intact record with ``seq > after``, in seq order,
        decoding one record at a time — replaying a long tail holds one
        record in host memory, not the whole log (`replay` keeps the
        list-returning form for small logs and tests).

        Stops at the first torn/corrupt record (remembered for
        `truncate_torn_tail`); segments behind a torn one are unreachable
        by construction (seqs are append-ordered across segments).

        The WAL lock is held until the generator is exhausted or closed;
        do not call other WAL methods mid-iteration (the engine's
        `recover()` drains it in one pass, then truncates any torn tail).
        """
        with self._lock:
            self._torn = None
            for p in self._segments:
                if not p.exists():
                    continue
                good = 0          # offset just past the last intact record
                torn = False
                with open(p, "rb") as f:
                    while True:
                        head = f.read(_HEADER.size)
                        if not head:
                            break                       # clean segment end
                        if len(head) < _HEADER.size:
                            torn = True
                            break
                        magic, seq, kind, blen, crc = _HEADER.unpack(head)
                        if magic != _MAGIC:
                            torn = True
                            break
                        body = f.read(blen)
                        if len(body) < blen or zlib.crc32(body) != crc:
                            torn = True
                            break
                        good += _HEADER.size + blen
                        self._seg_max[p] = int(seq)
                        if seq > after:
                            yield WALRecord(int(seq), int(kind),
                                            _decode_body(body))
                if torn:
                    self._torn = (p, good)
                    return

    def replay(self, after: int = -1) -> list[WALRecord]:
        """Materialized form of `iter_replay` (every record in one list)."""
        return list(self.iter_replay(after))

    def truncate_torn_tail(self) -> None:
        """Drop the garbage bytes found by the last `replay` (and any
        unreachable later segments), so new appends extend the good
        prefix.  No-op when the log ended cleanly."""
        with self._lock:
            if self._torn is None:
                return
            p, good = self._torn
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            with open(p, "ab") as f:
                f.truncate(good)
            for later in self._segments[self._segments.index(p) + 1:]:
                later.unlink(missing_ok=True)
            self._segments = self._segments[:self._segments.index(p) + 1]
            self._torn = None

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
