"""Sketch-state snapshots over the atomic/async checkpoint layer.

A snapshot is one `repro.checkpoint.checkpoint` directory
(``<root>/step_<seq>/``) holding the full sketch-state pytree; ``seq`` is
the engine's *operation sequence number* at capture time — the number of
WAL records (ingest chunks + logged mutations) already applied.  That
makes the snapshot/WAL contract trivial: a snapshot labelled ``seq``
together with the WAL records ``seq, seq+1, ...`` reconstructs the exact
engine state (DESIGN.md §11.2).

All three sketches' states (and the EH grids inside SW-AKDE) are plain
pytrees of dense arrays, so serialization is the generic checkpoint path:
atomic tmp-file + rename writes, numpy ``.npz`` leaves, dtype-exact
restore.  Sharded states are saved as full host arrays and re-placed onto
the service's mesh at restore time (the engine's ``_place_state`` hook).
"""
from __future__ import annotations

import pathlib
import shutil
from typing import Any, Optional

from repro.checkpoint import checkpoint


def snapshot_path(root: str | pathlib.Path, seq: int) -> pathlib.Path:
    return pathlib.Path(root) / f"step_{seq}"


def latest_seq(root: str | pathlib.Path) -> Optional[int]:
    """Newest complete (manifest present) snapshot's seq, or None."""
    return checkpoint.latest_step(root)


def save(root: str | pathlib.Path, seq: int, state: Any,
         fsync: bool = False) -> None:
    """Atomic synchronous snapshot of ``state`` at operation ``seq``.
    ``fsync`` must match the WAL's setting: a snapshot only licenses WAL
    compaction at the durability level it was written with."""
    checkpoint.save(snapshot_path(root, seq), {"state": state}, seq,
                    fsync=fsync)


def async_save(ckpt: checkpoint.AsyncCheckpointer, root: str | pathlib.Path,
               seq: int, state: Any, fsync: bool = False) -> None:
    """Background snapshot: the caller thread only pays the device_get;
    serialization + atomic rename happen on the checkpointer thread.  The
    previous async save is waited for first (at most one in flight)."""
    ckpt.save(snapshot_path(root, seq), {"state": state}, seq, fsync=fsync)


def load(root: str | pathlib.Path, seq: int, state_like: Any) -> Any:
    """Restore the snapshot at ``seq`` into the structure of
    ``state_like`` (host-placed arrays; re-shard via the caller)."""
    tree, _ = checkpoint.restore(snapshot_path(root, seq),
                                 {"state": state_like})
    return tree["state"]


def prune(root: str | pathlib.Path, keep: int = 2) -> int:
    """Delete all but the newest ``keep`` complete snapshots (and any
    orphaned incomplete ones older than them).  Returns dirs removed.

    ``keep`` is clamped to >= 1: the newest snapshot is never deleted —
    by the time prune runs, the WAL records it covers are gone, so
    removing it would make the directory unrecoverable."""
    root = pathlib.Path(root)
    keep = max(1, int(keep))
    steps = []
    for d in root.glob("step_*"):
        try:
            steps.append((int(d.name.split("_")[1]), d))
        except ValueError:
            continue
    steps.sort()
    removed = 0
    for _, d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
        removed += 1
    return removed
