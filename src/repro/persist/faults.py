"""Deterministic, seeded fault injection for the durability + cluster
runtime (DESIGN.md §14).

The streaming stack names its failure points ("fault sites") and calls
`fire(site)` at each one; with no plan installed that is a no-op, so the
production path pays one global read per site.  Tests and the chaos soak
install a `FaultPlan` — a list of `FaultSpec`s saying *which* site fails,
*how* (crash / torn WAL tail / transient-or-permanent IO error / delay)
and on *which hit* — and the same plan object then drives unit tests, the
seeded chaos soak and the CI `chaos` job.

Determinism: hits are counted **per concrete site string** (e.g.
``worker_1/wal.append``), so each engine's counter advances only with its
own deterministic operation order — cross-worker thread interleaving
cannot change which operation a fault lands on.  A spec whose ``site`` is
a glob (``*/engine.commit``) fires independently at every matching site's
own Nth hit.

Named sites (scope prefix ``worker_<w>/`` inside a cluster, empty for a
single service / the coordinator):

  ``wal.append``      WAL record append (fires before bytes are written —
                      a crash here models process death just before the
                      record is durable; ``torn_tail`` additionally leaves
                      a half-written record, modelling death mid-write)
  ``wal.rotate``      segment seal at snapshot time
  ``wal.compact``     sealed-segment deletion after a durable snapshot
  ``snapshot.save``   background state-snapshot write
  ``engine.commit``   the sequential commit half of two-phase ingest
  ``engine.recover``  snapshot + WAL-tail recovery (fires at entry — a
                      repeated fault here models an unrecoverable worker)
  ``engine.query``    a service query tick / direct query snapshot
  ``cluster.merge``   the coordinator's worker-state merge
  ``cluster.query``   a coordinator query tick / direct query snapshot
  ``cluster.salvage`` a dead worker's WAL-tail re-partition, fired after
                      each durable hand-off checkpoint (a crash here
                      models coordinator death mid-salvage; recover()
                      resumes from the checkpointed prefix)

Network sites (repro.net; fired on the *coordinator* side of each RPC —
worker processes have no plan installed, so injection stays deterministic
in one process — scoped ``worker_<w>/`` per remote worker):

  ``net.connect``     TCP connect + protocol handshake to a worker
  ``net.send``        one framed request, fired before any bytes go out
                      (``drop`` here models a lost request: nothing was
                      sent, the call is cleanly retryable)
  ``net.recv``        one framed reply, fired after the request went out
                      (a fault here breaks the channel — the reply may
                      still arrive later and would desync the framing)

The two network modes: ``drop`` raises a *transient* `InjectedIOError`
(the retry-with-backoff model of a lost datagram); ``disconnect`` raises
`InjectedDisconnect` (a `ConnectionError`: the channel is torn down and
the failover layer rebuilds the worker).  ``delay`` at a net site models
latency; combined with the client's RPC timeout it models a hung peer.

Install with the context manager so plans never leak between tests::

    plan = FaultPlan([FaultSpec("worker_0/engine.commit", "crash", hit=3)])
    with installed(plan):
        ...  # run the workload; plan.fired / plan.report() afterwards
"""
from __future__ import annotations

import dataclasses
import fnmatch
import threading
import time
from contextlib import contextmanager
from typing import Callable, List, Optional, Sequence

import numpy as np


class FaultError(RuntimeError):
    """An injected crash (process-death stand-in).  Never transient."""


class InjectedIOError(OSError):
    """An injected IO failure.  ``transient=True`` marks faults the
    failover layer may retry (the disk hiccup / dropped-RPC model);
    ``transient=False`` models a hard error (ENOSPC, dead disk)."""

    def __init__(self, msg: str, transient: bool = False):
        super().__init__(msg)
        self.transient = transient


class InjectedDisconnect(ConnectionError):
    """An injected network disconnect (`mode="disconnect"` at a ``net.*``
    site).  A `ConnectionError`, so the RPC channel layer treats it like a
    real peer reset: the channel breaks and the failover layer rebuilds
    the worker.  Never transient."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: at ``site`` (fnmatch glob over concrete site
    strings), fire ``mode`` on that site's ``hit``-th call (1-based),
    for ``count`` consecutive hits (so ``count=1`` is a one-shot fault
    that "heals", and a large ``count`` models a persistent failure).

    Modes: ``crash`` raises `FaultError`; ``torn_tail`` asks the site to
    leave partial bytes behind (WAL append only; elsewhere = crash) then
    raises; ``io_error`` raises `InjectedIOError` (``transient`` says
    whether retry is allowed to succeed later); ``delay`` sleeps
    ``delay_s`` and lets the operation proceed; ``drop`` raises a
    transient `InjectedIOError` (lost-message model, safe to retry);
    ``disconnect`` raises `InjectedDisconnect` (peer-reset model, the
    channel is torn down)."""
    site: str
    mode: str          # crash | torn_tail | io_error | delay | drop | disconnect
    hit: int = 1
    count: int = 1
    transient: bool = False
    delay_s: float = 0.0

    def __post_init__(self):
        if self.mode not in ("crash", "torn_tail", "io_error", "delay",
                             "drop", "disconnect"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.hit < 1 or self.count < 1:
            raise ValueError(f"hit={self.hit}, count={self.count} (< 1)")


class FaultPlan:
    """A deterministic set of `FaultSpec`s plus per-site hit counters.

    Thread-safe: counters mutate under one lock, so concurrent engines
    can fire sites freely; determinism comes from counting per concrete
    site string (each site's hits are ordered by that site's own caller).

    ``hits`` (dict site -> calls seen) is the fault-*site coverage*
    record; ``fired`` is the log of faults actually injected."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)
        self._lock = threading.Lock()
        self.hits: dict = {}
        self.fired: List[dict] = []

    def fire(self, site: str, tear: Optional[Callable[[], None]] = None):
        """Called by an instrumented site.  Counts the hit, then injects
        the first matching spec's fault (if this is its turn)."""
        with self._lock:
            n = self.hits.get(site, 0) + 1
            self.hits[site] = n
            spec = next(
                (s for s in self.specs
                 if fnmatch.fnmatchcase(site, s.site)
                 and s.hit <= n < s.hit + s.count), None)
            if spec is not None:
                self.fired.append({"site": site, "hit": n,
                                   "mode": spec.mode})
        if spec is None:
            return
        if spec.mode == "delay":
            time.sleep(spec.delay_s)
            return
        if spec.mode == "drop":
            raise InjectedIOError(
                f"injected drop at {site} (hit {n})", transient=True)
        if spec.mode == "disconnect":
            raise InjectedDisconnect(
                f"injected disconnect at {site} (hit {n})")
        if spec.mode == "io_error":
            raise InjectedIOError(
                f"injected io_error at {site} (hit {n})",
                transient=spec.transient)
        if spec.mode == "torn_tail" and tear is not None:
            tear()
        raise FaultError(f"injected {spec.mode} at {site} (hit {n})")

    def report(self) -> dict:
        """Coverage + injection record for the CI chaos artifact."""
        with self._lock:
            return {"sites_hit": dict(sorted(self.hits.items())),
                    "fired": list(self.fired),
                    "specs": [dataclasses.asdict(s) for s in self.specs]}


def seeded_plan(seed: int, scopes: Sequence[str],
                sites: Sequence[str] = ("engine.commit", "wal.append",
                                        "snapshot.save"),
                modes: Sequence[str] = ("crash", "torn_tail", "delay"),
                max_hit: int = 4) -> FaultPlan:
    """The chaos-soak plan generator: for every scope (worker), draw one
    fault — a random site, mode and hit number — from a seeded rng, so
    each worker fails at least once and the whole schedule is a pure
    function of ``seed``.  ``torn_tail`` is only meaningful at
    ``wal.append`` and is remapped to ``crash`` elsewhere."""
    rng = np.random.default_rng(seed)
    specs = []
    for scope in scopes:
        site = sites[int(rng.integers(len(sites)))]
        mode = modes[int(rng.integers(len(modes)))]
        if mode == "torn_tail" and not site.endswith("wal.append"):
            mode = "crash"
        specs.append(FaultSpec(site=f"{scope}{site}", mode=mode,
                               hit=int(rng.integers(1, max_hit + 1)),
                               delay_s=0.002))
    return FaultPlan(specs)


# --- the active plan ---------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Make ``plan`` the process-wide active plan (None = uninstall).
    Prefer the `installed` context manager in tests."""
    global _ACTIVE
    _ACTIVE = plan


@contextmanager
def installed(plan: FaultPlan):
    """Install ``plan`` for the duration of the block (exception-safe)."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def fire(site: str, tear: Optional[Callable[[], None]] = None) -> None:
    """Site entry point: no-op unless a plan is installed."""
    plan = _ACTIVE
    if plan is not None:
        plan.fire(site, tear=tear)


def is_transient(exc: BaseException) -> bool:
    """Whether the failover layer may retry this failure in place
    (exponential backoff) instead of declaring the worker failed."""
    return bool(getattr(exc, "transient", False))
