"""SW-AKDE — Sliding-Window Approximate KDE (paper §4, Algorithm 2).

A RACE grid in which **every cell is an Exponential Histogram**: cell
(i, h_i(x)) records a 1 at the arrival timestep, and a query reads the EH
estimate of "how many increments in the last N steps".  The estimator is the
row *average* (the paper uses the average for SW-AKDE, not median-of-means).

Guarantees (paper Thm 4.1): with EH relative error eps', the estimate is a
(1±eps) multiplicative KDE approximation, eps = 2*eps' + eps'^2, using
O(R*W * (1/(sqrt(1+eps)-1)) * log^2 N) space.

State layout (DESIGN.md §5.3): the EH grid is a single pytree of dense
arrays ``ts: (L, W, levels, slots)``, ``num: (L, W, levels)``; one stream
step touches L cells (one per row) via gather → vmapped eh_add → scatter.
Batch updates (Corollary 4.2) use SumEH cells instead.

Ingest paths:
  * ``swakde_update`` / ``swakde_stream`` — per-point reference semantics
    (one `lax.scan` step per stream element, each step scattering into the
    full EH grid);
  * ``swakde_update_chunk`` / ``swakde_stream_batched`` — the batched-update
    contract: one hash matmul per chunk, then per row the chunk's codes are
    sorted into per-cell segments and each hit cell folds its own adds (own
    timestamps, stream order) in closed-form segment-reduce passes
    (DESIGN.md §12).  The grid is read and written **once per chunk**
    instead of once per point, and the result is bit-identical to the
    per-point path (tests/test_batched_ingest.py).
  * ``swakde_prepare_chunk`` / ``swakde_commit_chunk`` — the two-phase form
    of the same contract (DESIGN.md §10): prepare is the pure hash + sort
    half (timestamps as chunk-relative offsets), commit the closed-form
    segment fold (`kernels.ops.swakde_segment_pass`, optionally capped via
    ``heavy_cell_cap``); ``swakde_update_chunk`` is their composition, and
    the serving engine overlaps prepare of chunk k+1 with commit of chunk k.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import lsh
from .eh import (
    EHConfig, EHState, eh_add, eh_init, eh_merge, eh_query, eh_query_cells,
    SumEHConfig, SumEHState, sum_eh_add, sum_eh_init, sum_eh_query,
)
from .util import saturating_add
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class SWAKDEConfig:
    L: int               # rows (repetitions R in the paper's space bound)
    W: int               # LSH range (bucket count after rehash)
    window: int          # N
    eh_eps: float        # eps' — EH relative error
    heavy_cell_cap: int = 0
    """Skew guard for the chunked commit (DESIGN.md §12): bound how many
    adds one (row, cell) segment may absorb per closed-form commit pass.
    0 = uncapped (a pass still splits only at EH-expiry boundaries).  The
    result is bit-identical for every value — capping only splits a
    segment's pass into shorter sub-chunk passes — so this is purely a
    per-tile work bound for the Pallas kernels."""

    @property
    def kde_eps(self) -> float:
        """Paper Lemma 4.3: eps = 2*eps' + eps'^2."""
        return 2 * self.eh_eps + self.eh_eps**2

    def eh_config(self) -> EHConfig:
        return EHConfig.create(self.window, self.eh_eps)


class SWAKDEState(NamedTuple):
    ts: jax.Array     # (L, W, levels, slots) int32
    num: jax.Array    # (L, W, levels) int32
    t: jax.Array      # () int32 current timestep, saturating (core.util)


def swakde_init(cfg: SWAKDEConfig) -> SWAKDEState:
    """Empty sketch: ``ts (L, W, levels, slots) int32`` (-1 = empty bucket),
    ``num (L, W, levels) int32``, ``t () int32``."""
    eh = cfg.eh_config()
    return SWAKDEState(
        ts=jnp.full((cfg.L, cfg.W, eh.levels, eh.slots), -1, jnp.int32),
        num=jnp.zeros((cfg.L, cfg.W, eh.levels), jnp.int32),
        t=jnp.zeros((), jnp.int32),
    )


def swakde_update(state: SWAKDEState, params, x: jax.Array, cfg: SWAKDEConfig) -> SWAKDEState:
    """One stream element ``x (d,) float32``: hash with L rows, `eh_add` the
    L hit cells at timestep ``t``.  Per-point reference path; the production
    chunked path `swakde_update_chunk` is bit-identical."""
    eh = cfg.eh_config()
    codes = lsh.hash_points(params, x)                      # (L,)
    rows = jnp.arange(cfg.L)
    cell = EHState(ts=state.ts[rows, codes], num=state.num[rows, codes])
    new_cell = jax.vmap(lambda s: eh_add(s, state.t, eh))(cell)
    return SWAKDEState(
        ts=state.ts.at[rows, codes].set(new_cell.ts),
        num=state.num.at[rows, codes].set(new_cell.num),
        t=saturating_add(state.t, 1),
    )


def swakde_stream(state: SWAKDEState, params, xs: jax.Array, cfg: SWAKDEConfig) -> SWAKDEState:
    """Scan a stream of points (T, d) through the sketch, one step per point."""

    def step(s, x):
        return swakde_update(s, params, x, cfg), None

    state, _ = jax.lax.scan(step, state, xs)
    return state


class SWAKDEPrep(NamedTuple):
    """Pure per-chunk precomputation (the *prepare* phase of the two-phase
    ingest contract, DESIGN.md §10): the hash matmul plus the per-row
    sort-into-cell-segments structure.  Depends only on (params, chunk) —
    never on sketch state — so preparing chunk k+1 can overlap committing
    chunk k.  Per-add timestamps are stored as *offsets* within the chunk
    (the stable-sort order); the commit rebases them on the state clock."""
    order: jax.Array      # (L, C) int32 — per-row stable sort order of codes
    seg_code: jax.Array   # (L, SW) int32 — cell code per segment (W = pad)
    seg_len: jax.Array    # (L, SW) int32 — points hitting each segment
    seg_first: jax.Array  # (L, SW) int32 — first sorted position of segment


def swakde_prepare_chunk(params, xs: jax.Array, cfg: SWAKDEConfig,
                         mask: Optional[jax.Array] = None) -> SWAKDEPrep:
    """Prepare phase for ``xs (C, d)``: one hash matmul, then per row a
    stable sort of the chunk's codes into ≤ min(C, W) cell segments (each
    hit cell's points form a contiguous run in stream order).  All of it is
    state-independent — the embarrassingly parallel half of an update.

    ``mask`` (optional, (C,) bool) drops rows from the chunk: masked-out
    rows are hashed to the sentinel code ``W`` — they sort last, land in a
    zero-length sentinel segment, and never touch the grid.  For the result
    to be bit-identical to preparing the compacted chunk, the live rows
    must form a **prefix** (``mask = arange(C) < count``): the stable sort
    then assigns live rows exactly the offsets the unpadded chunk would
    get.  This is the tenant-fleet padding contract (`core.fleet`); pair it
    with ``swakde_commit_chunk(..., count=count)``."""
    return swakde_prepare_from_codes(lsh.hash_points(params, xs), cfg, mask)


def swakde_prepare_from_codes(codes: jax.Array, cfg: SWAKDEConfig,
                              mask: Optional[jax.Array] = None) -> SWAKDEPrep:
    """`swakde_prepare_chunk` with the hash codes ``(C, L)`` supplied by the
    caller — the sort-into-segments half alone.  The tenant-routed fleet
    ingest (`core.fleet`) hashes one mixed multi-tenant chunk with the
    shared params in a single matmul and feeds each tenant's routed code
    block through this entry point."""
    C = codes.shape[0]
    SW = min(C, cfg.W)                       # max distinct cells hit per row
    if mask is not None:
        codes = jnp.where(mask[:, None], codes, jnp.int32(cfg.W))
    pos = jnp.arange(C, dtype=jnp.int32)

    def row_prep(codes_l):
        order = jnp.argsort(codes_l, stable=True)
        sc = codes_l[order]
        is_start = jnp.concatenate([jnp.ones((1,), bool), sc[1:] != sc[:-1]])
        seg_id = jnp.cumsum(is_start).astype(jnp.int32) - 1   # (C,) < SW
        seg_len = jnp.zeros((SW,), jnp.int32).at[seg_id].add(1, mode="drop")
        seg_code = jnp.full((SW,), cfg.W, jnp.int32).at[seg_id].set(
            sc, mode="drop")
        seg_first = jnp.full((SW,), C, jnp.int32).at[seg_id].min(
            pos, mode="drop")
        # Sentinel segments (unused slots *and* the masked-row segment)
        # carry code W and must stay empty so the commit never drains them;
        # real codes are < W, so this is a no-op without a mask.
        seg_len = jnp.where(seg_code == cfg.W, 0, seg_len)
        return order.astype(jnp.int32), seg_code, seg_len, seg_first

    order, seg_code, seg_len, seg_first = jax.vmap(row_prep)(codes.T)
    return SWAKDEPrep(order=order, seg_code=seg_code, seg_len=seg_len,
                      seg_first=seg_first)


def swakde_commit_chunk(state: SWAKDEState, prep: SWAKDEPrep,
                        cfg: SWAKDEConfig,
                        count: Optional[jax.Array] = None) -> SWAKDEState:
    """Commit phase: fold a prepared chunk into the EH grid — the
    state-sequential half, as closed-form segment-reduce passes
    (`kernels.ops.swakde_segment_pass`, DESIGN.md §12) instead of a
    per-add replay.  Per pass, every hit (row, cell) segment absorbs its
    longest expiry-free (and, with ``cfg.heavy_cell_cap``, capped) prefix
    of remaining adds in one Corollary-4.2 cascade settle; the outer while
    loop runs until all segments are drained — O(max splits) iterations,
    not O(max per-cell hit count).  Bit-identical to the per-point path
    (tests/test_batched_ingest.py, tests/test_two_phase.py), including
    dead ring slots.  The (L, W, levels, slots) grid is still read and
    written once per chunk.

    ``count`` (optional, traced) overrides the clock advance: the chunk
    counts as ``count`` stream steps instead of its static row count C.
    Pair it with a prefix ``mask`` on `swakde_prepare_chunk` — masked
    chunks fold only their live prefix, and the clock must advance by the
    live count (the tenant-fleet padding contract, `core.fleet`)."""
    eh = cfg.eh_config()
    C = prep.order.shape[1]

    # Per-add timestamps in sorted-segment order; saturating like the
    # per-point path's t counter.
    sorted_ts = saturating_add(state.t, prep.order)          # (L, C)
    gcode = jnp.minimum(prep.seg_code, cfg.W - 1)            # clamp padding
    rows = jnp.arange(cfg.L)[:, None]
    cell_ts = state.ts[rows, gcode]                          # (L, SW, lv, S)
    cell_num = state.num[rows, gcode]                        # (L, SW, lv)
    done = jnp.zeros_like(prep.seg_len)

    def cond(carry):
        return (carry[2] < prep.seg_len).any()

    def body(carry):
        cts, cnum, dn = carry
        return kernel_ops.swakde_segment_pass(
            cts, cnum, dn, sorted_ts, prep.seg_first, prep.seg_len,
            window=cfg.window, maxb=eh.max_buckets_per_level,
            n_levels=eh.levels, cap=cfg.heavy_cell_cap)

    cell_ts, cell_num, _ = lax.while_loop(
        cond, body, (cell_ts, cell_num, done))
    ts = state.ts.at[rows, prep.seg_code].set(cell_ts, mode="drop")
    num = state.num.at[rows, prep.seg_code].set(cell_num, mode="drop")
    return SWAKDEState(ts=ts, num=num,
                       t=saturating_add(state.t, C if count is None else count))


def swakde_update_chunk(state: SWAKDEState, params, xs: jax.Array,
                        cfg: SWAKDEConfig) -> SWAKDEState:
    """Consume a whole chunk ``xs (C, d)`` in one step, bit-identical to C
    calls of ``swakde_update``.

    Composition of `swakde_prepare_chunk` (hash + sort-into-segments, pure)
    and `swakde_commit_chunk` (EH replay, sequential) — the same ops, fused
    under one jit when called directly.
    """
    return swakde_commit_chunk(state, swakde_prepare_chunk(params, xs, cfg),
                               cfg)


def swakde_stream_batched(state: SWAKDEState, params, xs: jax.Array,
                          cfg: SWAKDEConfig, chunk: int = 1024) -> SWAKDEState:
    """Stream (T, d) points through ``swakde_update_chunk`` in fixed chunks —
    same final state as ``swakde_stream``, O(T / chunk) XLA steps."""
    T = xs.shape[0]
    n_full = T // chunk
    if n_full:
        def step(s, c):
            return swakde_update_chunk(s, params, c, cfg), None
        state, _ = lax.scan(
            step, state, xs[: n_full * chunk].reshape(n_full, chunk, -1))
    if T % chunk:
        state = swakde_update_chunk(state, params, xs[n_full * chunk:], cfg)
    return state


def swakde_row_estimates(state: SWAKDEState, params, q: jax.Array,
                         cfg: SWAKDEConfig) -> jax.Array:
    """Per-row EH window counts at ``q (d,) float32`` → (L,) float32.

    One gather + vmapped `eh_query` over the L hit cells.  Shared by
    `swakde_query` and the sharded query path
    (`repro.parallel.sketch_sharding.sharded_swakde_query_batch`), which
    all-gathers each shard's rows and applies the same mean — making the
    sharded estimate bit-identical to the single-device one."""
    eh = cfg.eh_config()
    codes = lsh.hash_points(params, q)
    rows = jnp.arange(cfg.L)
    cell = EHState(ts=state.ts[rows, codes], num=state.num[rows, codes])
    return jax.vmap(lambda s: eh_query(s, state.t - 1, eh))(cell)


def swakde_grid_estimates(state: SWAKDEState, cfg: SWAKDEConfig) -> jax.Array:
    """EH window counts of **every** cell in the grid → (L, W) float32.

    One vectorised `eh_query_cells` pass over the (L, W) grid at the query
    clock ``t - 1``.  O(L·W·levels·slots) regardless of the batch size —
    once B ≥ W this is cheaper than reading B·L cells, and the per-cell
    arithmetic is identical to `eh_query`, so estimates read from this
    table are bit-identical to the per-query path."""
    return eh_query_cells(state.ts, state.num, state.t - 1, cfg.eh_config())


def swakde_row_estimates_batch(state: SWAKDEState, params, qs: jax.Array,
                               cfg: SWAKDEConfig) -> jax.Array:
    """Batched per-row EH window counts: ``qs (B, d)`` → (B, L) float32.

    The fused read path: one hash matmul for the whole batch, then either

      * B ≥ W — precompute the full (L, W) estimate table
        (`swakde_grid_estimates`, O(L·W) cell queries) and gather (B, L)
        entries, or
      * B < W — gather the (B, L) hit cells once and run one batched
        `eh_query_cells` over them (O(B·L) cell queries);

    both branches are bit-identical to vmapping `swakde_row_estimates`
    over the batch (tests/test_query_batched.py checks each).  Shared by
    `swakde_query_batch` and the sharded query path
    (`repro.parallel.sketch_sharding.sharded_swakde_query_batch`)."""
    codes = lsh.hash_points(params, qs)                 # (B, L) — one matmul
    rows = jnp.arange(cfg.L)[None, :]
    if qs.shape[0] >= cfg.W:
        grid = swakde_grid_estimates(state, cfg)
        if cfg.W <= 256:
            # Read the table through a one-hot contraction rather than a
            # gather: XLA (CPU at least) fuses a gather into its producer
            # and recomputes the cell queries per (b, l) read — the O(B·L)
            # cell work this branch exists to avoid — while a dot forces
            # the (L, W) table to materialise once.  Exact: each one-hot
            # row has a single 1, so the contraction *is* the gather.
            onehot = (codes[..., None] == jnp.arange(cfg.W)).astype(
                grid.dtype)                             # (B, L, W)
            return jnp.einsum("lw,blw->bl", grid, onehot)
        # Large-W fallback: the B·L·W one-hot would dominate; a fusion
        # barrier still keeps the per-read recompute mostly at bay.
        grid = lax.optimization_barrier(grid)
        return grid[rows, codes]
    cell_ts = state.ts[rows, codes]                     # (B, L, levels, slots)
    cell_num = state.num[rows, codes]                   # (B, L, levels)
    return eh_query_cells(cell_ts, cell_num, state.t - 1, cfg.eh_config())


def swakde_row_estimates_from_grid(grid: jax.Array, params, qs: jax.Array,
                                   cfg: SWAKDEConfig) -> jax.Array:
    """Read batched per-row window counts from a precomputed estimate table:
    ``grid (L, W)`` (from `swakde_grid_estimates`), ``qs (B, d)`` → (B, L).

    One hash matmul + one gather — no EH arithmetic at all.  Because the
    grid is pure given (state, t) and per-cell arithmetic matches
    `eh_query` exactly, reads are bit-identical to
    `swakde_row_estimates_batch` on the state the grid was built from.
    This is the query-side snapshot-cache path (`repro.serve.engine`): the
    serving layer caches the grid per committed state (invalidated on
    commit), so B < W query batches hit the table too."""
    codes = lsh.hash_points(params, qs)                 # (B, L)
    return grid[jnp.arange(cfg.L)[None, :], codes]


def swakde_query_from_grid(grid: jax.Array, params, qs: jax.Array,
                           cfg: SWAKDEConfig) -> jax.Array:
    """Batched Ŷ estimates served from a cached grid: ``qs (B, d)`` → (B,)
    float32, bit-identical to `swakde_query_batch` on the grid's state."""
    return swakde_row_estimates_from_grid(grid, params, qs, cfg).mean(-1)


def swakde_query(state: SWAKDEState, params, q: jax.Array, cfg: SWAKDEConfig) -> jax.Array:
    """Average of the L EH estimates — the paper's SW-AKDE estimator Ŷ.

    ``q (d,) float32`` → () float32 (unnormalised window density)."""
    return swakde_row_estimates(state, params, q, cfg).mean()


def swakde_query_batch(state: SWAKDEState, params, qs: jax.Array, cfg: SWAKDEConfig):
    """Fused batch queries: ``qs (B, d) float32`` → (B,) float32.

    One hash matmul + one row gather for the whole batch
    (`swakde_row_estimates_batch`) instead of a vmap over the per-query
    pipeline; estimates are bit-identical to vmapping `swakde_query`."""
    return swakde_row_estimates_batch(state, params, qs, cfg).mean(-1)


def swakde_merge(a: SWAKDEState, b: SWAKDEState, cfg: SWAKDEConfig) -> SWAKDEState:
    """Combine two sketches built (with identical params and a shared clock)
    over different sub-streams — e.g. two ingest workers splitting one
    logical stream.

    Cell-wise exact EH bucket-union merge (`core.eh.eh_merge`): every cell's
    merged estimate counts the window hits of *both* sub-streams, so the
    merged Ŷ ≈ Ŷ_a + Ŷ_b with the standard mergeable-summaries error
    accumulation (eps' per input sketch).  Commutative bit-exactly; total
    bucket mass is preserved exactly, but the bucket *structure* after
    ``merge(merge(a,b),c)`` vs ``merge(a,merge(b,c))`` may differ by one
    cascade level, so associativity holds at the estimate level (within the
    EH error bound), not bitwise — see docs/DESIGN.md §8.3."""
    eh = cfg.eh_config()
    t = jnp.maximum(a.t, b.t)
    shape = a.ts.shape                                  # (L, W, levels, slots)
    flat = lambda x: x.reshape((-1,) + x.shape[2:])

    def cell(ts_a, num_a, ts_b, num_b):
        # Expire at the *query* clock t - 1 (every query path reads
        # eh_query(state, t - 1)): expiring at t would drop the boundary
        # bucket stamped exactly t - window that queries still count.
        m = eh_merge(EHState(ts_a, num_a), EHState(ts_b, num_b), t - 1, eh)
        return m.ts, m.num

    ts, num = jax.vmap(cell)(flat(a.ts), flat(a.num), flat(b.ts), flat(b.num))
    return SWAKDEState(ts=ts.reshape(shape), num=num.reshape(shape[:3]), t=t)


def swakde_kde(state: SWAKDEState, params, q: jax.Array, cfg: SWAKDEConfig) -> jax.Array:
    """Normalised sliding-window density: Ŷ / min(t, N)."""
    denom = jnp.minimum(state.t, cfg.window).astype(jnp.float32)
    return swakde_query(state, params, q, cfg) / jnp.maximum(denom, 1.0)


def swakde_bytes(cfg: SWAKDEConfig) -> int:
    """Concrete sketch footprint (for the §4 space-bound benchmarks)."""
    eh = cfg.eh_config()
    return cfg.L * cfg.W * (eh.levels * eh.slots * 8 + eh.levels * 4) + 8


# ---------------------------------------------------------------------------
# Batch-update variant (Corollary 4.2): window = last N *batches*
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchSWAKDEConfig:
    L: int
    W: int
    window: int        # N batches
    eh_eps: float
    batch_size: int    # R

    def eh_config(self) -> SumEHConfig:
        return SumEHConfig.create(self.window, self.eh_eps, self.batch_size)


class BatchSWAKDEState(NamedTuple):
    ts: jax.Array     # (L, W, levels, slots) int32
    num: jax.Array    # (L, W, levels) int32
    t: jax.Array      # () int32 — batch timestep


def batch_swakde_init(cfg: BatchSWAKDEConfig) -> BatchSWAKDEState:
    eh = cfg.eh_config().base
    return BatchSWAKDEState(
        ts=jnp.full((cfg.L, cfg.W, eh.levels, eh.slots), -1, jnp.int32),
        num=jnp.zeros((cfg.L, cfg.W, eh.levels), jnp.int32),
        t=jnp.zeros((), jnp.int32),
    )


def batch_swakde_update(
    state: BatchSWAKDEState, params, batch: jax.Array, cfg: BatchSWAKDEConfig
) -> BatchSWAKDEState:
    """One *batch* arrives at one timestep: each cell's increment is the
    number of batch elements hashing to it (0..R)."""
    eh = cfg.eh_config()
    codes = lsh.hash_points(params, batch)                # (R, L)
    incr = kernel_ops.race_hist(codes, cfg.W)             # (L, W)

    def upd_cell(ts, num, v):
        s = sum_eh_add(SumEHState(ts, num), state.t, v, eh)
        return s.ts, s.num

    ts, num = jax.vmap(jax.vmap(upd_cell))(state.ts, state.num, incr)
    return BatchSWAKDEState(ts=ts, num=num, t=saturating_add(state.t, 1))


def batch_swakde_query(
    state: BatchSWAKDEState, params, q: jax.Array, cfg: BatchSWAKDEConfig
) -> jax.Array:
    eh = cfg.eh_config()
    codes = lsh.hash_points(params, q)
    rows = jnp.arange(cfg.L)
    cell = SumEHState(state.ts[rows, codes], state.num[rows, codes])
    vals = jax.vmap(lambda s: sum_eh_query(s, state.t - 1, eh))(cell)
    return vals.mean()
