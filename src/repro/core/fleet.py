"""Multi-tenant sketch fleets: stacked states + tenant-routed vmapped ingest.

A fleet is T independent sketches of one kind sharing a single set of LSH
params, stored as ONE stacked pytree whose every leaf gains a leading
``[T]`` tenant axis (``RACEState.counts`` becomes ``(T, L, W)``, etc.).
Ingest takes one *mixed* chunk ``xs (B, d)`` tagged with per-point tenant
slots ``tids (B,)`` and commits it with a single device dispatch:

  1. hash the whole mixed chunk once (params are fleet-shared, and
     `lsh.hash_points` is pinned batch-shape invariant);
  2. route: a stable sort by tenant id (`route_chunk` — the same
     sort-by-key machinery as S-ANN's (row, code) append sort) gathers each
     tenant's points into a cap-padded ``(T, cap)`` block, preserving
     stream order within each tenant;
  3. commit: one `jax.vmap` of the existing two-phase prepare/commit over
     the tenant axis (RACE's commit is pure integer addition, so its
     "vmapped commit" collapses into one fused scatter-add).

Every fleet function is pinned bit-identical to the per-tenant oracle loop
of the single-sketch paths (tests/test_tenant_fleet.py).  The padding
contracts that make this exact:

  * routed blocks put the tenant's real points in a *prefix* (pads trail),
  * S-ANN pads get ``keep=False`` (prefix-stable `sann_row_keys` means the
    pad draws never perturb the real ones),
  * SW-AKDE pads hash to the sentinel code W and their segments are
    zeroed (`swakde_prepare_from_codes(mask=...)`),
  * both commits advance their clocks by the *real* count
    (``count=`` kwarg), not the padded block size.

Queries gather per-request tenant rows (tables / cells / counters) from
the stacked state and run the existing fused batch kernels once for the
whole mixed batch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from . import lsh
from .eh import eh_query_cells
from .race import RACEState, estimate_from_vals
from .sann import (SANNConfig, SANNState, _first_occurrence_mask,
                   sann_commit_chunk, sann_prepare_given_keep, sann_row_keys)
from .swakde import (SWAKDEConfig, SWAKDEState, swakde_commit_chunk,
                     swakde_prepare_from_codes)
from .util import saturating_add
from ..kernels import ops as kernel_ops

tree_map = jax.tree_util.tree_map


# --------------------------------------------------------------------------
# stacked-state helpers
# --------------------------------------------------------------------------

def fleet_stack(states: Sequence):
    """Stack identically-shaped sketch states into one fleet pytree
    (every leaf gains a leading ``[T]`` axis)."""
    return tree_map(lambda *xs: jnp.stack(xs), *states)


def fleet_row(stacked, i):
    """Extract tenant row ``i`` as a plain single-sketch state."""
    return tree_map(lambda x: x[i], stacked)


def fleet_set_row(stacked, i, row):
    """Functionally replace tenant row ``i`` with ``row``."""
    return tree_map(lambda x, r: x.at[i].set(r), stacked, row)


def fleet_broadcast(state, T: int):
    """A fleet of ``T`` copies of ``state`` (e.g. T empty sketches)."""
    return tree_map(
        lambda x: jnp.broadcast_to(x[None], (T,) + x.shape).copy(), state)


# --------------------------------------------------------------------------
# tenant routing
# --------------------------------------------------------------------------

class FleetRoute(NamedTuple):
    """Gather plan for one mixed chunk: tenant slot t's points are chunk
    rows ``take[t, :counts[t]]`` in stream order; columns >= counts[t] are
    arbitrary in-bounds pads flagged False in ``valid``."""
    take: jax.Array    # (T, cap) int32 — chunk row index per padded block
    valid: jax.Array   # (T, cap) bool  — prefix mask: col < counts[t]
    counts: jax.Array  # (T,) int32     — real points per tenant slot


def route_chunk(tids: jax.Array, num_slots: int, cap: int) -> FleetRoute:
    """Sort/segment a mixed chunk by tenant slot.

    ``tids (B,) int32`` holds per-point tenant slots; ids outside
    ``[0, num_slots)`` (use -1) are dropped.  ``cap`` bounds the per-slot
    count — the caller guarantees every slot receives <= cap points (the
    serve layer splits oversized chunks; `TenantFleet`).

    One stable argsort by slot id groups each tenant's points contiguously
    *in stream order* (stability), exactly like the (row, code) append sort
    in `core.sann.sann_prepare_given_keep`; prefix sums of the per-slot
    histogram locate each group's start."""
    B = tids.shape[0]
    slot = jnp.where((tids >= 0) & (tids < num_slots), tids,
                     jnp.int32(num_slots))
    order = jnp.argsort(slot, stable=True)                      # (B,)
    counts = jnp.zeros((num_slots,), jnp.int32).at[slot].add(1, mode="drop")
    starts = jnp.cumsum(counts) - counts                        # exclusive
    idx = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    take = order[jnp.clip(idx, 0, B - 1)]
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < counts[:, None]
    return FleetRoute(take=take, valid=valid, counts=counts)


# --------------------------------------------------------------------------
# RACE fleet
# --------------------------------------------------------------------------

def race_fleet_ingest(stacked: RACEState, params, xs: jax.Array,
                      tids: jax.Array) -> RACEState:
    """Tenant-routed RACE ingest: stacked ``counts (T, L, W)``, one mixed
    chunk, ONE fused scatter-add.

    RACE's commit is pure integer addition, so the vmapped two-phase
    prepare/commit collapses algebraically into a single scatter of all B
    points' (tenant, row, code) triples — bit-identical to the per-tenant
    `race_prepare_chunk` + `race_commit_chunk` loop (integer adds are
    exact and order-free) without even needing `route_chunk`."""
    T = stacked.counts.shape[0]
    codes = lsh.hash_points(params, xs)                         # (B, L)
    L = codes.shape[-1]
    slot = jnp.where((tids >= 0) & (tids < T), tids, jnp.int32(T))
    counts = stacked.counts.at[
        slot[:, None], jnp.arange(L)[None, :], codes].add(1, mode="drop")
    per = jnp.zeros((T,), jnp.int32).at[slot].add(1, mode="drop")
    return RACEState(counts=counts, n=saturating_add(stacked.n, per))


def race_fleet_row_reads(stacked: RACEState, params, qs: jax.Array,
                         tids: jax.Array) -> jax.Array:
    """Per-request row reads from the stacked fleet: ``qs (B, d)``,
    ``tids (B,)`` → (B, L) float32.  One hash matmul + one tenant-indexed
    gather — the tenant-axis form of `core.race.race_row_reads`."""
    codes = lsh.hash_points(params, qs)                         # (B, L)
    L = codes.shape[-1]
    t = jnp.clip(tids, 0, stacked.counts.shape[0] - 1)
    return stacked.counts[
        t[:, None], jnp.arange(L)[None, :], codes].astype(jnp.float32)


def race_fleet_query(stacked: RACEState, params, qs: jax.Array,
                     tids: jax.Array, median_of_means: int = 0) -> jax.Array:
    """Batched per-tenant RACE estimates: (B,) float32, bit-identical to
    `race_query_batch` against each request's own sketch."""
    return estimate_from_vals(race_fleet_row_reads(stacked, params, qs, tids),
                              median_of_means)


def race_fleet_kde(stacked: RACEState, params, qs: jax.Array,
                   tids: jax.Array, median_of_means: int = 0) -> jax.Array:
    """Normalised per-tenant KDE reads (`race_kde` with a tenant axis)."""
    est = race_fleet_query(stacked, params, qs, tids, median_of_means)
    t = jnp.clip(tids, 0, stacked.n.shape[0] - 1)
    return est / jnp.maximum(stacked.n[t], 1).astype(jnp.float32)


# --------------------------------------------------------------------------
# SW-AKDE fleet
# --------------------------------------------------------------------------

def swakde_fleet_ingest(stacked: SWAKDEState, params, xs: jax.Array,
                        tids: jax.Array, cfg: SWAKDEConfig,
                        cap: int) -> SWAKDEState:
    """Tenant-routed SW-AKDE ingest: hash the mixed chunk once, route the
    *codes* (`route_chunk`), and run ONE vmapped two-phase prepare/commit
    over the tenant axis.

    Pads hash to the sentinel code W inside `swakde_prepare_from_codes`
    (mask), so their segments carry zero mass and are dropped by the
    scatter-back; ``count=`` advances each tenant clock by its real count.
    Bit-identical to the per-tenant `swakde_update_chunk` loop."""
    T = stacked.t.shape[0]
    codes = lsh.hash_points(params, xs)                         # (B, L)
    route = route_chunk(tids, T, cap)
    codes_t = codes[route.take]                                 # (T, cap, L)

    def one(st, cb, vb, cnt):
        prep = swakde_prepare_from_codes(cb, cfg, mask=vb)
        return swakde_commit_chunk(st, prep, cfg, count=cnt)

    return jax.vmap(one)(stacked, codes_t, route.valid, route.counts)


def swakde_fleet_grid(stacked: SWAKDEState, cfg: SWAKDEConfig) -> jax.Array:
    """Window-count estimate tables for every tenant: (T, L, W) float32 —
    `swakde_grid_estimates` broadcast over the tenant axis (one
    `eh_query_cells` pass, each tenant expiring at its own clock)."""
    t = (stacked.t - 1)[:, None, None, None, None]
    return eh_query_cells(stacked.ts, stacked.num, t, cfg.eh_config())


def swakde_fleet_row_estimates(stacked: SWAKDEState, params, qs: jax.Array,
                               tids: jax.Array,
                               cfg: SWAKDEConfig) -> jax.Array:
    """Per-request EH row estimates from the stacked fleet: (B, L) float32.

    One hash matmul, one tenant-indexed cell gather, one batched
    `eh_query_cells` at each request's own tenant clock — the per-cell
    arithmetic is identical to `eh_query`, so estimates are bit-identical
    to `swakde_row_estimates_batch` against the request's own sketch."""
    codes = lsh.hash_points(params, qs)                         # (B, L)
    L = codes.shape[-1]
    t = jnp.clip(tids, 0, stacked.t.shape[0] - 1)
    rows = jnp.arange(L)[None, :]
    cell_ts = stacked.ts[t[:, None], rows, codes]    # (B, L, levels, slots)
    cell_num = stacked.num[t[:, None], rows, codes]  # (B, L, levels)
    tq = (stacked.t[t] - 1)[:, None, None, None]
    return eh_query_cells(cell_ts, cell_num, tq, cfg.eh_config())


def swakde_fleet_query(stacked: SWAKDEState, params, qs: jax.Array,
                       tids: jax.Array, cfg: SWAKDEConfig) -> jax.Array:
    """Batched per-tenant Ŷ estimates: (B,) float32, bit-identical to
    `swakde_query_batch` against each request's own sketch."""
    return swakde_fleet_row_estimates(stacked, params, qs, tids, cfg).mean(-1)


def swakde_fleet_kde(stacked: SWAKDEState, params, qs: jax.Array,
                     tids: jax.Array, cfg: SWAKDEConfig) -> jax.Array:
    """Normalised per-tenant window densities (`swakde_kde` + tenant axis)."""
    est = swakde_fleet_query(stacked, params, qs, tids, cfg)
    t = jnp.clip(tids, 0, stacked.t.shape[0] - 1)
    denom = jnp.minimum(stacked.t[t], cfg.window).astype(jnp.float32)
    return est / jnp.maximum(denom, 1.0)


# --------------------------------------------------------------------------
# S-ANN fleet
# --------------------------------------------------------------------------

def sann_fleet_ingest(stacked: SANNState, params, xs: jax.Array,
                      tids: jax.Array, keys: jax.Array, cfg: SANNConfig,
                      cap: int) -> SANNState:
    """Tenant-routed S-ANN ingest: hash once, route points *and* codes,
    ONE vmapped two-phase prepare/commit over the tenant axis.

    ``keys (T, 2)`` holds one PRNG key per tenant slot for this chunk's
    Bernoulli draws.  Because `sann_row_keys` is prefix-stable, drawing
    over the cap-padded block and masking pads to ``keep=False`` yields
    exactly the draws the unpadded per-tenant `sann_prepare_chunk` would
    make; pads write nothing and ``count=`` advances ``n_seen`` by the
    real count, so every tenant row lands bit-identical to the single
    sketch ingesting its own sub-stream under the same key."""
    T = stacked.n_seen.shape[0]
    codes = lsh.hash_points(params, xs)                         # (B, L)
    route = route_chunk(tids, T, cap)
    xs_t = xs[route.take]                                       # (T, cap, d)
    codes_t = codes[route.take]                                 # (T, cap, L)

    def one(st, key_t, xb, cb, vb, cnt):
        rks = sann_row_keys(key_t, cap)
        keep = jax.vmap(
            lambda k: jax.random.bernoulli(k, cfg.keep_prob))(rks) & vb
        prep = sann_prepare_given_keep(params, xb, keep, cfg, codes=cb)
        return sann_commit_chunk(st, prep, cfg, count=cnt)

    return jax.vmap(one)(stacked, keys, xs_t, codes_t, route.valid,
                         route.counts)


def sann_fleet_candidates(stacked: SANNState, params, qs: jax.Array,
                          tids: jax.Array, cfg: SANNConfig):
    """Per-request bucket candidates from the stacked fleet: one hash
    matmul + one tenant-indexed table gather → ``(cand, ok)`` with the
    same row-major (L, bucket_cap) column order as
    `sann_bucket_candidates_batch` on the request's own sketch."""
    codes = lsh.hash_points(params, qs)                         # (B, L)
    t = jnp.clip(tids, 0, stacked.n_seen.shape[0] - 1)
    cand = stacked.tables[t[:, None], jnp.arange(cfg.L)[None, :], codes]
    cand = cand.reshape(qs.shape[0], cfg.L * cfg.bucket_cap)
    ok = (cand >= 0) & stacked.valid[t[:, None], jnp.maximum(cand, 0)]
    return cand, ok, t


def sann_fleet_query_topk(stacked: SANNState, params, qs: jax.Array,
                          tids: jax.Array, cfg: SANNConfig, topk: int = 50):
    """Batched per-tenant top-k: ``(ids (B, k), dists (B, k))`` with the
    `sann_query_topk_batch` padding/ordering contract, bit-identical to
    running it against each request's own sketch (slot ids index the
    request's tenant row)."""
    cand, ok, t = sann_fleet_candidates(stacked, params, qs, tids, cfg)
    mask = ok & _first_occurrence_mask(cand, stacked.points.shape[1])
    vecs = stacked.points[t[:, None], jnp.maximum(cand, 0)]  # (B, C, d)
    k = min(topk, cand.shape[1])
    d2, idx = kernel_ops.batch_score_topk(qs, vecs, mask, k)
    ids = jnp.where(jnp.isfinite(d2),
                    jnp.take_along_axis(cand, idx, axis=1), -1)
    return ids, jnp.sqrt(d2)


def sann_fleet_query(stacked: SANNState, params, qs: jax.Array,
                     tids: jax.Array, cfg: SANNConfig):
    """Batched per-tenant (c, r)-NN queries → `SANNResult` with (B,)
    fields, bit-identical to `sann_query_batch` per request.

    Same masked truncate-and-score as `sann_score_candidates_batch`, with
    the candidate-vector gather indexed by tenant row."""
    from .sann import SANNResult                      # local: avoid cycle
    cand, ok, t = sann_fleet_candidates(stacked, params, qs, tids, cfg)
    budget = 3 * cfg.L
    C = cand.shape[1]
    budget_eff = min(budget, C)
    csum = jnp.cumsum(ok, axis=1).astype(jnp.int32)
    targets = jnp.arange(1, budget_eff + 1, dtype=jnp.int32)
    sel = jax.vmap(lambda a: jnp.searchsorted(a, targets, side="left"))(csum)
    sel_ok = sel < C
    sel = jnp.minimum(sel, C - 1)
    sel_cand = jnp.where(sel_ok, jnp.take_along_axis(cand, sel, axis=1), -1)
    vecs = stacked.points[t[:, None], jnp.maximum(sel_cand, 0)]
    d2, idx = kernel_ops.batch_score_topk(qs, vecs, sel_ok, 1)
    dist = jnp.sqrt(d2[:, 0])
    found = dist <= cfg.c * cfg.r
    best = jnp.take_along_axis(sel_cand, idx, axis=1)[:, 0]
    return SANNResult(
        index=jnp.where(found, best, -1),
        distance=jnp.where(found, dist, jnp.inf),
        found=found,
        n_candidates=jnp.minimum(csum[:, -1], budget).astype(jnp.int32),
    )
