"""Exponential Histograms [DGIM02] in fixed-shape JAX (paper §2.4).

Two variants:

  * ``EH``      — Basic Counting over 0/1 streams (the SW-AKDE cell, §4.1).
                  Buckets have power-of-two sizes, so the state is a dense
                  ring of timestamps per size-level.  This is the canonical
                  DGIM structure with its (1+eps') guarantee.
  * ``SumEH``   — the [DGIM02] Sum generalisation for batch updates
                  (Corollary 4.2): increments in [0, R] per timestep; buckets
                  carry explicit sizes and are merged oldest-first to restore
                  Invariant 1.

Hardware adaptation (DESIGN.md §5.3): DGIM's linked-list buckets become a
dense ``ts[levels, slots]`` array — Invariant 2 bounds buckets-per-size by
k/2+1 and sizes by log levels, so the dense layout is exact, vmap-able over
the RACE grid and scan-able over the stream.  Expired buckets are masked at
query time and lazily compacted at update time (within a level, timestamps
are sorted newest-first, so expiry is a suffix).

All timestamps are int32 (streams up to 2^31 steps; int64 would need jax_enable_x64).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class EHConfig:
    window: int          # N — sliding-window length (timesteps)
    k: int               # ceil(1/eps'): max k/2+1 buckets per level
    levels: int          # number of size levels (2^0 .. 2^{levels-1})
    slots: int           # per-level ring capacity = k//2 + 2

    @staticmethod
    def create(window: int, eps: float) -> "EHConfig":
        import math
        k = max(2, math.ceil(1.0 / eps))
        levels = math.ceil(math.log2(max(window, 2))) + 2
        return EHConfig(window=window, k=k, levels=levels, slots=k // 2 + 2)

    @property
    def max_buckets_per_level(self) -> int:
        return self.k // 2 + 1


class EHState(NamedTuple):
    ts: jax.Array    # (levels, slots) int32 — bucket timestamps, newest-first
    num: jax.Array   # (levels,) int32 — live buckets per level


def eh_init(cfg: EHConfig) -> EHState:
    return EHState(
        ts=jnp.full((cfg.levels, cfg.slots), -1, dtype=jnp.int32),
        num=jnp.zeros((cfg.levels,), dtype=jnp.int32),
    )


def _expire(state: EHState, t: jax.Array, cfg: EHConfig) -> EHState:
    """Drop buckets whose timestamp left the window (suffix per level)."""
    idx = jnp.arange(cfg.slots)[None, :]
    live = (idx < state.num[:, None]) & (state.ts > t - cfg.window)
    return EHState(ts=state.ts, num=live.sum(axis=1).astype(jnp.int32))


def eh_add_ref(state: EHState, t: jax.Array, cfg: EHConfig) -> EHState:
    """Record a 1 at time ``t``; cascade merges to maintain DGIM invariants.

    The cascade is a `lax.scan` over the levels axis: each level receives an
    optional carry bucket from below, prepends it, and (on overflow) merges
    its two oldest buckets into a carry for the level above.  This is the
    semantic oracle for the closed-form ``eh_add`` below (tests/test_eh.py
    pins bitwise equality, dead slots included).
    """
    state = _expire(state, t, cfg)
    ts, num = state

    def level_step(carry, level_row):
        in_ts, in_flag = carry                    # bucket pushed from below
        row_ts, row_num, level = level_row        # (slots,), (), ()
        new_ts = jnp.where(
            in_flag, jnp.roll(row_ts, 1).at[0].set(in_ts), row_ts)
        new_num = row_num + in_flag.astype(jnp.int32)
        # Two oldest buckets live at indices new_num-1 (oldest) and
        # new_num-2.  The merged bucket keeps the *newer* timestamp (DGIM:
        # a bucket's timestamp is its most recent 1).  The top level never
        # merges (sized so in-window mass cannot overflow it).
        overflow = (new_num > cfg.max_buckets_per_level) & (level < cfg.levels - 1)
        merged_ts = new_ts[jnp.maximum(new_num - 2, 0)]
        out_num = jnp.where(overflow, new_num - 2, new_num)
        return (merged_ts, overflow), (new_ts, out_num)

    levels = jnp.arange(cfg.levels, dtype=jnp.int32)
    # The size-1 bucket for ``t`` enters as the carry into level 0.
    _, (ts, num) = lax.scan(level_step,
                            (jnp.asarray(t, ts.dtype), jnp.bool_(True)),
                            (ts, num, levels))
    return EHState(ts=ts, num=num)


def eh_add(state: EHState, t: jax.Array, cfg: EHConfig) -> EHState:
    """Record a 1 at time ``t`` — closed-form carry count, no level scan.

    The unit-add cascade is binary-counter carry propagation: the carry
    reaches level l iff every level below it is full, so the whole carry
    chain is known up front from the (post-expiry) per-level counts.  A
    level the carry reaches prepends its incoming stamp; a level that also
    overflows merges its two oldest buckets and the merged stamp — the
    *newer* of the pair, which sits at ring index ``num-2`` — becomes the
    next level's incoming stamp.  All of it is one vectorised pass over the
    (levels, slots) buffer with no sequential dependence, which is what the
    batched ingest kernels vmap over thousands of cells.

    Bit-identical to ``eh_add_ref`` including the dead slots beyond ``num``
    (a reached level shifts its whole ring, an unreached one is untouched —
    the same writes the scan performs)."""
    state = _expire(state, t, cfg)
    ts, num = state
    maxb = cfg.max_buckets_per_level
    lvl = jnp.arange(cfg.levels, dtype=jnp.int32)
    # ``full`` = this level fires a merge when the carry reaches it; the
    # carry reaches level l iff levels 0..l-1 are all full.
    full = (num >= maxb) & (lvl < cfg.levels - 1)
    blocked = jnp.cumsum((~full).astype(jnp.int32))
    reach = jnp.concatenate([jnp.ones((1,), bool), blocked[:-1] == 0])
    # Incoming stamp per level: t at level 0; above, the merged stamp of
    # the level below = its pre-add ring at index num-2 (num >= maxb >= 2
    # whenever the level fires, so the index never touches the carry slot).
    below = jnp.clip(num[:-1] - 2, 0, cfg.slots - 1)
    carry = jnp.concatenate([
        jnp.asarray(t, ts.dtype)[None], ts[lvl[:-1], below]])
    shifted = jnp.concatenate([carry[:, None], ts[:, :-1]], axis=1)
    fired = reach & full
    return EHState(
        ts=jnp.where(reach[:, None], shifted, ts),
        num=num + reach.astype(jnp.int32) - 2 * fired.astype(jnp.int32))


def eh_step(state: EHState, t: jax.Array, bit: jax.Array, cfg: EHConfig) -> EHState:
    """Add ``bit`` (0 or 1) at time t — the scan-friendly entry point."""
    added = eh_add(state, t, cfg)
    keep = bit.astype(bool)
    return jax.tree.map(lambda a, b: jnp.where(keep, a, b), added, _expire(state, t, cfg))


def eh_query_cells(ts: jax.Array, num: jax.Array, t: jax.Array,
                   cfg: EHConfig) -> jax.Array:
    """`eh_query` over a whole batch of cells in one pass.

    ``ts (..., levels, slots)``, ``num (..., levels)`` → estimates ``(...)``
    float32.  Bit-identical per cell to `eh_query` (same integer reductions,
    broadcast over the leading axes) — this is what lets the batched query
    engine (core.swakde.swakde_row_estimates_batch) precompute or gather
    cell estimates grid-wide without a vmap per query."""
    idx = jnp.arange(cfg.slots)
    live = (idx < num[..., None]) & (ts > t - cfg.window)
    sizes = (jnp.int32(1) << jnp.arange(cfg.levels, dtype=jnp.int32))[:, None]
    total = jnp.sum(jnp.where(live, sizes, 0), axis=(-2, -1))
    # Oldest live bucket = the live bucket at the highest level (sizes are
    # age-monotone), i.e. the largest level with any live bucket.
    has = live.any(axis=-1)
    lvl = jnp.arange(cfg.levels)
    last_level = jnp.max(jnp.where(has, lvl, -1), axis=-1)
    last = jnp.where(last_level >= 0,
                     jnp.int32(1) << last_level.astype(jnp.int32), 0)
    est = total - last // 2
    return jnp.maximum(est, 0).astype(jnp.float32)


def eh_query(state: EHState, t: jax.Array, cfg: EHConfig) -> jax.Array:
    """DGIM estimate of #1s in (t - window, t]:  TOTAL - LAST/2.

    (Paper §2.4 states the formula once as TOTAL-LAST/2 and once as
    (TOTAL-LAST)/2; the former is DGIM's and is what we use.)
    """
    return eh_query_cells(state.ts, state.num, t, cfg)


def eh_merge(a: EHState, b: EHState, t: jax.Array, cfg: EHConfig) -> EHState:
    """Merge two EHs over disjoint sub-streams sharing one clock (mergeable-
    summaries EH merge; the SW-AKDE multi-worker combine).

    Both inputs are expired at ``t``, then per level (bottom-up) the union
    of A's, B's and the carried-up buckets is sorted newest-first and the
    DGIM invariant is restored by pairing *oldest* buckets: each pair
    becomes one bucket at the next level stamped with the pair's newer
    timestamp — exactly the `eh_add` cascade rule, applied to a multiset.
    Total bucket mass is preserved exactly (modulo expiry and the top-level
    capacity clamp, which only triggers when the merged in-window mass
    exceeds the 4N headroom the levels are sized for).

    Properties (docs/DESIGN.md §8.3): commutative bit-exactly (the per-level
    sort erases input order); a merge with an empty EH is a live-state
    identity; the merged estimate carries the standard additive error,
    eps_a + eps_b + eps_a*eps_b relative, instead of the single-stream
    eps'."""
    a = _expire(a, t, cfg)
    b = _expire(b, t, cfg)
    S = cfg.slots
    C = 2 * S                                    # carry capacity (see bound
    #   in docs/DESIGN.md §8.3: m <= (3*slots+2)/2 < 2*slots)
    pool_len = 2 * S + C
    maxb = cfg.max_buckets_per_level
    iota_s = jnp.arange(S, dtype=jnp.int32)
    iota_c = jnp.arange(C, dtype=jnp.int32)

    def level_step(carry, level_row):
        c_ts, c_n = carry                        # buckets pushed from below
        a_ts, a_n, b_ts, b_n, level = level_row
        pool = jnp.concatenate([
            jnp.where(iota_s < a_n, a_ts, -1),
            jnp.where(iota_s < b_n, b_ts, -1),
            jnp.where(iota_c < c_n, c_ts, -1),
        ])
        s = -jnp.sort(-pool)                     # newest first, -1 pads last
        count = a_n + b_n + c_n
        can_merge = level < cfg.levels - 1       # top level never merges
        m = jnp.where((count > maxb) & can_merge, (count - maxb + 1) // 2, 0)
        new_num = jnp.minimum(count - 2 * m, S)  # top-level capacity clamp
        # j-th merge consumes the oldest remaining pair (s[count-1-2j],
        # s[count-2-2j]) and carries up the pair's *newer* stamp.
        idx = jnp.clip(count - 2 - 2 * iota_c, 0, pool_len - 1)
        out_ts = jnp.where(iota_c < m, s[idx], -1)
        return (out_ts, m), (s[:S], new_num)

    levels = jnp.arange(cfg.levels, dtype=jnp.int32)
    init = (jnp.full((C,), -1, jnp.int32), jnp.int32(0))
    _, (ts, num) = lax.scan(
        level_step, init, (a.ts, a.num, b.ts, b.num, levels))
    return EHState(ts=ts, num=num.astype(jnp.int32))


def eh_exact_upper(cfg: EHConfig) -> int:
    """Worst-case live buckets — the paper's space bound (k/2+1)(log(2N/k)+1)+1."""
    import math
    return (cfg.k // 2 + 1) * (int(math.log2(max(2 * cfg.window / cfg.k, 2))) + 2)


# ---------------------------------------------------------------------------
# SumEH — batch updates (Corollary 4.2): per-step increments in [0, R]
#
# [DGIM02 §Sum]: arrival of value v at time t is *exactly* the arrival of v
# unit elements sharing timestamp t.  We therefore reuse the provably-correct
# binary EH cascade, applied v times (v <= batch_max, a small constant), with
# levels sized for window*batch_max total mass.  This keeps the (1+eps')
# guarantee verbatim — no bespoke canonical-form merge logic to get wrong.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SumEHConfig:
    base: EHConfig
    batch_max: int    # R — max increment per timestep

    @staticmethod
    def create(window: int, eps: float, batch_max: int) -> "SumEHConfig":
        import math
        k = max(2, math.ceil(1.0 / eps))
        levels = math.ceil(math.log2(max(window * batch_max, 2))) + 2
        base = EHConfig(window=window, k=k, levels=levels, slots=k // 2 + 2)
        return SumEHConfig(base=base, batch_max=batch_max)

    @property
    def max_buckets(self) -> int:
        return self.base.levels * self.base.slots


SumEHState = EHState  # identical dense layout


def sum_eh_init(cfg: SumEHConfig) -> SumEHState:
    return eh_init(cfg.base)


def sum_eh_add_ref(state: SumEHState, t, value, cfg: SumEHConfig) -> SumEHState:
    """Reference: ``value`` sequential unit ``eh_add``s, all stamped ``t``.

    O(batch_max · levels) — kept as the semantic oracle for the closed-form
    ``sum_eh_add`` below (tests/test_eh.py checks live-state equivalence)."""

    def body(i, s):
        added = eh_add(s, t, cfg.base)
        return jax.tree.map(lambda a, b: jnp.where(i < value, a, b), added, s)

    state = lax.fori_loop(0, cfg.batch_max, body, state)
    # value == 0 still advances expiry lazily (query-side masking handles it).
    return state


def sum_eh_add(state: SumEHState, t, value, cfg: SumEHConfig) -> SumEHState:
    """Add ``value`` in [0, batch_max] unit elements, all stamped ``t`` —
    closed-form cascade, O(levels · slots) independent of ``value``.

    Because all ``value`` unit buckets share one timestamp, the DGIM cascade
    is binary-counter carry propagation and each level can be settled in one
    shot.  Per level, arrivals are consumed oldest-first, so the j-th merge
    consumes queue items 2j and 2j+1 of

        queue = reverse(live ring) ++ carried-up stamps ++ t, t, ...

    and emits the newer stamp ``queue[2j+1]`` to the level above.  The merge
    count follows from the saturation dynamics: the level fills to
    ``maxb+1`` once, then every second arrival fires a merge.  Carried-up
    stamps are representable as (a ≤ slots-entry prefix of old stamps, a
    count of trailing ``t``s), which the halving keeps closed across levels.

    The live state (ring prefixes ``ts[:, :num]`` and ``num``) is identical
    to ``sum_eh_add_ref``; slots beyond ``num`` may hold different garbage
    (they are masked by every reader)."""
    base = cfg.base
    maxb = base.max_buckets_per_level
    S = base.slots
    expired = _expire(state, t, base)
    ts0, num0 = expired
    t32 = jnp.asarray(t, ts0.dtype)
    iota = jnp.arange(S, dtype=jnp.int32)

    def level_step(carry, level_row):
        pre, npre, r = carry                 # carried-up stamps: prefix + t's
        row_ts, num, level = level_row       # (S,), (), ()
        c = npre + r
        total = num + c
        K = num + npre                       # queue prefix length (non-t part)

        def q(i):                            # queue lookup at indices i
            ring_val = row_ts[jnp.clip(num - 1 - i, 0, S - 1)]
            pre_val = pre[jnp.clip(i - num, 0, S - 1)]
            return jnp.where(i < num, ring_val,
                             jnp.where(i < K, pre_val, t32))

        m = jnp.where(total <= maxb, 0, 1 + (c - (maxb + 1 - num)) // 2)
        m = jnp.where(level < base.levels - 1, m, 0)   # top level never merges
        out_pre = q(2 * iota + 1)
        out_npre = jnp.minimum(m, K // 2)
        out_r = m - out_npre
        n_f = total - 2 * m
        new_ts = jnp.where(iota < n_f, q(total - 1 - iota), row_ts)
        return (out_pre, out_npre, out_r), (new_ts, n_f)

    levels = jnp.arange(base.levels, dtype=jnp.int32)
    init = (jnp.zeros((S,), ts0.dtype), jnp.int32(0),
            jnp.asarray(value, jnp.int32))
    _, (ts, num) = lax.scan(level_step, init, (ts0, num0, levels))
    new = EHState(ts=ts, num=num.astype(jnp.int32))
    # value == 0 leaves the state untouched (expiry stays lazy, matching ref).
    return jax.tree.map(
        lambda a, b: jnp.where(jnp.asarray(value) > 0, a, b), new, state)


def sum_eh_query(state: SumEHState, t, cfg: SumEHConfig) -> jax.Array:
    return eh_query(state, t, cfg.base)
