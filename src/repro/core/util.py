"""Small shared helpers for the sketch state machines."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INT32_MAX = jnp.int32(2**31 - 1)


def saturating_add(counter: jax.Array, delta) -> jax.Array:
    """int32 counter + delta, clamped at INT32_MAX instead of wrapping.

    Stream counters (`n_seen`, `t`, `n`) are int32 (int64 needs
    jax_enable_x64); streams longer than 2^31 steps would silently wrap
    negative and corrupt window/normalisation arithmetic downstream, so we
    saturate: the counters stop being exact but stay monotone and positive.
    """
    counter = counter.astype(jnp.int32)
    delta = jnp.asarray(delta, jnp.int32)
    overflow = (delta > 0) & (counter > _INT32_MAX - delta)
    return jnp.where(overflow, _INT32_MAX, counter + delta)
