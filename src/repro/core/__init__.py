"""Core paper algorithms: LSH families, EH, RACE, SW-AKDE, S-ANN, JL."""
from . import eh, fleet, jl, lsh, race, sann, swakde, theory  # noqa: F401
