"""Johnson–Lindenstrauss baseline for streaming (c,r)-ANN (paper §5.1).

The paper's comparison point: project every stream point to ``k`` dims with
a Gaussian JL map and store *all* projected points.  Compression comes from
k < d; query is a brute-force scan in the projected space.  This is the
"only known strict one-pass" baseline the paper measures against.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class JLConfig:
    dim: int
    k: int          # projected dimension
    capacity: int   # max stream points stored


class JLState(NamedTuple):
    proj: jax.Array      # (dim, k) scaled Gaussian map
    store: jax.Array     # (capacity, k) projected points
    n: jax.Array         # () int32


def jl_init(cfg: JLConfig, key: jax.Array) -> JLState:
    proj = jax.random.normal(key, (cfg.dim, cfg.k), jnp.float32) / jnp.sqrt(cfg.k)
    return JLState(
        proj=proj,
        store=jnp.zeros((cfg.capacity, cfg.k), jnp.float32),
        n=jnp.zeros((), jnp.int32),
    )


def jl_insert(state: JLState, x: jax.Array, cfg: JLConfig) -> JLState:
    slot = state.n % cfg.capacity
    return state._replace(store=state.store.at[slot].set(x @ state.proj), n=state.n + 1)


def jl_insert_stream(state: JLState, xs: jax.Array, cfg: JLConfig) -> JLState:
    def step(s, x):
        return jl_insert(s, x, cfg), None
    state, _ = jax.lax.scan(step, state, xs)
    return state


def jl_query(state: JLState, q: jax.Array, cfg: JLConfig, topk: int = 1):
    """Brute scan in projected space; returns (indices, projected distances)."""
    qp = q @ state.proj
    d2 = jnp.sum((state.store - qp) ** 2, axis=-1)
    live = jnp.arange(cfg.capacity) < state.n
    d2 = jnp.where(live, d2, jnp.inf)
    dists, idx = jax.lax.top_k(-d2, topk)
    return idx, jnp.sqrt(-dists)


def jl_query_batch(state: JLState, qs: jax.Array, cfg: JLConfig, topk: int = 1):
    return jax.vmap(lambda q: jl_query(state, q, cfg, topk))(qs)


def jl_bytes(cfg: JLConfig) -> int:
    return cfg.capacity * cfg.k * 4 + cfg.dim * cfg.k * 4
