"""Parameter selection & guarantee formulas from the paper.

Everything here is host-side math (floats, not traced) used by config
builders, benchmarks, and tests that check the implementation against the
paper's own claims.
"""
from __future__ import annotations

import math


def pstable_p(dist: float, w: float) -> float:
    """Collision probability of one p-stable (2-stable) hash at distance
    ``dist`` with bucket width ``w`` [DIIM04]."""
    if dist <= 0:
        return 1.0
    t = w / dist
    phi = 0.5 * (1.0 + math.erf(-t / math.sqrt(2.0)))
    return max(
        0.0,
        min(1.0, 1.0 - 2.0 * phi - (2.0 / (math.sqrt(2.0 * math.pi) * t))
            * (1.0 - math.exp(-(t * t) / 2.0))),
    )


def srp_p(angle: float) -> float:
    """Collision probability of one SRP bit at angle ``angle`` [Cha02]."""
    return 1.0 - angle / math.pi


def rho(p1: float, p2: float) -> float:
    """LSH quality exponent rho = log(1/p1)/log(1/p2) (Thm 2.2)."""
    return math.log(1.0 / p1) / math.log(1.0 / p2)


def choose_k(n: int, p2: float) -> int:
    """Lemma 3.2: k = ceil(log_{1/p2} n) kills far collisions to 1/n."""
    return max(1, math.ceil(math.log(n) / math.log(1.0 / p2)))


def choose_L(n: int, p1: float, p2: float) -> int:
    """Lemma 3.3: L = n^rho / p1 gives constant per-table recall."""
    return max(1, math.ceil(n ** rho(p1, p2) / p1))


def sann_space_words(n: int, eta: float, p1: float, p2: float) -> float:
    """Theorem 3.1 space bound O(n^{1+rho-eta} / p1) in words."""
    return n ** (1.0 + rho(p1, p2) - eta) / p1


def sann_failure_prob(n: int, eta: float, m: float) -> float:
    """Theorem 3.1 failure bound: 1/(3 n^eta) + (e^{mp} + e - 1)/e^{mp+1},
    with p = n^-eta the sampling rate and m the Poisson ball mean."""
    p = n ** (-eta)
    mp = m * p
    return 1.0 / (3.0 * n**eta) + (math.exp(mp) + math.e - 1.0) / math.exp(mp + 1.0)


def turnstile_failure_prob(n: int, eta: float, m: float, d: int) -> float:
    """Theorem 3.3 failure bound with <= d deletions per r-ball."""
    p = n ** (-eta)
    mp = m * p
    if d <= 0:
        tail = math.exp(-mp)
    else:
        if d > mp:  # bound only valid for d <= lambda; clamp conservatively
            tail = 1.0
        else:
            tail = math.exp(d - mp + d * math.log(mp / d))
    return 1.0 / (3.0 * n**eta) + 1.0 / math.e + tail * (1.0 - 1.0 / math.e)


def poisson_tail_le(d: int, lam: float) -> float:
    """Lemma 3.4: P(S <= d) <= exp(d - lam + d ln(lam/d)) for d <= lam."""
    if d == 0:
        return math.exp(-lam)
    return math.exp(d - lam + d * math.log(lam / d))


def swakde_rows(max_x: float, K: float, eps: float, delta: float) -> int:
    """Theorem 4.1: R = O(2 max{X_i}^2 / ((1+eps) K^2) * log(2/delta))."""
    return max(1, math.ceil(2.0 * max_x**2 / ((1.0 + eps) * K**2) * math.log(2.0 / delta)))


def eh_eps_for_kde_eps(eps: float) -> float:
    """Lemma 4.4 inversion: eps = 2 eps' + eps'^2  =>  eps' = sqrt(1+eps) - 1."""
    return math.sqrt(1.0 + eps) - 1.0


def swakde_space_bound(R: int, W: int, eps: float, N: int) -> float:
    """Lemma 4.4: O(R W (1/(sqrt(1+eps)-1)) log^2 N) — reported in 'units'
    of EH buckets * bits."""
    epsp = eh_eps_for_kde_eps(eps)
    return R * W * (1.0 / epsp) * math.log2(max(N, 2)) ** 2
