"""RACE — Repeated Array-of-Counts Estimator [CS20] (paper §2.3).

The sketch is an (L, W) integer counter grid; row i is an ACE [LS18] indexed
by an independent LSH function h_i.  ``E[A[i, h_i(q)]] = sum_x k^p(x, q)``
(Theorem 2.3), so averaging rows estimates the (unnormalised) KDE.

Supports the turnstile model natively: deletions decrement counters.

The scatter-increment hot path has a Pallas kernel
(`repro.kernels.race_update`); this module is the pure-JAX reference and the
pytree/state layer used by the data-pipeline drift monitor.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import lsh
from .util import saturating_add
from repro.kernels import ops as kernel_ops


class RACEState(NamedTuple):
    counts: jax.Array   # (L, W) int32
    n: jax.Array        # () int32 — signed stream size (insertions - deletions),
    #                       saturating at INT32_MAX (core.util.saturating_add)


def race_init(L: int, W: int) -> RACEState:
    """Zero sketch: ``counts (L, W) int32``, ``n () int32``.

    Counters are mergeable histograms — two RACE sketches built over
    different streams with the *same* LSH params combine by addition
    (`race_merge`), which is what the sharded layer
    (`repro.parallel.sketch_sharding`) exploits."""
    return RACEState(counts=jnp.zeros((L, W), jnp.int32), n=jnp.zeros((), jnp.int32))


def race_merge(a: RACEState, b: RACEState) -> RACEState:
    """Combine two sketches built (with identical params) over different
    streams: counters sum exactly, so the merged sketch is bit-identical to
    one sketch fed both streams in any order.  Associative and commutative
    (int32 addition; ``n`` saturates at INT32_MAX like every other path)."""
    return RACEState(counts=a.counts + b.counts, n=saturating_add(a.n, b.n))


def race_update(state: RACEState, params, x: jax.Array, sign: int = 1) -> RACEState:
    """Insert (sign=+1) or delete (sign=-1) one point ``x (d,) float32`` —
    turnstile update.  Per-point reference path; the production path is
    `race_update_batch` (bit-identical counters)."""
    codes = lsh.hash_points(params, x)                       # (L,)
    rows = jnp.arange(codes.shape[0])
    counts = state.counts.at[rows, codes].add(jnp.int32(sign))
    return RACEState(counts=counts, n=saturating_add(state.n, sign))


class RACEPrep(NamedTuple):
    """Pure per-chunk precomputation (the *prepare* phase of the two-phase
    ingest contract, DESIGN.md §10): everything about a chunk that does not
    depend on sketch state, so preparing chunk k+1 can overlap committing
    chunk k."""
    hist: jax.Array    # (L, W) int32 — per-chunk bucket histogram
    count: jax.Array   # () int32 — chunk size B


def race_prepare_chunk(params, xs: jax.Array, n_buckets: int) -> RACEPrep:
    """Prepare phase: hash ``xs (B, d)`` (one matmul) and histogram the codes
    (`repro.kernels.ops.race_hist` — Pallas one-hot-compare on TPU,
    scatter-add oracle on CPU).  State-independent and therefore pure: safe
    to run ahead of (or concurrently with) any number of pending commits."""
    codes = lsh.hash_points(params, xs)                      # (B, L)
    return RACEPrep(hist=kernel_ops.race_hist(codes, n_buckets),
                    count=jnp.int32(xs.shape[0]))


def race_commit_chunk(state: RACEState, prep: RACEPrep,
                      sign: int = 1) -> RACEState:
    """Commit phase: fold a prepared chunk into the counters — the only
    state-sequential part of a RACE update (one dense add)."""
    counts = state.counts + jnp.int32(sign) * prep.hist
    return RACEState(counts=counts,
                     n=saturating_add(state.n, sign * prep.count))


def race_update_batch(state: RACEState, params, xs: jax.Array, sign: int = 1) -> RACEState:
    """Batched turnstile update: xs (B, d), one hash matmul + one histogram.

    Composition of `race_prepare_chunk` and `race_commit_chunk` (the same
    ops, fused under one jit) — counters are bit-identical to B single
    updates."""
    prep = race_prepare_chunk(params, xs, state.counts.shape[1])
    return race_commit_chunk(state, prep, sign)


def estimate_from_vals(vals: jax.Array, median_of_means: int = 0) -> jax.Array:
    """Reduce per-row counter reads ``vals (..., L) float32`` to the RACE
    estimate: mean over rows, or median-of-means with ``median_of_means``
    groups (the [CS20] failure-probability booster).

    Shared by `race_query` and the sharded query path
    (`repro.parallel.sketch_sharding.sharded_race_query_batch`), which
    all-gathers the per-shard rows and then applies this *same* reduction —
    that is what makes the sharded estimate bit-identical to the
    single-device one."""
    if median_of_means and median_of_means > 1:
        g = median_of_means
        L = vals.shape[-1]
        usable = (L // g) * g
        means = vals[..., :usable].reshape(*vals.shape[:-1], g, usable // g).mean(-1)
        return jnp.median(means, axis=-1)
    return vals.mean(-1)


def race_query(state: RACEState, params, q: jax.Array, median_of_means: int = 0) -> jax.Array:
    """Unnormalised KDE estimate at ``q (d,) float32`` → () float32.

    ``E[estimate] = sum_x k^p(x, q)`` (Theorem 2.3): reads one counter per
    row and reduces via `estimate_from_vals`."""
    codes = lsh.hash_points(params, q)                       # (L,)
    vals = state.counts[jnp.arange(codes.shape[-1]), codes].astype(jnp.float32)
    return estimate_from_vals(vals, median_of_means)


def race_row_reads(state: RACEState, params, qs: jax.Array) -> jax.Array:
    """Batched per-row counter reads: ``qs (B, d)`` → (B, L) float32.

    One hash matmul + one gather for the whole batch — the fused read half
    of the query path, shared by `race_query_batch` and the sharded query
    (`repro.parallel.sketch_sharding.sharded_race_query_batch`, which runs
    it per row shard and all-gathers)."""
    codes = lsh.hash_points(params, qs)                      # (B, L)
    L = state.counts.shape[0]
    return state.counts[jnp.arange(L)[None, :], codes].astype(jnp.float32)


def race_query_batch(state: RACEState, params, qs: jax.Array, median_of_means: int = 0):
    """Fused batch queries: ``qs (B, d) float32`` → (B,) float32.

    One hash matmul + one counter gather for the whole batch
    (`race_row_reads`) feeding the same `estimate_from_vals` reduction —
    identical estimates to vmapping `race_query` over the batch."""
    return estimate_from_vals(race_row_reads(state, params, qs),
                              median_of_means)


def race_kde(state: RACEState, params, q: jax.Array, median_of_means: int = 0) -> jax.Array:
    """Normalised density estimate at ``q (d,)``: raw count / stream size."""
    raw = race_query(state, params, q, median_of_means)
    return raw / jnp.maximum(state.n.astype(jnp.float32), 1.0)
