"""Locality-sensitive hash families (paper §2.1).

Two families, exactly the ones the paper uses:
  * SRP-LSH (angular / sign-random-projection) [Cha02]
  * p-stable Euclidean LSH [DIIM04]

Both are expressed as pure-JAX pytrees + functions so they can live inside
`vmap`/`scan`/`pjit`.  Hashing is a matmul (MXU-friendly); the Pallas kernel
`repro.kernels.srp_hash` implements the hot path, and these functions are its
reference semantics (`repro.kernels.ref` re-exports them).

A "hash function" here is always the paper's concatenated hash
``g(x) = (h_1(x), ..., h_k(x))`` folded to a bounded integer range via a
multiply-shift universal hash — the paper's "rehashing" trick (§5.2
*Implementation*).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Golden-ratio multiplicative constant for multiply-shift hashing.
_MIX = np.uint32(2654435761)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SRPParams:
    """L independent concatenations of k signed-random-projection bits."""

    proj: jax.Array      # (d, L*k) float32 — N(0,1) projections
    mix: jax.Array       # (L, k) uint32   — per-bit universal-hash multipliers
    L: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    n_buckets: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PStableParams:
    """L independent concatenations of k p-stable (Euclidean) hashes."""

    proj: jax.Array      # (d, L*k) float32 — N(0,1)
    bias: jax.Array      # (L*k,) float32   — U[0, w)
    mix: jax.Array       # (L, k) uint32
    w: float = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    n_buckets: int = dataclasses.field(metadata=dict(static=True))


def init_srp(key: jax.Array, dim: int, L: int, k: int, n_buckets: int) -> SRPParams:
    kp, km = jax.random.split(key)
    proj = jax.random.normal(kp, (dim, L * k), dtype=jnp.float32)
    mix = jax.random.randint(km, (L, k), 1, 2**31 - 1, dtype=jnp.int32)
    mix = (mix.astype(jnp.uint32) << 1) | jnp.uint32(1)  # odd multipliers
    return SRPParams(proj=proj, mix=mix, L=L, k=k, n_buckets=n_buckets)


def init_pstable(
    key: jax.Array, dim: int, L: int, k: int, w: float, n_buckets: int
) -> PStableParams:
    kp, kb, km = jax.random.split(key, 3)
    proj = jax.random.normal(kp, (dim, L * k), dtype=jnp.float32)
    bias = jax.random.uniform(kb, (L * k,), minval=0.0, maxval=w, dtype=jnp.float32)
    mix = jax.random.randint(km, (L, k), 1, 2**31 - 1, dtype=jnp.int32)
    mix = (mix.astype(jnp.uint32) << 1) | jnp.uint32(1)
    return PStableParams(proj=proj, bias=bias, mix=mix, w=w, L=L, k=k, n_buckets=n_buckets)


def _fold(raw: jax.Array, mix: jax.Array, n_buckets: int) -> jax.Array:
    """Universal multiply-shift fold of (..., L, k) integer hashes → (..., L) buckets."""
    acc = (raw.astype(jnp.uint32) * mix).sum(axis=-1)  # wraps mod 2^32
    acc = acc * _MIX
    return (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


def srp_hash(params: SRPParams, x: jax.Array) -> jax.Array:
    """x: (..., d) → bucket ids (..., L) in [0, n_buckets).

    Each of the L hashes is k sign bits packed to an integer (range 2^k),
    then folded to n_buckets.
    """
    y = x @ params.proj                                  # (..., L*k)
    bits = (y >= 0).astype(jnp.uint32)
    bits = bits.reshape(*x.shape[:-1], params.L, params.k)
    return _fold(bits, params.mix, params.n_buckets)


def pstable_hash(params: PStableParams, x: jax.Array) -> jax.Array:
    """x: (..., d) → bucket ids (..., L) via floor((a.x+b)/w), concatenated k times."""
    y = (x @ params.proj + params.bias) / params.w
    h = jnp.floor(y).astype(jnp.int32)
    h = h.reshape(*x.shape[:-1], params.L, params.k)
    return _fold(h, params.mix, params.n_buckets)


def hash_points(params, x: jax.Array) -> jax.Array:
    if isinstance(params, SRPParams):
        return srp_hash(params, x)
    if isinstance(params, PStableParams):
        return pstable_hash(params, x)
    raise TypeError(type(params))


# ---------------------------------------------------------------------------
# Collision probabilities (analysis-side; used by theory.py and tests)
# ---------------------------------------------------------------------------

def srp_collision_prob(x: jax.Array, y: jax.Array, p: int = 1) -> jax.Array:
    """k(x,y)^p for SRP: (1 - theta/pi)^p  [Cha02]."""
    nx = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    ny = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    cos = jnp.clip((nx * ny).sum(-1), -1.0, 1.0)
    theta = jnp.arccos(cos)
    return (1.0 - theta / jnp.pi) ** p


def pstable_collision_prob(dist, w: float, p: int = 1):
    """k(x,y)^p for 2-stable LSH at Euclidean distance ``dist`` [DIIM04]:

        p(s) = 1 - 2*Phi(-w/s) - (2s/(sqrt(2*pi)*w)) * (1 - exp(-w^2/(2 s^2)))
    """
    dist = jnp.asarray(dist, jnp.float32)
    s = jnp.maximum(dist, 1e-12)
    t = w / s
    phi = 0.5 * (1.0 + jax.lax.erf(-t / jnp.sqrt(2.0)))
    prob = 1.0 - 2.0 * phi - (2.0 / (jnp.sqrt(2.0 * jnp.pi) * t)) * (
        1.0 - jnp.exp(-(t**2) / 2.0)
    )
    prob = jnp.where(dist <= 0.0, 1.0, prob)
    return jnp.clip(prob, 0.0, 1.0) ** p
