"""S-ANN — Streaming (c,r)-Approximate Near Neighbor sketch (paper §3, Alg. 1).

Faithful mechanics:
  * uniform sampling: each arriving point is **kept with probability n^-eta**
    (Fig. 1), so only O(n^{1-eta}) points are stored;
  * L = ceil(n^rho / p1) hash tables, each keyed by a concatenation of
    k = ceil(log_{1/p2} n) p-stable hashes (Lemma 3.2/3.3);
  * query (Fig. 2): union of the L colliding buckets, truncated at **3L
    candidates** (the paper's early-exit budget), return the closest if it is
    within c*r, else NULL;
  * turnstile deletions (§3.4): delete-by-value tombstones;
  * batch queries (§3.3): the fused batch engine (below) — identical
    results to running the per-query pipeline on each element.

Hardware adaptation (DESIGN.md §5.2): pointer-chasing hash buckets become
fixed-capacity **ring buffers** of point ids — `tables (L, n_buckets,
bucket_cap)` — so insertion is a dense scatter and querying is a dense
gather + one distance matmul (`repro.kernels.cand_score`).  The early-exit
("stop at 3L") becomes a post-gather priority truncation: we score the same
<=3L candidates the sequential algorithm would, Lemma 3.2's Markov bound is
unchanged.

Query paths (DESIGN.md §9):
  * ``sann_query`` / ``sann_query_topk`` — per-query reference semantics
    (gather L buckets, truncate at 3L, score via `kernels.cand_score`);
  * ``sann_query_batch`` / ``sann_query_topk_batch`` — the fused batch
    engine: one hash matmul + one table gather for the whole batch,
    batch-wide truncation/dedup, one fused scorer call
    (`kernels.batch_score` on TPU).  Results are identical to the
    per-query path (tests/test_query_batched.py).

Ingest paths:
  * ``sann_insert`` / ``sann_insert_stream`` — the per-point reference
    semantics (Alg. 1 verbatim, one `lax.scan` step per stream element);
  * ``sann_insert_batch`` — the production batched-update contract: one hash
    matmul per chunk, keep decisions from the *same* per-point key schedule,
    slots assigned by a prefix sum over kept points, and the ring-buffer
    appends realised as a sort-by-(row, code) segment scatter.  The final
    state is bit-identical to replaying ``sann_insert`` point by point
    (tests/test_batched_ingest.py), but costs O(1) XLA steps per chunk
    instead of O(chunk).
  * ``sann_prepare_chunk`` / ``sann_commit_chunk`` — the two-phase form of
    the same contract (DESIGN.md §10): prepare is the pure half (keep
    decisions, prefix ranks, hashing, the sorted append structure), commit
    rebases it on the live pointers; ``sann_insert_batch`` is their
    composition, and the serving engine overlaps prepare of chunk k+1 with
    commit of chunk k.

Multi-worker merge (DESIGN.md §11.4): ``sann_merge`` unions two sketches
built over disjoint streams — under the paper's n^-eta uniform sampling a
union of independent samples is exactly a sample of the union stream.
Stored points carry logical arrival stamps (`SANNState.stamps`) so the
merge can interleave the two ring buffers deterministically and re-derive
the hash tables through the same sort-by-(row, code) append structure the
ingest path uses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import lsh, theory
from .util import saturating_add
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class SANNConfig:
    dim: int
    n_max: int             # upper bound on stream size (paper's n)
    eta: float             # sampling exponent: keep prob = n^-eta
    r: float               # near radius
    c: float               # approximation factor (far radius = c*r)
    w: float = 4.0         # p-stable bucket width
    L: Optional[int] = None
    k: Optional[int] = None
    bucket_cap: int = 16
    capacity_slack: float = 4.0

    def resolved(self) -> "SANNConfig":
        p1 = float(theory.pstable_p(self.r, self.w))
        p2 = float(theory.pstable_p(self.c * self.r, self.w))
        k = self.k or max(1, math.ceil(math.log(self.n_max) / math.log(1.0 / p2)))
        rho = math.log(1.0 / p1) / math.log(1.0 / p2)
        L = self.L or max(1, math.ceil(self.n_max**rho / p1))
        return dataclasses.replace(self, L=L, k=k)

    @property
    def keep_prob(self) -> float:
        return self.n_max ** (-self.eta)

    @property
    def capacity(self) -> int:
        """Point-store size: E[stored] = n^{1-eta}, padded by slack for
        concentration (binomial upper tail)."""
        expect = self.n_max ** (1.0 - self.eta)
        return max(64, int(self.capacity_slack * expect))

    @property
    def n_buckets(self) -> int:
        # enough buckets that far points spread out (Lemma 3.2 uses range
        # ~n via k concatenations; after rehash we provision ~4x capacity)
        return max(64, int(4 * self.capacity))


class SANNState(NamedTuple):
    points: jax.Array       # (capacity, dim) float32
    valid: jax.Array        # (capacity,) bool
    write_ptr: jax.Array    # () int32 slot pointer, kept reduced mod capacity
    n_seen: jax.Array       # () int32, saturating (see core.util.saturating_add)
    n_stored: jax.Array     # () int32 — live stored points (== valid.sum())
    tables: jax.Array       # (L, n_buckets, bucket_cap) int32 slot ids, -1 empty
    table_ptr: jax.Array    # (L, n_buckets) int32 cyclic bucket pointers
    stamps: jax.Array       # (capacity,) int32 — logical arrival time of each
    #   stored point (its stream index, = n_seen at arrival; saturating like
    #   n_seen, -1 for never-written slots).  Ingest never reads it; it is
    #   what lets `sann_merge` interleave two disjoint-stream sketches in a
    #   deterministic logical-time order.


def sann_empty_state(cfg: SANNConfig) -> SANNState:
    """Allocate an empty sketch for a *resolved* config (shapes documented
    on `SANNState`)."""
    return SANNState(
        points=jnp.zeros((cfg.capacity, cfg.dim), jnp.float32),
        valid=jnp.zeros((cfg.capacity,), bool),
        write_ptr=jnp.zeros((), jnp.int32),
        n_seen=jnp.zeros((), jnp.int32),
        n_stored=jnp.zeros((), jnp.int32),
        tables=jnp.full((cfg.L, cfg.n_buckets, cfg.bucket_cap), -1, jnp.int32),
        table_ptr=jnp.zeros((cfg.L, cfg.n_buckets), jnp.int32),
        stamps=jnp.full((cfg.capacity,), -1, jnp.int32),
    )


def sann_init(cfg: SANNConfig, key: jax.Array):
    """Resolve the config (derive L, k from n_max/r/c if unset) and allocate
    an empty sketch.

    Returns ``(resolved cfg, lsh.PStableParams, SANNState)`` — state shapes
    are documented on `SANNState`; all ids/counters are int32, points
    float32."""
    cfg = cfg.resolved()
    params = lsh.init_pstable(key, cfg.dim, cfg.L, cfg.k, cfg.w, cfg.n_buckets)
    return cfg, params, sann_empty_state(cfg)


def sann_insert(state: SANNState, params, x: jax.Array, key: jax.Array,
                cfg: SANNConfig) -> SANNState:
    """Sample-and-store one stream point (Alg. 1 insert; Fig. 1)."""
    keep = jax.random.bernoulli(key, cfg.keep_prob)
    slot = state.write_ptr % cfg.capacity
    evict = keep & state.valid[slot]
    # Recycling a live slot invalidates every table entry still pointing at
    # it (the evicted point's buckets) — tombstone them, or queries would
    # score the *new* vector under the *old* point's bucket membership.
    tables = lax.cond(
        evict,
        lambda tb: jnp.where(tb == slot, jnp.int32(-1), tb),
        lambda tb: tb,
        state.tables)

    points = state.points.at[slot].set(jnp.where(keep, x, state.points[slot]))
    valid = state.valid.at[slot].set(jnp.where(keep, True, state.valid[slot]))
    stamps = state.stamps.at[slot].set(
        jnp.where(keep, state.n_seen, state.stamps[slot]))

    codes = lsh.hash_points(params, x)                          # (L,)
    rows = jnp.arange(cfg.L)
    pos = state.table_ptr[rows, codes] % cfg.bucket_cap
    old = tables[rows, codes, pos]
    tables = tables.at[rows, codes, pos].set(
        jnp.where(keep, slot.astype(jnp.int32), old))
    table_ptr = state.table_ptr.at[rows, codes].add(jnp.where(keep, 1, 0))

    return SANNState(
        points=points, valid=valid,
        write_ptr=(state.write_ptr + jnp.where(keep, 1, 0).astype(jnp.int32))
        % cfg.capacity,
        n_seen=saturating_add(state.n_seen, 1),
        n_stored=state.n_stored + jnp.where(keep & ~evict, 1, 0),
        tables=tables, table_ptr=table_ptr, stamps=stamps,
    )


def sann_row_keys(key: jax.Array, n: int) -> jax.Array:
    """Per-point key schedule for a chunk: ``fold_in(key, i)`` for i < n.

    Unlike ``jax.random.split(key, n)`` (whose threefry counters pair
    ``(i, i + n)``, so every key depends on the total ``n``), this schedule
    is **prefix-stable**: ``sann_row_keys(key, m)[:b] == sann_row_keys(key,
    b)`` for b <= m.  That is what lets the tenant-fleet path
    (`core.fleet`) draw keep decisions for a cap-padded tenant block and
    land bit-identical to the unpadded single-sketch chunk."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n, dtype=jnp.int32))


def sann_insert_stream(state: SANNState, params, xs: jax.Array, key: jax.Array,
                       cfg: SANNConfig) -> SANNState:
    """Per-point reference ingest of ``xs (T, d) float32``: one `lax.scan`
    step per element under the per-point key schedule `sann_row_keys`.
    `sann_insert_batch` is the production path and is bit-identical to this
    one under the same key."""
    keys = sann_row_keys(key, xs.shape[0])

    def step(s, xk):
        x, k = xk
        return sann_insert(s, params, x, k, cfg), None

    state, _ = jax.lax.scan(step, state, (xs, keys))
    return state


class SANNPrep(NamedTuple):
    """Pure per-chunk precomputation (the *prepare* phase of the two-phase
    ingest contract, DESIGN.md §10): keep decisions, relative slot ranks,
    hash codes and the sort-by-(row, code) append structure.  Everything
    here depends only on (params, chunk, key) — never on sketch state — so
    preparing chunk k+1 can overlap committing chunk k.  Slot ids and ring
    positions are *relative* (ranks); the commit rebases them on the
    state's write/table pointers."""
    xs: jax.Array          # (B, d) float32 — the chunk (points to store)
    keep: jax.Array        # (B,) bool — Bernoulli keep decisions
    kept_rank: jax.Array   # (B,) int32 — exclusive prefix sum over keep
    n_kept: jax.Array      # () int32
    winner: jax.Array      # (B,) bool — keep & survives intra-chunk ring lap
    s_l: jax.Array         # (B*L,) int32 — sorted append rows
    s_c: jax.Array         # (B*L,) int32 — sorted append codes
    s_b: jax.Array         # (B*L,) int32 — sorted append point index
    rank: jax.Array        # (B*L,) int32 — within-bucket append rank
    entry_win: jax.Array   # (B*L,) bool — append survives the ring cap
    counts: jax.Array      # (L, n_buckets) int32 — per-bucket append counts


def sann_prepare_chunk(params, xs: jax.Array, key: jax.Array,
                       cfg: SANNConfig) -> SANNPrep:
    """Prepare phase for ``xs (B, d)``: the state-independent half of
    `sann_insert_batch` —

      1. one Bernoulli draw per point from the `sann_row_keys` schedule →
         ``keep`` mask, exclusive prefix ranks, and the last-writer-wins
         mask for chunks that lap the ring (``winner``: the kept points
         within one full lap of the chunk's end — a pure function of ranks
         and capacity);
      2. one hash matmul for the whole chunk;
      3. the ring-buffer append structure: flatten (point, row) pairs, sort
         by (row, code) so each bucket's appends are a contiguous run in
         stream order, with per-bucket append counts and the cap-survivor
         mask (``rank >= seg_total - bucket_cap``).
    """
    keys = sann_row_keys(key, xs.shape[0])
    keep = jax.vmap(lambda k: jax.random.bernoulli(k, cfg.keep_prob))(keys)
    return sann_prepare_given_keep(params, xs, keep, cfg)


def sann_prepare_given_keep(params, xs: jax.Array, keep: jax.Array,
                            cfg: SANNConfig,
                            codes: Optional[jax.Array] = None) -> SANNPrep:
    """`sann_prepare_chunk` with the keep mask supplied by the caller
    (everything after the Bernoulli draws: prefix ranks, last-writer mask,
    one hash matmul, the sort-by-(row, code) append structure).

    This is the entry point for arrival sequences whose sampling already
    happened — `sann_merge` feeds it the stamp-interleaved union of two
    sketches' stored points (all pre-sampled, so ``keep`` = their validity
    mask), reusing the exact append/eviction machinery of the ingest path.

    ``codes`` (optional, (B, L) int32) skips the hash matmul — the
    tenant-fleet ingest (`core.fleet`) hashes one mixed multi-tenant chunk
    once and routes per-tenant code blocks here.
    """
    B = xs.shape[0]
    cap = cfg.capacity

    # --- slot ranks: prefix sum over kept points ---------------------------
    kept_rank = (jnp.cumsum(keep) - keep).astype(jnp.int32)  # exclusive
    n_kept = keep.sum().astype(jnp.int32)
    # Last writer per slot wins (matters only when the chunk laps the ring);
    # ranks assign slots round-robin, so the shadowed writers are exactly
    # the kept points more than one full lap from the end.
    winner = keep & (kept_rank >= n_kept - cap)

    # --- ring-buffer appends: sort-by-(row, code) segment structure --------
    if codes is None:
        codes = lsh.hash_points(params, xs)                  # (B, L)
    l_idx = jnp.broadcast_to(jnp.arange(cfg.L, dtype=jnp.int32), (B, cfg.L))
    bucket_key = l_idx * cfg.n_buckets + codes               # (B, L)
    n_flat = B * cfg.L
    n_keys = cfg.L * cfg.n_buckets
    flat_key = bucket_key.reshape(-1)
    idx = jnp.arange(B, dtype=jnp.int32)
    flat_b = jnp.broadcast_to(idx[:, None], (B, cfg.L)).reshape(-1)
    kept_flat = jnp.broadcast_to(keep[:, None], (B, cfg.L)).reshape(-1)
    sentinel = jnp.int32(n_keys)
    masked_key = jnp.where(kept_flat, flat_key, sentinel)
    if (n_keys + 1) * B < 2**31:
        # Pack (bucket key, point id) into one int32 so XLA runs a plain
        # single-operand sort — several times faster on CPU than the
        # variadic (key, iota) sort argsort lowers to.
        packed = jnp.sort(masked_key * B + flat_b)
        s_key = packed // B
        s_b = packed % B
        s_kept = s_key < sentinel
    else:  # key space too large to pack — fall back to a stable argsort
        order = jnp.argsort(masked_key, stable=True)
        s_key = masked_key[order]
        s_b = flat_b[order]
        s_kept = kept_flat[order]
    pos_idx = jnp.arange(n_flat, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), s_key[1:] != s_key[:-1]])
    rank = pos_idx - lax.cummax(jnp.where(seg_start, pos_idx, 0))
    s_l = jnp.minimum(s_key // cfg.n_buckets, cfg.L - 1)     # clamp sentinel
    s_c = s_key % cfg.n_buckets
    # Per-bucket append counts (also the table_ptr advance).  Within a
    # bucket the appends at ring positions r, r+cap, ... shadow each other;
    # the survivors are the last `bucket_cap` ranks.
    counts = jnp.zeros((cfg.L, cfg.n_buckets), jnp.int32).at[
        l_idx, codes].add(kept_flat.reshape(B, cfg.L).astype(jnp.int32))
    seg_total = counts[s_l, s_c]
    entry_win = s_kept & (rank >= seg_total - cfg.bucket_cap)
    return SANNPrep(xs=xs, keep=keep, kept_rank=kept_rank, n_kept=n_kept,
                    winner=winner, s_l=s_l, s_c=s_c, s_b=s_b, rank=rank,
                    entry_win=entry_win, counts=counts)


def sann_commit_chunk(state: SANNState, prep: SANNPrep, cfg: SANNConfig,
                      count: Optional[jax.Array] = None) -> SANNState:
    """Commit phase: rebase a prepared chunk on the state's pointers and
    apply the dense updates — the state-sequential half of
    `sann_insert_batch`:

      1. slots = write_ptr + prepared ranks (mod capacity); point-store and
         valid-mask scatters;
      2. stale table entries pointing at slots recycled this chunk are
         tombstoned in one masked pass (the batched per-insert eviction);
      3. ring-buffer appends land at (table_ptr + prepared rank) % cap via
         one segment scatter; table_ptr advances by the prepared counts.

    ``count`` (optional, traced) overrides the stream-clock advance: the
    chunk counts as ``count`` arrivals instead of its static row count B.
    Used by the tenant-fleet path, whose per-tenant blocks are padded to a
    fixed cap with never-kept rows *after* the real prefix — the pad rows
    write nothing (keep = False ⇒ winner = False) and must not advance
    ``n_seen``, while the real prefix keeps the exact per-row arrival
    stamps of the unpadded chunk.
    """
    B = prep.xs.shape[0]
    cap = cfg.capacity
    slot = (state.write_ptr + prep.kept_rank) % cap          # (B,)
    win_slot = jnp.where(prep.winner, slot, cap)             # OOB → dropped

    points = state.points.at[win_slot].set(prep.xs, mode="drop")
    # Slots recycled this chunk form the ring interval
    # [write_ptr + max(0, n_kept - cap), write_ptr + n_kept).
    ring_off = (jnp.arange(cap, dtype=jnp.int32) - state.write_ptr) % cap
    overwritten = ring_off < prep.n_kept
    valid = state.valid | overwritten

    # --- tombstone stale references to every slot recycled this chunk ------
    stale = (state.tables >= 0) & overwritten[jnp.maximum(state.tables, 0)]
    tables = jnp.where(stale, jnp.int32(-1), state.tables)

    # --- ring-buffer appends: prepared segment scatter ---------------------
    # A loser point's entries are appended then tombstoned by the later
    # overwrite of its slot — net effect: the ring cell holds -1.  Entries
    # are sorted by (row, code), which is what makes this a coalesced
    # per-row write pass (kernels.ops.sann_table_scatter, DESIGN.md §12).
    val = jnp.where(prep.winner[prep.s_b], slot[prep.s_b], jnp.int32(-1))
    tables = kernel_ops.sann_table_scatter(
        tables, state.table_ptr, prep.s_l, prep.s_c, prep.rank, val,
        prep.entry_win)
    table_ptr = state.table_ptr + prep.counts

    # Logical arrival stamps: point i in the chunk arrived at stream time
    # n_seen + i (the same saturating accumulation the per-point path's
    # n_seen chain produces).
    arrival = saturating_add(state.n_seen,
                             jnp.arange(B, dtype=jnp.int32))
    stamps = state.stamps.at[win_slot].set(arrival, mode="drop")

    newly = prep.winner & ~state.valid[jnp.where(prep.winner, slot, 0)]
    return SANNState(
        points=points, valid=valid,
        write_ptr=(state.write_ptr + prep.n_kept) % cap,
        n_seen=saturating_add(state.n_seen, B if count is None else count),
        n_stored=state.n_stored + newly.sum(),
        tables=tables, table_ptr=table_ptr, stamps=stamps,
    )


def sann_insert_batch(state: SANNState, params, xs: jax.Array, key: jax.Array,
                      cfg: SANNConfig) -> SANNState:
    """Batched ingest of a whole chunk ``xs (B, d)`` in O(1) XLA steps.

    Bit-identical to ``sann_insert_stream`` under the same key (the chunk
    shares the per-point `sann_row_keys` schedule).  Composition of
    `sann_prepare_chunk` (keep decisions + hashing + sort-by-(row, code)
    append structure, pure) and `sann_commit_chunk` (pointer rebase + dense
    scatters, sequential) — the same ops, fused under one jit when called
    directly.
    """
    return sann_commit_chunk(state, sann_prepare_chunk(params, xs, key, cfg),
                             cfg)


def sann_insert_chunked(state: SANNState, params, xs: jax.Array,
                        key: jax.Array, cfg: SANNConfig,
                        chunk: int = 1024) -> SANNState:
    """Stream ``xs (T, d)`` through ``sann_insert_batch`` in fixed chunks.

    Equivalent to one big ``sann_insert_batch`` call whose key is split per
    chunk; use when T is too large to hash in one matmul.
    """
    T = xs.shape[0]
    n_full = T // chunk
    n_keys = n_full + (1 if T % chunk else 0)
    ckeys = jax.random.split(key, max(n_keys, 1))
    if n_full:
        def step(s, ck):
            c, k = ck
            return sann_insert_batch(s, params, c, k, cfg), None
        state, _ = lax.scan(
            step, state,
            (xs[: n_full * chunk].reshape(n_full, chunk, -1), ckeys[:n_full]))
    if T % chunk:
        state = sann_insert_batch(state, params, xs[n_full * chunk:],
                                  ckeys[n_full], cfg)
    return state


def sann_merge(a: SANNState, b: SANNState, params, cfg: SANNConfig) -> SANNState:
    """Union of two S-ANN sketches built (with *identical* params and cfg)
    over **disjoint** streams — the multi-worker combine.

    Under the paper's n^-eta uniform sampling, a union of independently
    sampled substreams is exactly a sample of the union stream, so merging
    is semantically just "one sketch that saw both streams".  Mechanically:

      1. **interleave by logical timestamp**: the stored points of both
         sketches are ordered by their arrival stamps (`SANNState.stamps`),
         ties broken a-before-b — a fixed, deterministic interleaving of
         the two streams (for K-way merges, fold left in worker order);
      2. **re-derive the tables**: the interleaved union is replayed as one
         pre-sampled chunk through the existing sort-by-(row, code) append
         structure (`sann_prepare_given_keep` + `sann_commit_chunk` from an
         empty state) — one hash matmul over ≤ 2·capacity points;
      3. **tombstone-consistent eviction**: if the union exceeds
         ``capacity``, the ring keeps the newest ``capacity`` points by
         stamp and the evicted (oldest) points' table entries come out as
         -1 — exactly the eviction rule of the ingest path.

    Counters combine saturating (``n_seen``, like every ingest path);
    ``n_stored``/``write_ptr``/``valid`` are re-derived from the union.
    When neither input has ever evicted or wrapped, the merged sketch is
    bit-identical (modulo ``stamps``, which keep their per-stream clocks)
    to a single sketch fed the interleaved stream with the same keep
    decisions (tests/test_cluster.py); with eviction, the *live point set*
    and query answers still match, but slot ids rotate by the evicted
    count.  Associative at the stored-set level: the newest-``capacity``
    rule commutes with folding (tests/test_distributed.py).
    """
    cap = cfg.capacity
    pts = jnp.concatenate([a.points, b.points])              # (2*cap, d)
    valid = jnp.concatenate([a.valid, b.valid])
    stamps = jnp.concatenate([a.stamps, b.stamps])
    # Stable order: valid entries first, by ascending stamp; ties keep input
    # order (a's entries, then b's — and slot order within one sketch, which
    # is arrival order between two stamps of the same value post-saturation).
    order = jnp.lexsort((stamps, ~valid))
    xs = pts[order]
    keep = valid[order]
    st_sorted = stamps[order]

    prep = sann_prepare_given_keep(params, xs, keep, cfg)
    merged = sann_commit_chunk(sann_empty_state(cfg), prep, cfg)
    # The commit stamped slots with their union-chunk offsets; restore the
    # true per-stream arrival stamps so later merges interleave correctly.
    slot = prep.kept_rank % cap
    win_slot = jnp.where(prep.winner, slot, cap)
    return merged._replace(
        stamps=merged.stamps.at[win_slot].set(st_sorted, mode="drop"),
        n_seen=saturating_add(a.n_seen, b.n_seen),
    )


def sann_delete(state: SANNState, params, x: jax.Array, cfg: SANNConfig,
                tol: float = 1e-5) -> SANNState:
    """Turnstile delete-by-value (§3.4): tombstone every stored copy of x."""
    d2 = jnp.sum((state.points - x) ** 2, axis=-1)
    hit = state.valid & (d2 <= tol)
    valid = state.valid & ~hit
    # Tombstone table entries pointing at deleted slots.
    dead = hit[jnp.maximum(state.tables, 0)] & (state.tables >= 0)
    tables = jnp.where(dead, -1, state.tables)
    return state._replace(valid=valid, tables=tables,
                          n_stored=state.n_stored - hit.sum())


class SANNResult(NamedTuple):
    index: jax.Array      # slot id of returned point (-1 = NULL)
    distance: jax.Array   # distance to returned point (inf = NULL)
    found: jax.Array      # bool — success per the (c,r) contract
    n_candidates: jax.Array


def sann_bucket_candidates(state: SANNState, params, q: jax.Array,
                           cfg: SANNConfig):
    """Gather the colliding buckets for ``q (d,) float32``.

    Returns ``(cand, ok)``: candidate slot ids ``(cfg.L * bucket_cap,)
    int32`` in row-major table order and their validity mask (entry is a
    live stored point).  Split out from `sann_query` so the table-sharded
    path (`repro.parallel.sketch_sharding`) can gather per-shard candidate
    blocks and concatenate them — shard-order concatenation reproduces this
    exact row-major order."""
    codes = lsh.hash_points(params, q)                          # (L,)
    rows = jnp.arange(cfg.L)
    cand = state.tables[rows, codes].reshape(-1)                # (L*bucket_cap,)
    ok = (cand >= 0) & state.valid[jnp.maximum(cand, 0)]
    return cand, ok


def sann_score_candidates(points: jax.Array, cand: jax.Array, ok: jax.Array,
                          q: jax.Array, budget: int,
                          cfg: SANNConfig) -> SANNResult:
    """Truncate-and-score: keep the first ``budget`` valid candidates (the
    paper's 3L early-exit), score via `repro.kernels.cand_score`, return the
    argmin if within c*r (Fig. 2).

    ``points (capacity, d) float32`` is the (replicated, in the sharded
    case) point store; ``cand/ok`` come from `sann_bucket_candidates`."""
    # Truncate to the budget: stable-sort invalid entries last, keep the
    # first ``budget``.
    order = jnp.argsort(jnp.where(ok, 0, 1), stable=True)
    sel = order[:budget]
    cand, ok = cand[sel], ok[sel]
    vecs = points[jnp.maximum(cand, 0)]                         # (budget, dim)
    d2 = kernel_ops.cand_score(q, vecs)                         # (budget,)
    d2 = jnp.where(ok, d2, jnp.inf)
    best = jnp.argmin(d2)
    dist = jnp.sqrt(d2[best])
    found = dist <= cfg.c * cfg.r
    return SANNResult(
        index=jnp.where(found, cand[best], -1),
        distance=jnp.where(found, dist, jnp.inf),
        found=found,
        n_candidates=ok.sum(),
    )


def sann_query(state: SANNState, params, q: jax.Array, cfg: SANNConfig) -> SANNResult:
    """Alg. 1 query: gather L buckets, truncate to 3L candidates, score,
    return argmin if within c*r (Fig. 2).

    ``q (d,) float32`` → `SANNResult` of scalars (index -1 / distance inf
    encode the paper's NULL answer)."""
    cand, ok = sann_bucket_candidates(state, params, q, cfg)
    return sann_score_candidates(state.points, cand, ok, q, 3 * cfg.L, cfg)


def sann_bucket_candidates_batch(state: SANNState, params, qs: jax.Array,
                                 cfg: SANNConfig):
    """Batched bucket gather: ``qs (B, d)`` → ``(cand (B, L*bucket_cap)
    int32, ok (B, L*bucket_cap) bool)``.

    One hash matmul + one table gather for the whole batch; per query the
    candidate order is the same row-major table order as
    `sann_bucket_candidates`, so the table-sharded path can all-gather
    per-shard blocks along axis 1 and reproduce the single-device order."""
    codes = lsh.hash_points(params, qs)                         # (B, L)
    cand = state.tables[jnp.arange(cfg.L)[None, :], codes]      # (B, L, cap)
    # explicit flat shape (not -1): keeps B = 0 batches reshapeable
    cand = cand.reshape(qs.shape[0], cfg.L * cfg.bucket_cap)
    ok = (cand >= 0) & state.valid[jnp.maximum(cand, 0)]
    return cand, ok


def sann_score_candidates_batch(points: jax.Array, cand: jax.Array,
                                ok: jax.Array, qs: jax.Array, budget: int,
                                cfg: SANNConfig) -> SANNResult:
    """Batched truncate-and-score: the whole-batch form of
    `sann_score_candidates`, returning a `SANNResult` with (B,) fields.

    The per-query stable argsort that implemented the 3L truncation is
    replaced by a masked cumulative-count keep rule evaluated batch-wide:
    a candidate is kept iff it is valid and fewer than ``budget`` valid
    candidates precede it in its row.  The keep positions are *located*
    (rather than sorted into place) by a binary search on the row's
    running valid count — ``searchsorted(cumsum(ok), j+1)`` is the column
    of the j-th valid candidate — which costs O(B·budget·log C) instead of
    the O(B·C·log C) sort (and avoids XLA/CPU's slow scatter and top_k;
    see the ingest-side precedent in `sann_insert_batch`).  The compacted
    ``(B, budget)`` block preserves table order, so scoring it with the
    fused batch scorer (`repro.kernels.ops.batch_score_topk`, k = 1 ⇒
    masked argmin) returns results identical to vmapping
    `sann_score_candidates` (tests/test_query_batched.py)."""
    C = cand.shape[1]
    budget_eff = min(budget, C)
    csum = jnp.cumsum(ok, axis=1).astype(jnp.int32)         # running count
    targets = jnp.arange(1, budget_eff + 1, dtype=jnp.int32)
    sel = jax.vmap(lambda a: jnp.searchsorted(a, targets, side="left"))(csum)
    sel_ok = sel < C                   # j-th valid exists ⇔ search stayed in
    sel = jnp.minimum(sel, C - 1)
    sel_cand = jnp.where(sel_ok, jnp.take_along_axis(cand, sel, axis=1), -1)
    vecs = points[jnp.maximum(sel_cand, 0)]                 # (B, budget, dim)
    d2, idx = kernel_ops.batch_score_topk(qs, vecs, sel_ok, 1)  # (B, 1) each
    dist = jnp.sqrt(d2[:, 0])
    found = dist <= cfg.c * cfg.r
    best = jnp.take_along_axis(sel_cand, idx, axis=1)[:, 0]
    return SANNResult(
        index=jnp.where(found, best, -1),
        distance=jnp.where(found, dist, jnp.inf),
        found=found,
        n_candidates=jnp.minimum(csum[:, -1], budget).astype(jnp.int32),
    )


def sann_query_batch(state: SANNState, params, qs: jax.Array, cfg: SANNConfig) -> SANNResult:
    """Batch queries (§3.3 / Corollary 3.2) — fused batch-level pipeline.

    ``qs (B, d) float32`` → `SANNResult` with (B,) fields.  One hash matmul
    and one table gather cover the whole batch
    (`sann_bucket_candidates_batch`), the 3L truncation is a batch-wide
    keep-mask, and scoring is one fused kernel call
    (`sann_score_candidates_batch`) — results identical to vmapping
    `sann_query` per query (which remains the oracle path)."""
    cand, ok = sann_bucket_candidates_batch(state, params, qs, cfg)
    return sann_score_candidates_batch(state.points, cand, ok, qs,
                                       3 * cfg.L, cfg)


def sann_bytes(cfg: SANNConfig) -> int:
    """Concrete sketch footprint for the Fig.-5 memory-scaling benchmark."""
    cfg = cfg.resolved()
    # points + valid + arrival stamps
    pts = cfg.capacity * cfg.dim * 4 + cfg.capacity + cfg.capacity * 4
    tbl = cfg.L * cfg.n_buckets * (cfg.bucket_cap + 1) * 4
    return pts + tbl


def sann_query_topk(state: SANNState, params, q: jax.Array, cfg: SANNConfig,
                    topk: int = 50):
    """Top-k variant for recall benchmarks (no 3L truncation, no (c,r)
    contract): score the full bucket union, dedup repeated slot ids, return
    the k closest.

    ``q (d,) float32`` → ``(ids (k,) int32, dists (k,) float32)`` with
    ``k = min(topk, cfg.L * bucket_cap)``, sorted by ascending distance and
    padded with id -1 / distance inf when fewer than k live candidates
    collide.  The table-sharded path merges per-shard results of this
    function (`sketch_sharding.sharded_sann_query_topk_batch`): the global
    top-k is contained in the union of per-shard top-ks, so the merge is
    exact."""
    codes = lsh.hash_points(params, q)
    rows = jnp.arange(cfg.L)
    cand = state.tables[rows, codes].reshape(-1)
    ok = (cand >= 0) & state.valid[jnp.maximum(cand, 0)]
    vecs = state.points[jnp.maximum(cand, 0)]
    d2 = jnp.where(ok, kernel_ops.cand_score(q, vecs), jnp.inf)
    # dedup identical slots: keep first occurrence
    sort_idx = jnp.argsort(cand)
    sorted_c = cand[sort_idx]
    dup = jnp.concatenate([jnp.zeros((1,), bool),
                           sorted_c[1:] == sorted_c[:-1]])
    dedup_mask = jnp.zeros_like(ok).at[sort_idx].set(~dup)
    d2 = jnp.where(dedup_mask, d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, min(topk, d2.shape[0]))
    ids = jnp.where(jnp.isfinite(-neg), cand[idx], -1)
    return ids, jnp.sqrt(-neg)


def _first_occurrence_mask(cand: jax.Array, capacity: int) -> jax.Array:
    """Batch-wide duplicate-slot mask: True at the first occurrence (lowest
    column) of each slot id per row of ``cand (B, C)`` — the batched form of
    the per-query sort-and-compare dedup in `sann_query_topk`, identical
    masks.

    When the slot-id range is small enough, first occurrences come from a
    scatter-min of column positions keyed by slot id — O(B·(C + capacity))
    and no sort.  Otherwise one batched stable argsort along the candidate
    axis marks duplicates exactly like the per-query path."""
    B, C = cand.shape
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    if capacity + 1 <= max(4096, 8 * C):
        # cand ∈ [-1, capacity) → key range [0, capacity]; -1 gets its own key
        first = jnp.full((B, capacity + 1), C, jnp.int32).at[
            rows, cand + 1].min(pos)
        return first[rows, cand + 1] == pos
    order = jnp.argsort(cand, axis=1)                      # stable
    sorted_c = jnp.take_along_axis(cand, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((B, 1), bool), sorted_c[:, 1:] == sorted_c[:, :-1]], axis=1)
    return jnp.zeros_like(dup).at[rows, order].set(~dup)


def sann_query_topk_batch(state, params, qs, cfg: SANNConfig, topk: int = 50):
    """Batched `sann_query_topk`: ``qs (B, d)`` → ``(ids (B, k), dists
    (B, k))`` with the same padding/ordering contract.

    Fused batch-level pipeline: one hash matmul + one gather for all B
    queries, batch-wide duplicate-slot dedup (`_first_occurrence_mask`),
    and one fused masked top-k scorer call — identical outputs to vmapping
    `sann_query_topk` per query (which remains the oracle path)."""
    cand, ok = sann_bucket_candidates_batch(state, params, qs, cfg)
    mask = ok & _first_occurrence_mask(cand, state.points.shape[0])
    vecs = state.points[jnp.maximum(cand, 0)]              # (B, C, dim)
    k = min(topk, cand.shape[1])
    d2, idx = kernel_ops.batch_score_topk(qs, vecs, mask, k)   # (B, k) each
    ids = jnp.where(jnp.isfinite(d2), jnp.take_along_axis(cand, idx, axis=1),
                    -1)
    return ids, jnp.sqrt(d2)
