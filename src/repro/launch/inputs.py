"""Model inputs: concrete batches (tests/examples) and ShapeDtypeStruct
stand-ins (multi-pod dry-run — weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import VIT_DIM


def batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Logical input shapes/dtypes for one train/prefill step."""
    shapes = {}
    if cfg.family == "vlm":
        text = seq - cfg.n_patches
        assert text > 0, (seq, cfg.n_patches)
        shapes["tokens"] = ((batch, text), jnp.int32)
        shapes["patches"] = ((batch, cfg.n_patches, VIT_DIM), jnp.bfloat16)
    elif cfg.family == "encdec":
        shapes["tokens"] = ((batch, seq), jnp.int32)
        shapes["frames"] = ((batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    else:
        shapes["tokens"] = ((batch, seq), jnp.int32)
    return shapes


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no device allocation)."""
    return {
        k: jax.ShapeDtypeStruct(shp, dt)
        for k, (shp, dt) in batch_shapes(cfg, shape.global_batch, shape.seq_len).items()
    }


def make_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array) -> dict:
    """Concrete random batch (smoke tests, examples, benchmarks)."""
    out = {}
    for name, (shp, dt) in batch_shapes(cfg, batch, seq).items():
        key, sub = jax.random.split(key)
        if dt == jnp.int32:
            out[name] = jax.random.randint(sub, shp, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = (0.02 * jax.random.normal(sub, shp)).astype(dt)
    return out
