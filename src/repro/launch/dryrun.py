import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ALL_SHAPES, ModelConfig
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.optim.adam import adam8bit, adamw
from repro.parallel import param_specs
from repro.parallel.sharding import make_ctx
from repro.roofline import hlo_cost
from repro.serve import kv_cache, serve_step as serve_lib
from repro.train.train_step import make_train_step

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPES = {s.name: s for s in ALL_SHAPES}

# v5e hardware constants (ROOFLINE ANALYSIS section of the assignment)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s/link


def _optimizer_for(cfg: ModelConfig):
    # 671B needs 8-bit moments to fit 512 x 16GB (DESIGN.md §4)
    if cfg.param_count() > 100e9:
        return adam8bit(), "adam8bit"
    return adamw(), "adamw"


def _model_flops(cfg: ModelConfig, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch  # decode: one token / sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False}
    ok_shape, why = registry.shape_applicable(cfg, shape)
    if not ok_shape:
        rec.update(skipped=True, reason=why, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    if shape.kind == "train":
        opt, opt_name = _optimizer_for(cfg)
        rec["optimizer"] = opt_name
        train_step, init_state = make_train_step(cfg, opt, mesh)
        state_shapes = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = param_specs.param_shardings(state_shapes.params, mesh,
                                           fsdp_over_pod=cfg.fsdp_over_pod)
        o_sh = param_specs.opt_state_shardings(state_shapes.opt_state, p_sh, mesh)
        state_sh = type(state_shapes)(params=p_sh, opt_state=o_sh,
                                      step=NamedSharding(mesh, P()))
        batch_shapes = input_specs(cfg, shape)
        b_sh = param_specs.batch_shardings(batch_shapes, mesh)
        metric_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            jax.eval_shape(train_step, state_shapes, batch_shapes)[1])
        step = jax.jit(train_step, in_shardings=(state_sh, b_sh),
                       out_shardings=(state_sh, metric_sh),
                       donate_argnums=(0,))
        lowered = step.lower(state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        ctx = make_ctx(mesh)
        params_shapes = jax.eval_shape(
            lambda k: model_lib.init_model(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = param_specs.param_shardings(params_shapes, mesh,
                                           fsdp_over_pod=cfg.fsdp_over_pod)
        batch_shapes = input_specs(cfg, shape)
        b_sh = param_specs.batch_shardings(batch_shapes, mesh)

        def prefill(params, batch):
            logits, _ = model_lib.forward(params, cfg, batch, ctx)
            return logits

        lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(
            params_shapes, batch_shapes)
    else:  # decode
        sketch = cfg.local_window > 0 and shape.name == "long_500k"
        rec["sketch_attn"] = sketch
        B, S = shape.global_batch, shape.seq_len
        params_shapes = jax.eval_shape(
            lambda k: model_lib.init_model(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = param_specs.param_shardings(params_shapes, mesh,
                                           fsdp_over_pod=cfg.fsdp_over_pod)
        cache_shapes = jax.eval_shape(
            lambda: kv_cache.init_cache(cfg, B, S, mesh=None, sketch=sketch))
        c_sh = kv_cache.cache_specs(cache_shapes, cfg, B, mesh)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        ba, _ = kv_cache.cache_axes(cfg, B, mesh)
        t_sh = NamedSharding(mesh, P(ba, None))
        step_fn = serve_lib.make_serve_step(cfg, mesh, sketch=sketch)
        logit_sh = NamedSharding(
            mesh, P(ba, None, "model" if cfg.vocab % mesh.shape["model"] == 0
                    else None))
        step = jax.jit(step_fn, in_shardings=(p_sh, c_sh, t_sh),
                       out_shardings=(logit_sh, c_sh),
                       donate_argnums=(1,))
        lowered = step.lower(params_shapes, cache_shapes, tok)

    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    rec["hbm_per_device_gb"] = round(
        (ma.argument_size_in_bytes + ma.output_size_in_bytes
         + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3)
    ca = compiled.cost_analysis() or {}
    rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}
    t0 = time.time()
    hc = hlo_cost.analyze(compiled.as_text())
    rec["hlo_parse_s"] = round(time.time() - t0, 2)
    rec["hlo"] = {"flops_per_device": hc["flops"],
                  "hbm_bytes_per_device": hc["hbm_bytes"],
                  "collectives_per_device": hc["collectives"],
                  "collective_bytes_per_device": hc["collective_bytes"]}

    # roofline terms (seconds) — single-chip rates, per-device costs
    comp_t = hc["flops"] / PEAK_FLOPS
    mem_t = hc["hbm_bytes"] / HBM_BW
    coll_t = hc["collective_bytes"] / ICI_BW
    mf = _model_flops(cfg, shape)
    rec["roofline"] = {
        "compute_s": comp_t, "memory_s": mem_t, "collective_s": coll_t,
        "dominant": max((("compute", comp_t), ("memory", mem_t),
                         ("collective", coll_t)), key=lambda kv: kv[1])[0],
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(hc["flops"] * n_chips, 1.0),
    }
    rec["n_chips"] = int(n_chips)
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        # each cell in a fresh subprocess (isolated XLA state / failures)
        fails = []
        for arch in registry.ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    name = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                    fp = out_dir / f"{name}.json"
                    if fp.exists() and json.loads(fp.read_text()).get("ok"):
                        print(f"[skip cached] {name}", flush=True)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out", str(out_dir)]
                    if mp:
                        cmd.append("--multipod")
                    print(f"[run] {name}", flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        fails.append(name)
                        fp.write_text(json.dumps(
                            {"arch": arch, "shape": shape,
                             "mesh": "pod2x16x16" if mp else "pod16x16",
                             "ok": False, "error": r.stderr[-3000:]}, indent=1))
                        print(f"  FAILED: {r.stderr.splitlines()[-1] if r.stderr else '?'}",
                              flush=True)
        print("failures:", fails)
        sys.exit(1 if fails else 0)

    rec = run_cell(args.arch, args.shape, args.multipod, out_dir)
    name = f"{args.arch}__{args.shape}__{'mp' if args.multipod else 'sp'}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in rec
                      if k not in ("hlo", "memory", "xla_cost")}, indent=1))


if __name__ == "__main__":
    main()
