"""LSH family invariants (paper §2.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh, theory


def test_srp_range_and_determinism():
    key = jax.random.PRNGKey(0)
    p = lsh.init_srp(key, 64, L=6, k=5, n_buckets=97)
    x = jax.random.normal(jax.random.PRNGKey(1), (50, 64))
    c1 = lsh.srp_hash(p, x)
    c2 = lsh.srp_hash(p, x)
    assert c1.shape == (50, 6)
    assert (c1 == c2).all()
    assert int(c1.min()) >= 0 and int(c1.max()) < 97


def test_pstable_range():
    key = jax.random.PRNGKey(0)
    p = lsh.init_pstable(key, 64, L=6, k=3, w=4.0, n_buckets=101)
    x = jax.random.normal(jax.random.PRNGKey(1), (50, 64))
    c = lsh.pstable_hash(p, x)
    assert c.shape == (50, 6)
    assert int(c.min()) >= 0 and int(c.max()) < 101


def test_srp_collision_monotone_in_similarity():
    """Definition 2.1: closer pairs must collide more often (p1 > p2)."""
    key = jax.random.PRNGKey(2)
    d = 32
    # L acts as repetition count for the empirical rate.
    p = lsh.init_srp(key, d, L=512, k=1, n_buckets=2**20)
    base = jax.random.normal(jax.random.PRNGKey(3), (d,))
    near = base + 0.1 * jax.random.normal(jax.random.PRNGKey(4), (d,))
    far = jax.random.normal(jax.random.PRNGKey(5), (d,))
    cb, cn, cf = (lsh.srp_hash(p, v[None])[0] for v in (base, near, far))
    rate_near = float((cb == cn).mean())
    rate_far = float((cb == cf).mean())
    assert rate_near > rate_far + 0.2


def test_srp_collision_prob_matches_charikar():
    """Empirical single-bit collision rate ~ 1 - theta/pi [Cha02]."""
    key = jax.random.PRNGKey(6)
    d = 48
    p = lsh.init_srp(key, d, L=4096, k=1, n_buckets=2**20)
    a = jax.random.normal(jax.random.PRNGKey(7), (d,))
    b = a + 0.7 * jax.random.normal(jax.random.PRNGKey(8), (d,))
    emp = float((lsh.srp_hash(p, a[None])[0] == lsh.srp_hash(p, b[None])[0]).mean())
    pred = float(lsh.srp_collision_prob(a, b, p=1))
    # Folding to n_buckets adds ~2^-20 extra collisions — negligible.
    assert abs(emp - pred) < 0.05, (emp, pred)


def test_pstable_collision_prob_matches_diim():
    """Empirical p-stable collision rate vs the [DIIM04] closed form."""
    key = jax.random.PRNGKey(9)
    d, w = 32, 4.0
    p = lsh.init_pstable(key, d, L=4096, k=1, w=w, n_buckets=2**20)
    a = jax.random.normal(jax.random.PRNGKey(10), (d,))
    for scale in (0.5, 2.0, 6.0):
        off = jax.random.normal(jax.random.PRNGKey(11), (d,))
        b = a + scale * off / jnp.linalg.norm(off)
        emp = float((lsh.pstable_hash(p, a[None])[0] == lsh.pstable_hash(p, b[None])[0]).mean())
        pred = theory.pstable_p(scale, w)
        assert abs(emp - pred) < 0.05, (scale, emp, pred)


def test_fold_spreads_buckets():
    """The universal fold must not collapse the bucket space."""
    key = jax.random.PRNGKey(12)
    p = lsh.init_srp(key, 32, L=1, k=12, n_buckets=64)
    x = jax.random.normal(jax.random.PRNGKey(13), (4096, 32))
    c = np.asarray(lsh.srp_hash(p, x)[:, 0])
    counts = np.bincount(c, minlength=64)
    # every bucket population within 5x of uniform
    assert counts.max() < 5 * (4096 / 64)


def test_theory_params_consistent():
    p1, p2 = 0.8, 0.3
    rho = theory.rho(p1, p2)
    assert 0 < rho < 1
    n = 10_000
    k = theory.choose_k(n, p2)
    assert p2**k <= 1.0 / n + 1e-12
    L = theory.choose_L(n, p1, p2)
    assert L >= n**rho / p1 - 1
    # Theorem 3.1 failure prob < 1 for m >= C n^eta with large C
    eta = 0.5
    m = 10 * n**eta
    assert theory.sann_failure_prob(n, eta, m) < 1.0
    # and decreasing in m
    assert theory.sann_failure_prob(n, eta, 2 * m) < theory.sann_failure_prob(n, eta, m)
