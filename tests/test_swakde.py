"""SW-AKDE end-to-end guarantees (paper §4, Theorem 4.1 / Lemma 4.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh, race, swakde


def _exact_window_counts(params, window_pts, q):
    """Ground truth: per-row count of window points colliding with q."""
    codes_q = lsh.hash_points(params, q)                    # (L,)
    codes_x = lsh.hash_points(params, window_pts)           # (T, L)
    return (codes_x == codes_q[None, :]).sum(axis=0)        # (L,)


def test_swakde_matches_exact_window_count_within_eps():
    """The SW-AKDE estimator must track the exact in-window collision count
    to the EH error eps' (Lemma 4.1/4.2 with X_i known exactly)."""
    key = jax.random.PRNGKey(0)
    d, L, W, N = 12, 8, 64, 100
    eps = 0.1
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=N, eh_eps=eps)
    params = lsh.init_srp(key, d, L=L, k=2, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(1), (250, d))
    state = swakde.swakde_init(cfg)
    state = swakde.swakde_stream(state, params, xs, cfg)

    q = xs[-5]
    est = float(swakde.swakde_query(state, params, q, cfg))
    exact_rows = np.asarray(_exact_window_counts(params, xs[-N:], q))
    exact = exact_rows.mean()
    assert abs(est - exact) <= eps * exact + 1.0, (est, exact)


def test_swakde_full_window_equals_race():
    """With stream length <= N nothing expires: SW-AKDE == plain RACE (the
    paper's Fig. 11 comparison in the degenerate regime)."""
    key = jax.random.PRNGKey(2)
    d, L, W = 8, 6, 32
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=500, eh_eps=0.1)
    params = lsh.init_srp(key, d, L=L, k=2, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(3), (80, d))

    sw = swakde.swakde_stream(swakde.swakde_init(cfg), params, xs, cfg)
    rc = race.race_update_batch(race.race_init(L, W), params, xs)

    for i in (0, 17, 42):
        q = xs[i]
        est_sw = float(swakde.swakde_query(sw, params, q, cfg))
        est_rc = float(race.race_query(rc, params, q))
        assert abs(est_sw - est_rc) <= 0.1 * est_rc + 1.0, (i, est_sw, est_rc)


def test_swakde_expiry_shifts_density():
    """Distribution drift: after the window slides past cluster A, density at
    A must fall and density at B must rise (the paper's motivating use)."""
    key = jax.random.PRNGKey(4)
    d, L, W, N = 8, 8, 64, 60
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=N, eh_eps=0.1)
    params = lsh.init_srp(key, d, L=L, k=3, n_buckets=W)
    mu_a = jnp.full((d,), 4.0)
    mu_b = -mu_a
    a = mu_a + 0.3 * jax.random.normal(jax.random.PRNGKey(5), (100, d))
    b = mu_b + 0.3 * jax.random.normal(jax.random.PRNGKey(6), (100, d))

    state = swakde.swakde_init(cfg)
    state = swakde.swakde_stream(state, params, jnp.concatenate([a, b]), cfg)
    da = float(swakde.swakde_query(state, params, mu_a, cfg))
    db = float(swakde.swakde_query(state, params, mu_b, cfg))
    assert db > 10 * max(da, 0.1), (da, db)


def test_swakde_batch_query_matches_single():
    key = jax.random.PRNGKey(7)
    d, L, W = 8, 4, 32
    cfg = swakde.SWAKDEConfig(L=L, W=W, window=40, eh_eps=0.2)
    params = lsh.init_srp(key, d, L=L, k=2, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(8), (50, d))
    state = swakde.swakde_stream(swakde.swakde_init(cfg), params, xs, cfg)
    qs = xs[:4]
    batch = np.asarray(swakde.swakde_query_batch(state, params, qs, cfg))
    single = np.asarray([float(swakde.swakde_query(state, params, q, cfg)) for q in qs])
    np.testing.assert_allclose(batch, single, rtol=1e-6)


def test_batch_swakde_window_semantics():
    """Corollary 4.2: window counts the last N *batches*."""
    key = jax.random.PRNGKey(9)
    d, L, W, R = 8, 4, 32, 8
    cfg = swakde.BatchSWAKDEConfig(L=L, W=W, window=5, eh_eps=0.2, batch_size=R)
    params = lsh.init_srp(key, d, L=L, k=2, n_buckets=W)
    mu = jnp.full((d,), 3.0)
    near = mu + 0.2 * jax.random.normal(jax.random.PRNGKey(10), (5 * R, d))
    far = -mu + 0.2 * jax.random.normal(jax.random.PRNGKey(11), (10 * R, d))

    st = swakde.batch_swakde_init(cfg)
    for i in range(5):
        st = swakde.batch_swakde_update(st, params, near[i * R:(i + 1) * R], cfg)
    dens_in = float(swakde.batch_swakde_query(st, params, mu, cfg))
    for i in range(10):  # push the near batches out of the window
        st = swakde.batch_swakde_update(st, params, far[i * R:(i + 1) * R], cfg)
    dens_out = float(swakde.batch_swakde_query(st, params, mu, cfg))
    assert dens_in > 5 * max(dens_out, 0.2), (dens_in, dens_out)


def test_swakde_space_formula():
    cfg = swakde.SWAKDEConfig(L=16, W=128, window=450, eh_eps=0.1)
    assert swakde.swakde_bytes(cfg) > 0
    # Lemma 4.3 relation between KDE eps and EH eps'
    assert abs(cfg.kde_eps - (2 * 0.1 + 0.1**2)) < 1e-9
