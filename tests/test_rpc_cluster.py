"""Network cluster (repro.net, DESIGN.md §16): multi-PROCESS sketch
workers behind the RPC front door, pinned bit-exact against the
in-process PR-5 cluster oracle:

  * for all three sketches, an RPC cluster (each worker its own spawned
    process + engine + WAL) answers ingest/query/delete exactly like the
    in-process `Cluster*Service` over the same stream;
  * a durable RPC cluster recovers bit-identically across a full
    stop/start of every worker process;
  * seeded chaos: SIGKILLing a worker process mid-stream drives the PR-8
    failover path — the coordinator respawns the process and
    `recover()`s it from its WAL (bit-exact), or, with respawn disabled,
    declares it DEAD and re-partitions its WAL tail to the survivors
    (RACE stays bit-identical to a single engine under any routing);
  * injected transient network faults (``net.send`` drop) retry in place
    with no recovery and identical state;
  * lifecycle hygiene: a constructor failing mid-startup reaps every
    already-spawned worker process (no orphan PIDs), and a query against
    a wedged worker resolves the batched-query future with an error
    instead of leaking it.

Every test here spawns subprocesses (seconds of jax startup each on the
1-core dev shape), so the file is deselected from tier-1 and runs in the
CI ``rpc-cluster`` job — mirror of how test_distributed.py is handled.
"""
import multiprocessing
import os
import signal
import time

import numpy as np
import pytest

from repro import persist
from repro.net import (RPCClusterKDEService, RPCClusterRACEService,
                       RPCClusterRetrievalService, RPCConfig)
from repro.net import protocol as P
from repro.persist import faults
from repro.serve.cluster import (ClusterKDEService, ClusterRACEService,
                                 ClusterRetrievalService, FailoverConfig)
from repro.serve.kde_service import KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig

_RACE_KW = dict(dim=8, L=6, W=32, ingest_chunk=64, seed=3)
_KDE_KW = dict(dim=8, L=6, W=32, window=100_000, eh_eps=0.2, ingest_chunk=50)
_SANN_KW = dict(dim=8, n_max=100, eta=0.0, r=0.4, c=2.0, w=1.0, L=6, k=3,
                ingest_chunk=64)
_FO = dict(max_retries=2, backoff_s=0.01)


def _data(n=500, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


def _states_equal(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(la, lb))


def _no_worker_orphans():
    return [p.name for p in multiprocessing.active_children()
            if p.name.startswith("sketch-worker")] == []


# ---------------------------------------------------------------------------
# Bit-exactness vs the in-process oracle
# ---------------------------------------------------------------------------

def test_rpc_race_bit_exact_vs_inprocess_oracle():
    data = _data(seed=2)
    qs = data[:7] + 0.01
    oracle = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=2, merge_every=4)
    oracle.ingest(data)
    svc = RPCClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=2, merge_every=4)
    try:
        assert svc.workers[0]._ch.engine_kind == "RACEService"
        svc.ingest(data)
        np.testing.assert_array_equal(svc.query(qs), oracle.query(qs))
        np.testing.assert_array_equal(svc.kde(qs), oracle.kde(qs))
        assert svc.count == oracle.count == len(data)
        svc.delete(data[:5])
        oracle.delete(data[:5])
        np.testing.assert_array_equal(svc.query(qs), oracle.query(qs))
        assert _states_equal(svc.merged_state(), oracle.merged_state())
    finally:
        svc.close()
        oracle.close()
    assert _no_worker_orphans()


def test_rpc_kde_bit_exact_vs_inprocess_oracle():
    data = _data(seed=3)
    qs = data[:7] + 0.01
    oracle = ClusterKDEService(KDEServiceConfig(**_KDE_KW),
                               num_workers=2, merge_every=4)
    oracle.ingest(data)
    svc = RPCClusterKDEService(KDEServiceConfig(**_KDE_KW),
                               num_workers=2, merge_every=4)
    try:
        svc.ingest(data)
        np.testing.assert_array_equal(svc.query(qs), oracle.query(qs))
        np.testing.assert_array_equal(svc.density(qs), oracle.density(qs))
        assert svc.steps == oracle.steps == len(data)
        assert _states_equal(svc.merged_state(), oracle.merged_state())
    finally:
        svc.close()
        oracle.close()
    assert _no_worker_orphans()


def test_rpc_sann_bit_exact_vs_inprocess_oracle():
    data = _data(n=300, seed=4)
    qs = np.asarray(data[:6] + 0.01, np.float32)
    oracle = ClusterRetrievalService(RetrievalConfig(**_SANN_KW),
                                     num_workers=2, merge_every=4)
    oracle.ingest(data)
    svc = RPCClusterRetrievalService(RetrievalConfig(**_SANN_KW),
                                     num_workers=2, merge_every=4)
    try:
        svc.ingest(data)
        for a, b in zip(svc.query(qs), oracle.query(qs)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ra, rb = svc.query_topk(qs), oracle.query_topk(qs)
        for a, b in zip(ra, rb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert _states_equal(svc.merged_state(), oracle.merged_state())
    finally:
        svc.close()
        oracle.close()
    assert _no_worker_orphans()


def test_rpc_durable_recover_bit_exact(tmp_path):
    """Stop every worker process (graceful close), restart the cluster on
    the same directory, `recover()`: answers and state match the oracle
    that never stopped."""
    data = _data(n=400, seed=5)
    qs = data[:6] + 0.01
    oracle = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=2, merge_every=4)
    oracle.ingest(data)

    cfg = RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "c"),
                            snapshot_every=3)
    a = RPCClusterRACEService(cfg, num_workers=2, merge_every=4)
    try:
        a.ingest(data)
    finally:
        a.close()
    assert _no_worker_orphans()

    b = RPCClusterRACEService(cfg, num_workers=2, merge_every=4)
    try:
        assert b.recover() > 0
        np.testing.assert_array_equal(b.query(qs), oracle.query(qs))
        assert _states_equal(b.merged_state(), oracle.merged_state())
    finally:
        b.close()
        oracle.close()
    assert _no_worker_orphans()


# ---------------------------------------------------------------------------
# Chaos: killed worker processes, injected network faults
# ---------------------------------------------------------------------------

def test_rpc_worker_process_kill_failover_respawns_bit_exact(tmp_path):
    """SIGKILL worker 1's process mid-stream: the broken channel surfaces
    as a hard failure, failover respawns the process on the same
    durability directory and `recover()`s it from its WAL — every
    acknowledged chunk was logged (+flushed) before the OK, so the
    cluster converges bit-identically to a never-faulted oracle."""
    data = _data(seed=21)
    oracle = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=2, merge_every=4)
    oracle.ingest(data)
    svc = RPCClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "c"),
                          snapshot_every=4),
        num_workers=2, merge_every=4, failover=FailoverConfig(**_FO))
    try:
        for i in range(0, len(data), 100):
            if i == 200:
                svc._procs[1].kill()        # SIGKILL, no goodbye
                svc._procs[1].join(10.0)
            svc.ingest(data[i:i + 100])
        h = svc.health()
        assert h["counters"]["recoveries"] >= 1
        assert h["dead_workers"] == [] and h["coverage"] == 1.0
        assert _states_equal(svc.merged_state(), oracle.merged_state())
    finally:
        svc.close()
        oracle.close()
    assert _no_worker_orphans()


def test_rpc_worker_kill_without_respawn_salvages_wal_tail(tmp_path):
    """Same kill, ``respawn=False``: the rebuild hook refuses, the worker
    is declared DEAD, and its WAL tail — read straight off the shared
    filesystem — is re-partitioned to the survivor.  RACE counter sums
    are routing-independent, so the cluster stays bit-identical to a
    single engine over the whole stream."""
    data = _data(seed=22)
    qs = data[:6] + 0.01
    single = RACEService(RACEServiceConfig(**_RACE_KW))
    single.ingest(data)
    # Huge snapshot cadence: the dead worker's WAL is never compacted, so
    # its whole history is salvageable (same setup as the in-process
    # dead-worker chaos tests).
    svc = RPCClusterRACEService(
        RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path / "c"),
                          snapshot_every=10_000),
        num_workers=2, merge_every=4,
        failover=FailoverConfig(on_degraded="partial", **_FO),
        rpc=RPCConfig(respawn=False))
    try:
        for i in range(0, len(data), 100):
            if i == 200:
                svc._procs[1].kill()
                svc._procs[1].join(10.0)
            svc.ingest(data[i:i + 100])
        h = svc.health()
        assert h["dead_workers"] == [1]
        assert h["salvage_complete"] == [1]
        assert h["counters"]["repartitions"] >= 1
        np.testing.assert_array_equal(svc.query(qs), single.query(qs))
        assert svc.count == single.count == len(data)
    finally:
        svc.close()
        single.close()
    assert _no_worker_orphans()


def test_rpc_transient_net_drop_retries_in_place(tmp_path):
    """A seeded ``net.send`` drop (request lost before any bytes went
    out) is transient: the coordinator retries on the same channel — no
    recovery, no respawn, bit-identical state."""
    data = _data(n=300, seed=23)
    oracle = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=2, merge_every=4)
    oracle.ingest(data)
    svc = RPCClusterRACEService(
        RACEServiceConfig(**_RACE_KW), num_workers=2, merge_every=4,
        failover=FailoverConfig(**_FO))
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_1/net.send", mode="drop", hit=2)])
    try:
        with faults.installed(plan):
            svc.ingest(data)
        assert plan.fired, "the injected send drop never fired"
        h = svc.health()
        assert h["counters"]["retries"] >= 1
        assert h["counters"]["recoveries"] == 0
        assert _states_equal(svc.merged_state(), oracle.merged_state())
    finally:
        svc.close()
        oracle.close()
    assert _no_worker_orphans()


# ---------------------------------------------------------------------------
# Lifecycle hygiene
# ---------------------------------------------------------------------------

def test_rpc_startup_connect_failure_reaps_spawned_workers():
    """Worker 0 spawns and connects fine; worker 1's connect is killed by
    an injected crash.  The constructor must reap BOTH processes before
    re-raising — no orphan PIDs from a half-built cluster."""
    plan = persist.FaultPlan([persist.FaultSpec(
        site="worker_1/net.connect", mode="crash", hit=1)])
    with faults.installed(plan):
        with pytest.raises(persist.FaultError):
            RPCClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                  num_workers=2, merge_every=4)
    assert plan.fired
    assert _no_worker_orphans()


def test_rpc_query_timeout_resolves_batched_future(tmp_path):
    """SIGSTOP a worker and submit a batched query: the RPC timeout must
    propagate through the batch executor and resolve the future with an
    error — never leak it / hang the client."""
    data = _data(n=200, seed=24)
    svc = RPCClusterRACEService(
        RACEServiceConfig(**_RACE_KW, batch_queries=True),
        num_workers=2, merge_every=4)
    try:
        svc.ingest(data)
        _ = svc.query(data[:3])             # warm the jitted query path
        for w in svc.workers:               # then shrink the deadline
            w._ch._timeout = 1.5
            w._ch._sock.settimeout(1.5)
        os.kill(svc._procs[0].pid, signal.SIGSTOP)
        try:
            fut = svc.submit_query(data[:3])
            with pytest.raises(BaseException):
                fut.result(timeout=30.0)
        finally:
            os.kill(svc._procs[0].pid, signal.SIGCONT)
        assert svc.workers[0]._ch.broken is not None
    finally:
        svc.close()
    assert _no_worker_orphans()
