"""Batched-ingest engine equivalence (the unified update contract).

The contract: for every sketch, the batched path must be *bit-identical* to
replaying the per-point reference path — RACE and SW-AKDE counters exactly,
S-ANN full state under a shared key schedule.  These tests are the license
for serve/ and benchmarks/ to use the batched engine unconditionally.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eh, lsh, race, sann, swakde


def _states_equal(a, b):
    return all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# RACE
# ---------------------------------------------------------------------------

def test_race_batch_bit_identical_to_scan():
    p = lsh.init_srp(jax.random.PRNGKey(0), 16, L=5, k=3, n_buckets=32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (203, 16))  # non-multiple of
    st_b = race.race_update_batch(race.race_init(5, 32), p, xs)  # the cb block

    def step(s, x):
        return race.race_update(s, p, x), None

    st_s, _ = jax.lax.scan(step, race.race_init(5, 32), xs)
    assert _states_equal(st_b, st_s)


def test_race_batch_turnstile_sign():
    p = lsh.init_pstable(jax.random.PRNGKey(2), 8, L=4, k=2, w=4.0,
                         n_buckets=16)
    xs = jax.random.normal(jax.random.PRNGKey(3), (60, 8))
    st = race.race_update_batch(race.race_init(4, 16), p, xs)
    st = race.race_update_batch(st, p, xs, sign=-1)
    assert (np.asarray(st.counts) == 0).all()
    assert int(st.n) == 0


def test_race_batch_wide_range_path():
    """W > 128 takes the scatter-add branch in kernels.ops.race_hist."""
    p = lsh.init_srp(jax.random.PRNGKey(4), 8, L=3, k=4, n_buckets=500)
    xs = jax.random.normal(jax.random.PRNGKey(5), (64, 8))
    st_b = race.race_update_batch(race.race_init(3, 500), p, xs)

    def step(s, x):
        return race.race_update(s, p, x), None

    st_s, _ = jax.lax.scan(step, race.race_init(3, 500), xs)
    assert _states_equal(st_b, st_s)


# ---------------------------------------------------------------------------
# SW-AKDE (exact per-point-timestamp chunk replay)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 17, 64, 250])
def test_swakde_chunked_stream_bit_identical(chunk):
    cfg = swakde.SWAKDEConfig(L=6, W=32, window=100, eh_eps=0.1)
    p = lsh.init_srp(jax.random.PRNGKey(0), 8, L=6, k=2, n_buckets=32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (250, 8))
    st_seq = swakde.swakde_stream(swakde.swakde_init(cfg), p, xs, cfg)
    st_bat = swakde.swakde_stream_batched(swakde.swakde_init(cfg), p, xs, cfg,
                                          chunk=chunk)
    assert _states_equal(st_seq, st_bat)


def test_swakde_chunk_skewed_codes():
    """All points in one bucket — the replay loop's worst case."""
    cfg = swakde.SWAKDEConfig(L=4, W=16, window=40, eh_eps=0.2)
    p = lsh.init_srp(jax.random.PRNGKey(2), 4, L=4, k=2, n_buckets=16)
    xs = jnp.ones((96, 4))  # identical points → identical codes
    st_seq = swakde.swakde_stream(swakde.swakde_init(cfg), p, xs, cfg)
    st_bat = swakde.swakde_update_chunk(swakde.swakde_init(cfg), p, xs, cfg)
    assert _states_equal(st_seq, st_bat)


# ---------------------------------------------------------------------------
# SumEH closed form (the Corollary-4.2 batch cell)
# ---------------------------------------------------------------------------

def _live_masked(state, cfg):
    idx = np.arange(cfg.base.slots)[None, :]
    live = idx < np.asarray(state.num)[:, None]
    return np.where(live, np.asarray(state.ts), -7)


def test_sum_eh_closed_form_matches_ref():
    """Closed-form multi-increment add == `value` sequential unit adds:
    identical live buckets and identical queries at every step."""
    cfg = eh.SumEHConfig.create(window=20, eps=0.2, batch_max=64)
    rng = np.random.default_rng(0)
    st_ref = eh.sum_eh_init(cfg)
    st_new = eh.sum_eh_init(cfg)
    for t in range(40):
        v = int(rng.integers(0, 65))
        st_ref = eh.sum_eh_add_ref(st_ref, jnp.int32(t), jnp.int32(v), cfg)
        st_new = eh.sum_eh_add(st_new, jnp.int32(t), jnp.int32(v), cfg)
        assert (np.asarray(st_ref.num) == np.asarray(st_new.num)).all(), t
        assert (_live_masked(st_ref, cfg) == _live_masked(st_new, cfg)).all(), t
        q_ref = float(eh.sum_eh_query(st_ref, jnp.int32(t), cfg))
        q_new = float(eh.sum_eh_query(st_new, jnp.int32(t), cfg))
        assert q_ref == q_new, (t, q_ref, q_new)


def test_batch_swakde_grid_matches_ref_cells():
    """batch_swakde_update (closed-form cells + kernel histogram) equals the
    reference per-cell sum_eh_add_ref grid, live-masked."""
    cfg = swakde.BatchSWAKDEConfig(L=4, W=16, window=6, eh_eps=0.2,
                                   batch_size=8)
    ehc = cfg.eh_config()
    p = lsh.init_srp(jax.random.PRNGKey(3), 8, L=4, k=2, n_buckets=16)
    st = swakde.batch_swakde_init(cfg)
    st_ref = swakde.batch_swakde_init(cfg)
    for i in range(10):
        batch = jax.random.normal(jax.random.PRNGKey(10 + i), (8, 8))
        st = swakde.batch_swakde_update(st, p, batch, cfg)
        codes = lsh.hash_points(p, batch)
        incr = jax.nn.one_hot(codes, cfg.W, dtype=jnp.int32).sum(0)

        def upd(ts, num, v, t=st_ref.t):
            s = eh.sum_eh_add_ref(eh.EHState(ts, num), t, v, ehc)
            return s.ts, s.num

        ts, num = jax.vmap(jax.vmap(upd))(st_ref.ts, st_ref.num, incr)
        st_ref = swakde.BatchSWAKDEState(ts=ts, num=num, t=st_ref.t + 1)
        assert (np.asarray(st.num) == np.asarray(st_ref.num)).all(), i
        idx = np.arange(ehc.base.slots)
        live = idx[None, None, None, :] < np.asarray(st.num)[..., None]
        assert (np.where(live, np.asarray(st.ts), -7)
                == np.where(live, np.asarray(st_ref.ts), -7)).all(), i
    assert int(st.t) == int(st_ref.t) == 10


# ---------------------------------------------------------------------------
# S-ANN
# ---------------------------------------------------------------------------

def _sann_setup(n_max=2000, eta=0.25, slack=4.0, dim=8, seed=0):
    cfg = sann.SANNConfig(dim=dim, n_max=n_max, eta=eta, r=0.5, c=2.0,
                          L=4, k=2, capacity_slack=slack)
    return sann.sann_init(cfg, jax.random.PRNGKey(seed))


def test_sann_batch_bit_identical_to_stream():
    cfg, p, st0 = _sann_setup()
    xs = jax.random.uniform(jax.random.PRNGKey(1), (500, 8))
    key = jax.random.PRNGKey(2)
    st_seq = sann.sann_insert_stream(st0, p, xs, key, cfg)
    st_bat = sann.sann_insert_batch(st0, p, xs, key, cfg)
    assert _states_equal(st_seq, st_bat)


def test_sann_batch_bit_identical_under_ring_wrap():
    """Chunk laps the ring several times: last-writer-wins + tombstones must
    still replay the sequential path exactly."""
    cfg, p, st0 = _sann_setup(n_max=300, eta=0.0, slack=0.1)
    assert cfg.capacity == 64
    xs = jax.random.uniform(jax.random.PRNGKey(3), (300, 8))
    key = jax.random.PRNGKey(4)
    st_seq = sann.sann_insert_stream(st0, p, xs, key, cfg)
    st_bat = sann.sann_insert_batch(st0, p, xs, key, cfg)
    assert _states_equal(st_seq, st_bat)
    assert int(st_seq.n_stored) == int(st_seq.valid.sum()) == 64


def test_sann_chunked_matches_sequential_build_and_queries():
    """sann_insert_chunked splits the key once per chunk; the sequential
    replay with the same per-chunk schedule must give identical state and
    identical query results."""
    cfg, p, st0 = _sann_setup(n_max=600, eta=0.1)
    xs = jax.random.uniform(jax.random.PRNGKey(5), (600, 8))
    key = jax.random.PRNGKey(6)
    chunk = 150
    ckeys = jax.random.split(key, 4)
    st_seq = st0
    for i in range(4):
        st_seq = sann.sann_insert_stream(
            st_seq, p, xs[i * chunk:(i + 1) * chunk], ckeys[i], cfg)
    st_bat = sann.sann_insert_chunked(st0, p, xs, key, cfg, chunk=chunk)
    assert _states_equal(st_seq, st_bat)
    qs = xs[:16] + 0.01
    r_seq = sann.sann_query_batch(st_seq, p, qs, cfg)
    r_bat = sann.sann_query_batch(st_bat, p, qs, cfg)
    assert (np.asarray(r_seq.index) == np.asarray(r_bat.index)).all()
    assert (np.asarray(r_seq.found) == np.asarray(r_bat.found)).all()
    assert (np.asarray(r_seq.distance) == np.asarray(r_bat.distance)).all()


def test_sann_ring_eviction_tombstones_stale_entries():
    """Regression (seed bug): streaming past capacity recycles slots; every
    surviving table entry must point at a vector that actually hashes into
    that bucket — stale references to evicted points must be tombstoned."""
    cfg, p, st0 = _sann_setup(n_max=300, eta=0.0, slack=0.1, dim=4, seed=7)
    xs = jax.random.uniform(jax.random.PRNGKey(8), (300, 4))
    for build in ("seq", "batch"):
        if build == "seq":
            st = sann.sann_insert_stream(st0, p, xs, jax.random.PRNGKey(9), cfg)
        else:
            st = sann.sann_insert_batch(st0, p, xs, jax.random.PRNGKey(9), cfg)
        codes_all = np.asarray(lsh.hash_points(p, st.points))  # (capacity, L)
        tables = np.asarray(st.tables)
        for l in range(cfg.L):
            tab = tables[l]                                    # (buckets, cap)
            mask = tab >= 0
            entry_codes = codes_all[np.maximum(tab, 0), l]
            expect = np.arange(tab.shape[0])[:, None]
            assert ((entry_codes == expect) | ~mask).all(), build


# ---------------------------------------------------------------------------
# Serving layer
# ---------------------------------------------------------------------------

def test_retrieval_service_batched_ingest_partial_chunks():
    from repro.serve.retrieval import RetrievalConfig, RetrievalService
    svc = RetrievalService(RetrievalConfig(
        dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=6, k=3,
        ingest_chunk=64))
    rng = np.random.default_rng(0)
    data = rng.uniform(0, 1, (300, 8)).astype(np.float32)
    svc.ingest(data[:100])       # 1 full chunk + remainder
    svc.ingest(data[100:300])    # 3 full chunks + remainder
    assert svc.stored > 0
    res = svc.query(data[:8] + 0.01)
    assert np.asarray(res.found).any()
    assert int(svc.state.n_seen) == 300


def test_kde_service_matches_direct_stream():
    """The service's chunked ingest is bit-identical to one swakde_stream."""
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    svc = KDEService(KDEServiceConfig(dim=8, L=6, W=32, window=80,
                                      eh_eps=0.2, ingest_chunk=50))
    rng = np.random.default_rng(1)
    data = rng.normal(0, 1, (230, 8)).astype(np.float32)
    svc.ingest(data[:120])
    svc.ingest(data[120:])
    assert svc.steps == 230
    direct = swakde.swakde_stream(
        swakde.swakde_init(svc.sketch_cfg), svc.params,
        jnp.asarray(data), svc.sketch_cfg)
    assert _states_equal(svc.state, direct)
    q = svc.query(data[:4])
    dq = np.asarray(swakde.swakde_query_batch(
        direct, svc.params, jnp.asarray(data[:4]), svc.sketch_cfg))
    np.testing.assert_allclose(q, dq)
    assert (svc.density(data[:4]) >= 0).all()
