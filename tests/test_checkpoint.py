"""Checkpoint: atomic save/restore, async writer, cross-mesh resharding."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    ck.save(tmp_path / "step_3", tree, 3)
    like = jax.tree.map(jnp.zeros_like, tree)
    out, step = ck.restore(tmp_path / "step_3", like)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.full((64, 64), 7.0)}
    acp = ck.AsyncCheckpointer()
    acp.save(tmp_path / "step_1", tree, 1)
    acp.wait()
    out, step = ck.restore(tmp_path / "step_1", jax.tree.map(jnp.zeros_like, tree))
    assert step == 1 and float(out["w"][0, 0]) == 7.0


def test_latest_step(tmp_path):
    for s in (5, 20, 10):
        ck.save(tmp_path / f"step_{s}", {"x": jnp.zeros(3)}, s)
    assert ck.latest_step(tmp_path) == 20
    assert ck.latest_step(tmp_path / "nope") is None


def test_cross_mesh_reshard(tmp_path):
    """Elastic scaling: save on a (4,) data mesh, restore on (2, 4) — runs in
    a subprocess with 8 virtual devices so the main process stays 1-device."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ck

        mesh_a = jax.make_mesh((4,), ("data",),
                               axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh_a, P("data", None)))
        ck.save(r"{tmp_path}/step_1", {{"w": x}}, 1)

        mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2)
        tgt = NamedSharding(mesh_b, P("data", "model"))
        out, step = ck.restore(r"{tmp_path}/step_1",
                               {{"w": jnp.zeros((8, 8))}}, {{"w": tgt}})
        assert step == 1
        assert out["w"].sharding == tgt
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("RESHARD_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=_env())
    assert "RESHARD_OK" in r.stdout, r.stderr[-2000:]


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    return env
