"""Fused batched query engine == vmapped per-query oracles (all three
sketches).

PR 3 replaced the vmap-over-per-query *_batch entry points with batch-level
fused pipelines (one hash matmul + one gather + batch-wide truncation /
dedup / fused scoring).  The per-query functions (`sann_query`,
`sann_query_topk`, `race_query`, `swakde_query`) keep the original
semantics and serve as oracles; these tests pin the fused engine to them —
indices / found flags / counts exactly, distances to 1e-5, RACE counts and
SW-AKDE estimates exactly — including ring-wrapped and evicted stores,
duplicate-slot dedup, batch sizes not divisible by the service query
block, and the 8-device sharded path.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh, race, sann, swakde
from repro.kernels import batch_score as bs_k
from repro.kernels import ref


# ---------------------------------------------------------------------------
# The fused scorer kernel vs its oracle (interpret mode).  These live here
# rather than tests/test_kernels.py so they are not gated behind that
# module's hypothesis importorskip — they must run everywhere.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,d", [(1, 1, 8), (7, 100, 24), (64, 300, 48)])
@pytest.mark.parametrize("k", [1, 5])
def test_batch_score_topk_matches_ref(B, M, d, k):
    k = min(k, M)
    qs = jax.random.normal(jax.random.PRNGKey(B + M), (B, d))
    cands = jax.random.normal(jax.random.PRNGKey(d + k), (B, M, d))
    ok = jax.random.uniform(jax.random.PRNGKey(3), (B, M)) < 0.8
    got_d, got_i = bs_k.batch_score_topk(qs, cands, ok, k, block_b=4,
                                         block_m=32, interpret=True)
    want_d, want_i = ref.batch_score_topk_ref(qs, cands, ok, k)
    got_d, want_d = np.asarray(got_d), np.asarray(want_d)
    finite = np.isfinite(want_d)
    np.testing.assert_array_equal(np.isfinite(got_d), finite)
    np.testing.assert_allclose(got_d[finite], want_d[finite],
                               rtol=2e-2, atol=1e-4)
    # identity-vs-diff numerics cannot flip generic (distinct-distance)
    # rankings; only compare indices where distances are finite
    np.testing.assert_array_equal(np.asarray(got_i)[finite],
                                  np.asarray(want_i)[finite])


def test_batch_score_topk_fully_masked_row():
    qs = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    cands = jax.random.normal(jax.random.PRNGKey(1), (4, 40, 16))
    ok = jnp.ones((4, 40), bool).at[2].set(False)
    got_d, _ = bs_k.batch_score_topk(qs, cands, ok, 3, block_b=2,
                                     block_m=16, interpret=True)
    assert np.isinf(np.asarray(got_d)[2]).all()
    assert np.isfinite(np.asarray(got_d)[[0, 1, 3]]).all()


def _assert_sann_results_equal(got: sann.SANNResult, want: sann.SANNResult):
    np.testing.assert_array_equal(np.asarray(got.index),
                                  np.asarray(want.index), err_msg="index")
    np.testing.assert_array_equal(np.asarray(got.found),
                                  np.asarray(want.found), err_msg="found")
    np.testing.assert_array_equal(np.asarray(got.n_candidates),
                                  np.asarray(want.n_candidates),
                                  err_msg="n_candidates")
    np.testing.assert_allclose(np.asarray(got.distance),
                               np.asarray(want.distance), atol=1e-5,
                               err_msg="distance")


def _wrapped_state(n=1200, d=10, extra_deletes=True):
    """A store that ring-wrapped several times (capacity_slack shrinks the
    ring far below the kept-point count) with tombstoned entries."""
    cfg = sann.SANNConfig(dim=d, n_max=n, eta=0.2, r=0.6, c=2.0, w=1.5,
                          L=8, k=3, capacity_slack=0.15)
    cfg, params, st = sann.sann_init(cfg, jax.random.PRNGKey(0))
    xs = jnp.asarray(np.random.default_rng(1).uniform(
        0, 1, (n, d)).astype(np.float32))
    st = sann.sann_insert_chunked(st, params, xs, jax.random.PRNGKey(2), cfg,
                                  chunk=256)
    assert int(st.n_stored) < n * cfg.keep_prob  # the ring really wrapped
    if extra_deletes:
        for i in (n - 3, n - 40):               # evict recent (live) points
            st = sann.sann_delete(st, params, xs[i], cfg)
    return cfg, params, st, xs


def test_sann_batch_matches_oracle_ring_wrapped():
    cfg, params, st, xs = _wrapped_state()
    # mix of: near-duplicates of stored points, exact stored points, and
    # far-away queries that must return NULL
    qs = jnp.concatenate([
        xs[-30:] + 0.01, xs[:5], jnp.full((4, xs.shape[1]), 25.0)])
    want = jax.vmap(lambda q: sann.sann_query(st, params, q, cfg))(qs)
    got = sann.sann_query_batch(st, params, qs, cfg)
    _assert_sann_results_equal(got, want)
    assert bool(np.asarray(want.found).any())       # test isn't vacuous
    assert not bool(np.asarray(want.found[-4:]).any())


def test_sann_topk_batch_matches_oracle_duplicate_slots():
    """Streams with many identical points put the same slot id in several
    buckets *and* several identical vectors in distinct slots — the dedup
    and tie paths of the top-k engine."""
    d = 8
    cfg = sann.SANNConfig(dim=d, n_max=400, eta=0.0, r=0.4, c=2.0, w=2.0,
                          L=4, k=2, bucket_cap=16)
    cfg, params, st = sann.sann_init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(4)
    base = rng.uniform(0, 1, (40, d)).astype(np.float32)
    xs = jnp.asarray(np.repeat(base, 10, axis=0))   # every vector 10 times
    st = sann.sann_insert_batch(st, params, xs, jax.random.PRNGKey(5), cfg)
    qs = jnp.asarray(base[:12]) + 0.005
    want = jax.vmap(
        lambda q: sann.sann_query_topk(st, params, q, cfg, 10))(qs)
    got = sann.sann_query_topk_batch(st, params, qs, cfg, 10)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-5)


def test_sann_topk_dedup_sort_fallback_branch():
    """A capacity above the scatter-dedup threshold exercises the batched
    argsort dedup branch of `_first_occurrence_mask`."""
    d = 6
    cfg = sann.SANNConfig(dim=d, n_max=1100, eta=0.0, r=0.5, c=2.0, w=2.0,
                          L=4, k=2, bucket_cap=8)
    cfg, params, st = sann.sann_init(cfg, jax.random.PRNGKey(6))
    assert cfg.capacity + 1 > max(4096, 8 * cfg.L * cfg.bucket_cap)
    xs = jnp.asarray(np.random.default_rng(7).uniform(
        0, 1, (1100, d)).astype(np.float32))
    st = sann.sann_insert_batch(st, params, xs, jax.random.PRNGKey(8), cfg)
    qs = xs[:7] + 0.01
    want = jax.vmap(
        lambda q: sann.sann_query_topk(st, params, q, cfg, 15))(qs)
    got = sann.sann_query_topk_batch(st, params, qs, cfg, 15)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-5)


def test_race_batch_matches_oracle_exactly():
    d, L, W = 10, 12, 32
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=3, n_buckets=W)
    xs = jax.random.normal(jax.random.PRNGKey(1), (400, d))
    st = race.race_update_batch(race.race_init(L, W), params, xs)
    st = race.race_update_batch(st, params, xs[:60], sign=-1)  # turnstile
    qs = jax.random.normal(jax.random.PRNGKey(2), (33, d))
    for mom in (0, 4):
        want = jax.vmap(lambda q: race.race_query(st, params, q, mom))(qs)
        got = race.race_query_batch(st, params, qs, mom)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B", [5, 40])  # below / at-least the W threshold
def test_swakde_batch_matches_oracle_exactly(B):
    """Both fused branches (gather-then-query for B < W, grid precompute +
    one-hot read for B ≥ W) must equal the per-query oracle bitwise,
    including after window expiry."""
    d = 9
    cfg = swakde.SWAKDEConfig(L=6, W=24, window=90, eh_eps=0.15)
    params = lsh.init_srp(jax.random.PRNGKey(3), d, L=6, k=2, n_buckets=24)
    xs = jax.random.normal(jax.random.PRNGKey(4), (300, d))  # > window
    st = swakde.swakde_init(cfg)
    for i in range(0, 300, 100):
        st = swakde.swakde_update_chunk(st, params, xs[i:i + 100], cfg)
    qs = jax.random.normal(jax.random.PRNGKey(5), (B, d))
    want = jax.vmap(lambda q: swakde.swakde_query(st, params, q, cfg))(qs)
    got = swakde.swakde_query_batch(st, params, qs, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the row-estimate form feeding the sharded path is exact as well
    want_rows = jax.vmap(
        lambda q: swakde.swakde_row_estimates(st, params, q, cfg))(qs)
    got_rows = swakde.swakde_row_estimates_batch(st, params, qs, cfg)
    np.testing.assert_array_equal(np.asarray(got_rows), np.asarray(want_rows))


def test_service_query_block_not_divisible():
    """Serving through query_block chunks (B % block != 0) must equal one
    unblocked call for both services."""
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    from repro.serve.retrieval import RetrievalConfig, RetrievalService

    rng = np.random.default_rng(0)
    emb = rng.normal(0, 1, (300, 12)).astype(np.float32)
    qs = np.concatenate([emb[:9] + 0.01, rng.normal(5, 1, (4, 12))]
                        ).astype(np.float32)                 # B=13

    kw = dict(dim=12, n_max=2000, eta=0.3, r=0.8, c=2.0, ingest_chunk=128)
    blocked = RetrievalService(RetrievalConfig(**kw, query_block=5))
    whole = RetrievalService(RetrievalConfig(**kw))
    blocked.ingest(emb)
    whole.ingest(emb)
    _assert_sann_results_equal(blocked.query(qs), whole.query(qs))

    kk = dict(dim=12, L=8, W=16, window=200, ingest_chunk=128)
    kb = KDEService(KDEServiceConfig(**kk, query_block=5))
    kww = KDEService(KDEServiceConfig(**kk))
    kb.ingest(emb)
    kww.ingest(emb)
    np.testing.assert_array_equal(kb.query(qs), kww.query(qs))
    np.testing.assert_array_equal(kb.density(qs), kww.density(qs))


def test_empty_query_batch():
    """B = 0 must return empty results everywhere (engine + services), as
    the pre-fusion vmapped path did."""
    from repro.serve.kde_service import KDEService, KDEServiceConfig
    from repro.serve.retrieval import RetrievalConfig, RetrievalService

    d = 8
    # the Pallas scorer itself (the TPU / REPRO_FORCE_PALLAS path) must not
    # try to launch a zero grid
    e_d2, e_idx = bs_k.batch_score_topk(
        jnp.zeros((0, d)), jnp.zeros((0, 6, d)), jnp.zeros((0, 6), bool), 2,
        interpret=True)
    assert e_d2.shape == (0, 2) and e_idx.shape == (0, 2)

    cfg = sann.SANNConfig(dim=d, n_max=200, eta=0.2, r=0.5, c=2.0, w=2.0,
                          L=4, k=2)
    cfg, params, st = sann.sann_init(cfg, jax.random.PRNGKey(0))
    empty = jnp.zeros((0, d), jnp.float32)
    res = sann.sann_query_batch(st, params, empty, cfg)
    assert res.index.shape == (0,)
    ids, dists = sann.sann_query_topk_batch(st, params, empty, cfg, 5)
    assert ids.shape[0] == 0 and dists.shape[0] == 0

    rparams = lsh.init_srp(jax.random.PRNGKey(1), d, L=4, k=2, n_buckets=16)
    assert race.race_query_batch(race.race_init(4, 16), rparams,
                                 empty).shape == (0,)
    scfg = swakde.SWAKDEConfig(L=4, W=16, window=50, eh_eps=0.2)
    assert swakde.swakde_query_batch(swakde.swakde_init(scfg), rparams,
                                     empty, scfg).shape == (0,)

    svc = RetrievalService(RetrievalConfig(dim=d, n_max=500))
    assert svc.query(np.zeros((0, d), np.float32)).index.shape == (0,)
    kde = KDEService(KDEServiceConfig(dim=d, L=4, W=16, window=50))
    assert kde.query(np.zeros((0, d), np.float32)).shape == (0,)
    assert kde.density(np.zeros((0, d), np.float32)).shape == (0,)


def _run(body: str) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n" +
              textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return r.stdout


def test_sharded_fused_sann_matches_single_device():
    """The sharded S-ANN query paths reuse the fused batched reductions per
    table shard; results must stay bitwise equal to the single-device fused
    engine (and therefore to the per-query oracles) on 8 forced host
    devices."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sann
        from repro.parallel import sketch_sharding as ss

        d = 12
        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        cfg = sann.SANNConfig(dim=d, n_max=2000, eta=0.35, r=0.8, c=2.0,
                              w=1.6, L=16, k=4)
        cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(0))
        stream = jnp.asarray(np.random.default_rng(1).uniform(
            0, 1, (600, d)).astype(np.float32))
        key = jax.random.PRNGKey(2)
        qs = stream[:9] + 0.01
        ref = sann.sann_insert_batch(st0, params, stream, key, cfg)
        st, p = ss.shard_sann(st0, params, ctx)
        st = ss.sharded_sann_insert_batch(st, p, stream, key, cfg, ctx)
        r1 = sann.sann_query_batch(ref, params, qs, cfg)
        r8 = ss.sharded_sann_query_batch(st, p, qs, cfg, ctx)
        for nm, a, b in zip(r1._fields, r8, r1):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=nm)
        t1 = sann.sann_query_topk_batch(ref, params, qs, cfg, topk=10)
        t8 = ss.sharded_sann_query_topk_batch(st, p, qs, cfg, ctx, topk=10)
        np.testing.assert_array_equal(np.asarray(t8[0]), np.asarray(t1[0]))
        np.testing.assert_array_equal(np.asarray(t8[1]), np.asarray(t1[1]))
        print("FUSED_SHARDED_SANN_OK")
    """)
    assert "FUSED_SHARDED_SANN_OK" in out


def test_sharded_fused_race_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import lsh, race
        from repro.parallel import sketch_sharding as ss

        d, L, W = 12, 16, 32
        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        stream = jnp.asarray(np.random.default_rng(1).uniform(
            0, 1, (600, d)).astype(np.float32))
        rp = lsh.init_srp(jax.random.PRNGKey(3), d, L=L, k=3, n_buckets=W)
        rref = race.race_update_batch(race.race_init(L, W), rp, stream)
        rst, rpp = ss.shard_race(race.race_init(L, W), rp, ctx)
        rst = ss.sharded_race_update_batch(rst, rpp, stream, ctx)
        qq = stream[:40]
        np.testing.assert_array_equal(
            np.asarray(ss.sharded_race_query_batch(rst, rpp, qq, ctx)),
            np.asarray(race.race_query_batch(rref, rp, qq)))
        print("FUSED_SHARDED_RACE_OK")
    """)
    assert "FUSED_SHARDED_RACE_OK" in out


def test_sharded_fused_swakde_matches_single_device():
    """Both fused branches (B < W gather / B ≥ W grid precompute) inside
    the shard_map body must stay bitwise equal to single-device."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import lsh, swakde
        from repro.parallel import sketch_sharding as ss

        d = 12
        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        stream = jnp.asarray(np.random.default_rng(1).uniform(
            0, 1, (250, d)).astype(np.float32))
        scfg = swakde.SWAKDEConfig(L=8, W=24, window=120, eh_eps=0.15)
        sp = lsh.init_srp(jax.random.PRNGKey(4), d, L=8, k=2, n_buckets=24)
        sref = swakde.swakde_update_chunk(swakde.swakde_init(scfg), sp,
                                          stream, scfg)
        sst, spp = ss.shard_swakde(swakde.swakde_init(scfg), sp, ctx)
        sst = ss.sharded_swakde_update_chunk(sst, spp, stream, scfg, ctx)
        for nq in (5, 40):
            qq = stream[:nq]
            np.testing.assert_array_equal(
                np.asarray(ss.sharded_swakde_query_batch(sst, spp, qq, scfg,
                                                         ctx)),
                np.asarray(swakde.swakde_query_batch(sref, sp, qq, scfg)))
        print("FUSED_SHARDED_SWAKDE_OK")
    """)
    assert "FUSED_SHARDED_SWAKDE_OK" in out
