"""Per-architecture smoke tests: reduced same-family config, one forward
(and one grad) step on CPU — output shapes + finiteness (assignment item f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.inputs import make_batch
from repro.models import model as model_lib

SEQ = {"default": 64}


def _loss(params, cfg, batch):
    logits, aux = model_lib.forward(params, cfg, batch)
    tgt = batch["tokens"][:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean() + aux.get("moe_aux", 0.0)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = registry.get_smoke_config(arch)
    batch = make_batch(cfg, batch=2, seq=64, key=jax.random.PRNGKey(0))
    params = model_lib.init_model(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, b: model_lib.forward(p, cfg, b))(params, batch)
    text_len = batch["tokens"].shape[1]
    assert logits.shape == (2, text_len, cfg.vocab), logits.shape
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux["moe_aux"]))
    if cfg.mtp_depth:
        assert aux["mtp_logits"].shape == (2, text_len, cfg.vocab)


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b", "zamba2-2.7b",
                                  "xlstm-125m", "whisper-large-v3"])
def test_grad_step_finiteness(arch):
    """One value_and_grad step per family representative."""
    cfg = registry.get_smoke_config(arch)
    batch = make_batch(cfg, batch=2, seq=32, key=jax.random.PRNGKey(2))
    params = model_lib.init_model(cfg, jax.random.PRNGKey(3))
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: _loss(p, cfg, batch)))(params)
    assert bool(jnp.isfinite(loss)), arch
    finite = jax.tree.reduce(
        lambda a, g: a and bool(jnp.isfinite(g).all()), grads, True)
    assert finite, f"{arch}: non-finite grads"


def test_param_counts_are_plausible():
    """Analytic 6ND param counts should be within 2x of actual for the full
    configs (used by the roofline MODEL_FLOPS term)."""
    for arch in ("qwen3-4b", "granite-8b"):
        cfg = registry.get_config(arch)
        approx = cfg.param_count()
        # qwen3-4b ~4e9, granite-8b ~8e9
        target = {"qwen3-4b": 4e9, "granite-8b": 8e9}[arch]
        assert 0.4 * target < approx < 2.5 * target, (arch, approx)


def test_window_pattern_gemma3():
    cfg = registry.get_config("gemma3-4b")
    pat = np.asarray(model_lib.window_pattern(cfg))
    assert (pat[5::6] == 0).all()              # every 6th layer global
    assert (np.delete(pat, np.s_[5::6]) == 1024).all()


def test_long_context_applicability():
    from repro.configs.base import LONG_500K
    runs = [a for a in registry.ARCH_IDS
            if registry.shape_applicable(registry.get_config(a), LONG_500K)[0]]
    assert set(runs) == {"zamba2-2.7b", "xlstm-125m", "gemma2-27b", "gemma3-4b"}
