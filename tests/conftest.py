import os

# IMPORTANT: do NOT set XLA_FLAGS / device-count overrides here — smoke tests
# and benches must see the real single-device CPU.  Multi-device semantics are
# tested in subprocesses (tests/test_distributed.py) and the production mesh
# only inside launch/dryrun.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ImportError:  # pragma: no cover — property tests skip themselves
    settings = None

if settings is not None:
    settings.register_profile("repro", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("repro")
