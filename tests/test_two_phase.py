"""Two-phase ingest equivalence (the prepare/commit split, DESIGN.md §10).

The contract: for every sketch, ``*_commit_chunk(state, *_prepare_chunk(...))``
over a chunked stream is *bit-identical* to the fused batched path (and
therefore to the per-point reference path, which tests/test_batched_ingest.py
pins the fused path to).  Prepare is pure — it never reads sketch state —
so every chunk's prep can be computed *before any commit runs*; the
prepare-ahead tests fold prepared chunks in afterwards, which is exactly
the license for `repro.serve.engine` to overlap preparing chunk k+1 with
committing chunk k.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh, race, sann, swakde


def _states_equal(a, b):
    return all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# RACE
# ---------------------------------------------------------------------------

def test_race_two_phase_bit_identical():
    p = lsh.init_srp(jax.random.PRNGKey(0), 16, L=5, k=3, n_buckets=32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (203, 16))
    ref = race.race_update_batch(race.race_init(5, 32), p, xs)
    prep = race.race_prepare_chunk(p, xs, 32)
    st = race.race_commit_chunk(race.race_init(5, 32), prep)
    assert _states_equal(st, ref)
    # turnstile: committing the same prep with sign=-1 cancels exactly
    st = race.race_commit_chunk(st, prep, sign=-1)
    assert (np.asarray(st.counts) == 0).all()
    assert int(st.n) == 0


def test_race_prepare_ahead_of_commits():
    """All preps computed up front (no state in sight), commits folded in
    afterwards — equals the chunk-by-chunk fused path."""
    p = lsh.init_srp(jax.random.PRNGKey(2), 8, L=4, k=2, n_buckets=16)
    xs = jax.random.normal(jax.random.PRNGKey(3), (130, 8))
    chunks = [xs[i:i + 40] for i in range(0, 130, 40)]
    preps = [race.race_prepare_chunk(p, c, 16) for c in chunks]
    st = race.race_init(4, 16)
    ref = race.race_init(4, 16)
    for c, prep in zip(chunks, preps):
        ref = race.race_update_batch(ref, p, c)
        st = race.race_commit_chunk(st, prep)
    assert _states_equal(st, ref)


# ---------------------------------------------------------------------------
# SW-AKDE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [17, 64, 250])
def test_swakde_two_phase_bit_identical(chunk):
    cfg = swakde.SWAKDEConfig(L=6, W=32, window=100, eh_eps=0.1)
    p = lsh.init_srp(jax.random.PRNGKey(0), 8, L=6, k=2, n_buckets=32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (250, 8))
    ref = swakde.swakde_stream(swakde.swakde_init(cfg), p, xs, cfg)
    st = swakde.swakde_init(cfg)
    for i in range(0, 250, chunk):
        prep = swakde.swakde_prepare_chunk(p, xs[i:i + chunk], cfg)
        st = swakde.swakde_commit_chunk(st, prep, cfg)
    assert _states_equal(st, ref)


def test_swakde_prepare_ahead_of_commits_skewed():
    """Prepare-ahead over the replay loop's worst case (all points in one
    bucket): preps carry only relative sort offsets, so committing them
    later against an advanced clock must still replay exactly."""
    cfg = swakde.SWAKDEConfig(L=4, W=16, window=40, eh_eps=0.2)
    p = lsh.init_srp(jax.random.PRNGKey(2), 4, L=4, k=2, n_buckets=16)
    xs = jnp.ones((96, 4))  # identical points → identical codes
    chunks = [xs[i:i + 32] for i in range(0, 96, 32)]
    preps = [swakde.swakde_prepare_chunk(p, c, cfg) for c in chunks]
    st = swakde.swakde_init(cfg)
    for prep in preps:
        st = swakde.swakde_commit_chunk(st, prep, cfg)
    ref = swakde.swakde_stream(swakde.swakde_init(cfg), p, xs, cfg)
    assert _states_equal(st, ref)


def _skew_stream(kind, n, dim, seed):
    """Synthetic streams that funnel mass into few (row, cell) segments."""
    rng = np.random.default_rng(seed)
    if kind == "hot":          # 100% of points in one (row, cell) per row
        return jnp.ones((n, dim), jnp.float32)
    if kind == "powerlaw":     # Zipf over a handful of distinct points
        base = rng.normal(size=(8, dim)).astype(np.float32)
        idx = np.minimum(rng.zipf(1.3, size=n) - 1, 7)
        return jnp.asarray(base[idx])
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["hot", "powerlaw"])
@pytest.mark.parametrize("cap", [0, 1, 3, 17])
def test_swakde_skewed_capped_commit_bit_identical(kind, cap):
    """Skew fuzz (DESIGN.md §12): heavy-cell-capped sub-chunk commits are
    bitwise identical to the uncapped per-point oracle — including EH
    expiry landing *at* a split boundary (window < chunk, so segments
    split both at expiry points and at the cap) and ring wrap inside one
    hot cell's cascade."""
    cfg = swakde.SWAKDEConfig(L=4, W=16, window=40, eh_eps=0.2,
                              heavy_cell_cap=cap)
    p = lsh.init_srp(jax.random.PRNGKey(2), 4, L=4, k=2, n_buckets=16)
    xs = _skew_stream(kind, 96, 4, seed=cap)
    ref = swakde.swakde_stream(swakde.swakde_init(cfg), p, xs, cfg)
    st = swakde.swakde_init(cfg)
    for i in range(0, 96, 64):                 # window (40) < chunk (64)
        prep = swakde.swakde_prepare_chunk(p, xs[i:i + 64], cfg)
        st = swakde.swakde_commit_chunk(st, prep, cfg)
    assert _states_equal(st, ref)


@pytest.mark.parametrize("seed", range(4))
def test_swakde_powerlaw_fuzz_cap_invariance(seed):
    """Every cap value (including uncapped) lands on the same bits: the cap
    only splits a segment's closed-form pass into shorter sub-chunk
    passes, never changes what is committed."""
    cfg0 = swakde.SWAKDEConfig(L=3, W=8, window=25, eh_eps=0.3)
    p = lsh.init_srp(jax.random.PRNGKey(5), 4, L=3, k=2, n_buckets=8)
    xs = _skew_stream("powerlaw", 150, 4, seed=100 + seed)
    states = []
    for cap in (0, 1, 2, 7):
        cfg = swakde.SWAKDEConfig(L=3, W=8, window=25, eh_eps=0.3,
                                  heavy_cell_cap=cap)
        st = swakde.swakde_init(cfg)
        for i in range(0, 150, 50):
            st = swakde.swakde_commit_chunk(
                st, swakde.swakde_prepare_chunk(p, xs[i:i + 50], cfg), cfg)
        states.append(st)
    ref = swakde.swakde_stream(swakde.swakde_init(cfg0), p, xs, cfg0)
    for st in states:
        assert _states_equal(st, ref)


# ---------------------------------------------------------------------------
# S-ANN
# ---------------------------------------------------------------------------

def _sann_setup(n_max=2000, eta=0.25, slack=4.0, dim=8, seed=0):
    cfg = sann.SANNConfig(dim=dim, n_max=n_max, eta=eta, r=0.5, c=2.0,
                          L=4, k=2, capacity_slack=slack)
    return sann.sann_init(cfg, jax.random.PRNGKey(seed))


def test_sann_two_phase_bit_identical():
    cfg, p, st0 = _sann_setup()
    xs = jax.random.uniform(jax.random.PRNGKey(1), (500, 8))
    key = jax.random.PRNGKey(2)
    ref = sann.sann_insert_batch(st0, p, xs, key, cfg)
    prep = sann.sann_prepare_chunk(p, xs, key, cfg)
    st = sann.sann_commit_chunk(st0, prep, cfg)
    assert _states_equal(st, ref)


def test_sann_prepare_ahead_under_ring_wrap():
    """Chunked prepare-ahead with the ring lapping several times inside and
    across chunks: relative slot ranks rebased on the live write pointer
    must replay the sequential eviction/tombstone semantics exactly."""
    cfg, p, st0 = _sann_setup(n_max=300, eta=0.0, slack=0.1)
    assert cfg.capacity == 64
    xs = jax.random.uniform(jax.random.PRNGKey(3), (300, 8))
    ckeys = jax.random.split(jax.random.PRNGKey(4), 4)
    chunks = [xs[i:i + 75] for i in range(0, 300, 75)]
    preps = [sann.sann_prepare_chunk(p, c, k, cfg)
             for c, k in zip(chunks, ckeys)]
    st = st0
    ref = st0
    for c, k, prep in zip(chunks, ckeys, preps):
        ref = sann.sann_insert_stream(ref, p, c, k, cfg)
        st = sann.sann_commit_chunk(st, prep, cfg)
    assert _states_equal(st, ref)
    assert int(st.n_stored) == int(st.valid.sum()) == 64


def test_sann_two_phase_eviction_tombstones_stale_entries():
    """Regression guard carried over to the split path: after ring-wrap via
    prepare→commit, every surviving table entry points at a vector that
    actually hashes into that bucket."""
    cfg, p, st0 = _sann_setup(n_max=300, eta=0.0, slack=0.1, dim=4, seed=7)
    xs = jax.random.uniform(jax.random.PRNGKey(8), (300, 4))
    st = st0
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    for i, k in zip(range(0, 300, 100), keys):
        st = sann.sann_commit_chunk(
            st, sann.sann_prepare_chunk(p, xs[i:i + 100], k, cfg), cfg)
    codes_all = np.asarray(lsh.hash_points(p, st.points))      # (capacity, L)
    tables = np.asarray(st.tables)
    for l in range(cfg.L):
        tab = tables[l]                                        # (buckets, cap)
        mask = tab >= 0
        entry_codes = codes_all[np.maximum(tab, 0), l]
        expect = np.arange(tab.shape[0])[:, None]
        assert ((entry_codes == expect) | ~mask).all()
