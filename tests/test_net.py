"""RPC wire-framing failure modes (DESIGN.md §16): torn/truncated frames,
CRC corruption, oversized payloads, and protocol-version mismatches must
all fail LOUDLY with `ProtocolError` — never hang, never desync.

Mirrors the torn-tail style of tests/test_persist.py: craft the corrupt
bytes directly and assert the decoder refuses them.  Everything here is
in-process (socketpairs + scripted server threads) — no worker processes,
so the file stays in the tier-1 run.
"""
from __future__ import annotations

import socket
import struct
import threading
import time
import zlib

import numpy as np
import pytest

from repro.net import protocol as P


def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def _scripted_server(script):
    """Listener running ``script(conn)`` on the first connection in a
    daemon thread; returns the port."""
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]

    def run():
        conn, _ = lsock.accept()
        conn.settimeout(10.0)
        try:
            script(conn)
        except Exception:
            pass
        finally:
            try:
                conn.close()
            finally:
                lsock.close()

    threading.Thread(target=run, daemon=True).start()
    return port


def _reply_hello(conn, version=P.PROTOCOL_VERSION):
    mid, kind, _ = P.recv_msg(conn)
    assert kind == P.K_HELLO
    P.send_msg(conn, mid, P.K_OK,
               P.encode_body({"version": version, "session": "test"}))


# --- frame encode/decode -----------------------------------------------------

def test_roundtrip_meta_and_arrays():
    a, b = _pair()
    xs = np.arange(12, dtype=np.float32).reshape(3, 4)
    ids = np.array([-1, 7], dtype=np.int64)
    P.send_msg(a, 42, P.K_INGEST,
               P.encode_body({"kind": "topk", "n": 3},
                             {"xs": xs, "ids": ids}))
    mid, kind, body = P.recv_msg(b)
    assert (mid, kind) == (42, P.K_INGEST)
    meta, arrays = P.decode_body(body)
    assert meta == {"kind": "topk", "n": 3}
    assert arrays["xs"].dtype == np.float32
    np.testing.assert_array_equal(arrays["xs"], xs)
    np.testing.assert_array_equal(arrays["ids"], ids)
    a.close(), b.close()


def test_truncated_header_fails_loudly():
    a, b = _pair()
    a.sendall(b"\x31\x43")          # two bytes of magic, then peer dies
    a.close()
    with pytest.raises(P.ProtocolError, match="mid-header"):
        P.recv_msg(b)
    b.close()


def test_truncated_body_fails_loudly():
    a, b = _pair()
    body = P.encode_body({"x": 1})
    hdr = P._HEADER.pack(P._MAGIC, 1, P.K_OK, len(body) + 50,
                         zlib.crc32(body))
    a.sendall(hdr + body)           # 50 bytes short of the promise
    a.close()
    with pytest.raises(P.ProtocolError, match="mid-body"):
        P.recv_msg(b)
    b.close()


def test_bad_magic_fails_loudly():
    a, b = _pair()
    body = P.encode_body({})
    a.sendall(P._HEADER.pack(0xDEADBEEF, 1, P.K_OK, len(body),
                             zlib.crc32(body)) + body)
    with pytest.raises(P.ProtocolError, match="bad magic"):
        P.recv_msg(b)
    a.close(), b.close()


def test_crc_corruption_fails_loudly():
    a, b = _pair()
    body = bytearray(P.encode_body({"v": 123}))
    hdr = P._HEADER.pack(P._MAGIC, 9, P.K_OK, len(body), zlib.crc32(body))
    body[-2] ^= 0x40                # flip one bit after the CRC was taken
    a.sendall(hdr + bytes(body))
    with pytest.raises(P.ProtocolError, match="crc mismatch"):
        P.recv_msg(b)
    a.close(), b.close()


def test_oversized_body_len_rejected_before_read():
    # A corrupt/hostile length field is refused from the header alone —
    # no allocation, no attempt to read the (absent) payload.  A buggy
    # decoder would block here; the socket timeout turns that into a
    # visible failure instead of a hang.
    a, b = _pair()
    a.sendall(P._HEADER.pack(P._MAGIC, 1, P.K_OK, 1 << 30, 0))
    with pytest.raises(P.ProtocolError, match="oversized frame"):
        P.recv_msg(b, max_body=1 << 20)
    a.close(), b.close()


def test_send_oversized_body_rejected(monkeypatch):
    a, b = _pair()
    monkeypatch.setattr(P, "MAX_BODY", 64)
    with pytest.raises(P.ProtocolError, match="exceeds MAX_BODY"):
        P.send_msg(a, 1, P.K_OK, b"x" * 65)
    a.close(), b.close()


def test_decode_body_truncated_and_garbage():
    with pytest.raises(P.ProtocolError, match="truncated"):
        P.decode_body(b"\x01")                       # shorter than jlen
    with pytest.raises(P.ProtocolError, match="truncated"):
        P.decode_body(struct.pack("<I", 99) + b"{}")  # meta longer than body
    bad_json = struct.pack("<I", 3) + b"{{{"
    with pytest.raises(P.ProtocolError, match="not JSON"):
        P.decode_body(bad_json)
    good_meta = struct.pack("<I", 2) + b"{}"
    with pytest.raises(P.ProtocolError, match="not npz"):
        P.decode_body(good_meta + b"this is not a zip archive")


# --- channel handshake / lockstep -------------------------------------------

def test_version_mismatch_fails_loudly():
    port = _scripted_server(lambda c: _reply_hello(c, version=999))
    with pytest.raises(P.ProtocolError, match="version mismatch"):
        P.Channel("127.0.0.1", port, timeout_s=5.0)


def test_server_side_hello_check():
    with pytest.raises(P.ProtocolError, match="version mismatch"):
        P.check_hello({"version": 0})
    P.check_hello({"version": P.PROTOCOL_VERSION})   # no raise


def test_timeout_breaks_channel_and_fails_fast():
    stall = threading.Event()

    def script(conn):
        _reply_hello(conn)
        P.recv_msg(conn)            # swallow the next request ...
        stall.wait(10.0)            # ... and never reply

    port = _scripted_server(script)
    ch = P.Channel("127.0.0.1", port, timeout_s=5.0)
    t0 = time.monotonic()
    with pytest.raises(OSError):    # socket.timeout
        ch.call(P.K_FLUSH, timeout_s=0.3)
    assert time.monotonic() - t0 < 4.0
    assert ch.broken is not None
    # The late reply could still arrive; a broken channel must refuse to
    # pair it with the next request.
    with pytest.raises(P.ProtocolError, match="broken"):
        ch.call(P.K_FLUSH)
    stall.set()
    ch.close()


def test_desynced_reply_breaks_channel():
    def script(conn):
        _reply_hello(conn)
        mid, kind, _ = P.recv_msg(conn)
        P.send_msg(conn, mid + 7, P.K_OK, P.encode_body({}))

    port = _scripted_server(script)
    ch = P.Channel("127.0.0.1", port, timeout_s=5.0)
    with pytest.raises(P.ProtocolError, match="desynced reply"):
        ch.call(P.K_FLUSH)
    assert ch.broken is not None
    ch.close()


def test_remote_error_carries_failover_markers():
    def script(conn):
        _reply_hello(conn)
        mid, kind, _ = P.recv_msg(conn)
        P.send_msg(conn, mid, P.K_ERR, P.encode_body(
            {"error": "boom", "type": "ValueError", "transient": True,
             "wal_accepted": True}))
        try:
            P.recv_msg(conn)        # channel must still be usable
        except P.ProtocolError:
            pass

    port = _scripted_server(script)
    ch = P.Channel("127.0.0.1", port, timeout_s=5.0)
    with pytest.raises(P.RemoteError, match="boom") as ei:
        ch.call(P.K_FLUSH)
    assert ei.value.remote_type == "ValueError"
    assert ei.value.transient and ei.value.wal_accepted
    # a clean K_ERR reply is an application failure, not a wire failure
    assert ch.broken is None
    ch.close()
