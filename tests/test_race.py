"""RACE sketch invariants (paper §2.3, Theorems 2.3/2.4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh, race


def test_race_counts_conserve_mass():
    key = jax.random.PRNGKey(0)
    p = lsh.init_srp(key, 16, L=5, k=3, n_buckets=32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (200, 16))
    st = race.race_init(5, 32)
    st = race.race_update_batch(st, p, xs)
    # every row holds exactly one increment per element
    assert (np.asarray(st.counts).sum(axis=1) == 200).all()
    assert int(st.n) == 200


def test_race_turnstile_exact_cancellation():
    key = jax.random.PRNGKey(2)
    p = lsh.init_pstable(key, 16, L=4, k=2, w=4.0, n_buckets=64)
    xs = jax.random.normal(jax.random.PRNGKey(3), (50, 16))
    st_all = race.race_update_batch(race.race_init(4, 64), p, xs)
    st_del = race.race_update_batch(st_all, p, xs[:20], sign=-1)
    st_ref = race.race_update_batch(race.race_init(4, 64), p, xs[20:])
    assert (np.asarray(st_del.counts) == np.asarray(st_ref.counts)).all()
    assert int(st_del.n) == 30


def test_race_single_vs_batch_update_agree():
    key = jax.random.PRNGKey(4)
    p = lsh.init_srp(key, 8, L=3, k=2, n_buckets=16)
    xs = jax.random.normal(jax.random.PRNGKey(5), (32, 8))
    st_b = race.race_update_batch(race.race_init(3, 16), p, xs)

    def step(s, x):
        return race.race_update(s, p, x), None

    st_s, _ = jax.lax.scan(step, race.race_init(3, 16), xs)
    assert (np.asarray(st_b.counts) == np.asarray(st_s.counts)).all()


def test_ace_unbiasedness_theorem_2_3():
    """E[A[h(q)]] = sum_x k^p(x, q): average over many independent sketches
    approaches the collision-kernel KDE (+ n/W fold-collision bias)."""
    d, n, L, k, W = 16, 64, 64, 2, 256
    key = jax.random.PRNGKey(6)
    xs = jax.random.normal(jax.random.PRNGKey(7), (n, d))
    q = xs[0] + 0.3 * jax.random.normal(jax.random.PRNGKey(8), (d,))

    # L independent rows of an SRP sketch = L independent ACE estimators.
    p = lsh.init_srp(key, d, L=L, k=k, n_buckets=W)
    st = race.race_update_batch(race.race_init(L, W), p, xs)
    codes = lsh.srp_hash(p, q[None])[0]
    est = float(np.asarray(st.counts)[np.arange(L), np.asarray(codes)].mean())

    kernel = float(jax.vmap(lambda x: lsh.srp_collision_prob(x, q, p=k))(xs).sum())
    bias = n / W  # chance fold collisions
    assert abs(est - kernel) < 0.35 * kernel + 3 * bias, (est, kernel, bias)


def test_race_query_median_of_means_close_to_mean():
    key = jax.random.PRNGKey(9)
    p = lsh.init_srp(key, 8, L=20, k=2, n_buckets=64)
    xs = jax.random.normal(jax.random.PRNGKey(10), (128, 8))
    st = race.race_update_batch(race.race_init(20, 64), p, xs)
    q = xs[3]
    mean = float(race.race_query(st, p, q))
    mom = float(race.race_query(st, p, q, median_of_means=4))
    assert abs(mean - mom) <= 0.5 * mean + 1
