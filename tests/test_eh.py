"""Exponential Histogram invariants (paper §2.4, [DGIM02])."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import eh


def _run_stream(bits, window, eps):
    cfg = eh.EHConfig.create(window=window, eps=eps)
    state = eh.eh_init(cfg)
    bits_arr = jnp.asarray(bits, jnp.int32)

    def step(s, tb):
        t, b = tb
        return eh.eh_step(s, t, b, cfg), None

    ts = jnp.arange(len(bits), dtype=jnp.int32)
    state, _ = jax.lax.scan(step, state, (ts, bits_arr))
    est = float(eh.eh_query(state, jnp.int32(len(bits) - 1), cfg))
    exact = int(np.asarray(bits)[max(0, len(bits) - window):].sum())
    return cfg, state, est, exact


@given(
    bits=st.lists(st.integers(0, 1), min_size=1, max_size=300),
    window=st.sampled_from([16, 64, 100]),
    eps=st.sampled_from([0.1, 0.2, 0.5]),
)
def test_eh_relative_error_bound(bits, window, eps):
    """DGIM guarantee: relative error <= eps (+1 for integer halving)."""
    _, _, est, exact = _run_stream(bits, window, eps)
    assert est <= (1 + eps) * exact + 1
    assert est >= (1 - eps) * exact - 1
    if exact == 0:
        assert est == 0


@given(
    bits=st.lists(st.integers(0, 1), min_size=50, max_size=300),
    window=st.sampled_from([32, 128]),
)
def test_eh_space_bound(bits, window):
    """Live buckets never exceed the paper's (k/2+1)(log(2N/k)+1)+1 bound."""
    cfg, state, _, _ = _run_stream(bits, window, 0.2)
    assert int(state.num.sum()) <= eh.eh_exact_upper(cfg)


def test_eh_all_ones_dense():
    bits = [1] * 250
    _, _, est, exact = _run_stream(bits, 100, 0.1)
    assert exact == 100
    assert abs(est - exact) <= 0.1 * exact + 1


def test_eh_expiry_to_zero():
    """A burst followed by silence must decay to zero estimate."""
    bits = [1] * 50 + [0] * 200
    _, _, est, exact = _run_stream(bits, 64, 0.1)
    assert exact == 0 and est == 0


def test_eh_timestamps_sorted_within_level():
    """Internal invariant: per-level rings are newest-first."""
    cfg, state, _, _ = _run_stream([1] * 200, 128, 0.1)
    ts, num = np.asarray(state.ts), np.asarray(state.num)
    for lvl in range(cfg.levels):
        live = ts[lvl, : num[lvl]]
        assert (np.diff(live) <= 0).all(), (lvl, live)


# ---------------------------------------------------------------------------
# Closed-form eh_add vs the scan-based oracle
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    window=st.sampled_from([16, 64, 100]),
    eps=st.sampled_from([0.1, 0.2, 0.5]),
)
@settings(max_examples=40, deadline=None)
def test_eh_add_closed_form_matches_ref(seed, window, eps):
    """`eh_add` (closed-form carry count) is bitwise identical to
    `eh_add_ref` (the scan cascade) — dead ring slots included — over
    gappy streams that exercise carries, ring wrap and expiry."""
    cfg = eh.EHConfig.create(window=window, eps=eps)
    rng = np.random.default_rng(seed)
    gaps = rng.integers(0, max(2, window // 4), 120)
    ts = np.cumsum(gaps).astype(np.int32)
    a = b = eh.eh_init(cfg)
    for t in ts:
        t = jnp.int32(t)
        a = eh.eh_add(a, t, cfg)
        b = eh.eh_add_ref(b, t, cfg)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# SumEH (batch updates, Corollary 4.2)
# ---------------------------------------------------------------------------

def _run_sum_stream(vals, window, eps, R):
    cfg = eh.SumEHConfig.create(window=window, eps=eps, batch_max=R)
    state = eh.sum_eh_init(cfg)

    def step(s, tv):
        t, v = tv
        return eh.sum_eh_add(s, t, v, cfg), None

    ts = jnp.arange(len(vals), dtype=jnp.int32)
    state, _ = jax.lax.scan(step, state, (ts, jnp.asarray(vals, jnp.int32)))
    est = float(eh.sum_eh_query(state, jnp.int32(len(vals) - 1), cfg))
    exact = int(np.asarray(vals)[max(0, len(vals) - window):].sum())
    return cfg, state, est, exact


@given(
    vals=st.lists(st.integers(0, 8), min_size=1, max_size=200),
    window=st.sampled_from([16, 64]),
    eps=st.sampled_from([0.1, 0.25]),
)
def test_sum_eh_error_bound(vals, window, eps):
    cfg, state, est, exact = _run_sum_stream(vals, window, eps, R=8)
    # Sum-EH guarantee is relative eps; allow +R slack for the boundary batch.
    assert est <= (1 + eps) * exact + 8
    assert est >= (1 - eps) * exact - 8
    assert int(state.num.sum()) <= cfg.max_buckets


def test_sum_eh_matches_binary_on_01():
    """On 0/1 streams SumEH and EH answer the same query."""
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, 250).tolist()
    _, _, est_b, exact = _run_stream(bits, 64, 0.1)
    _, _, est_s, exact_s = _run_sum_stream(bits, 64, 0.1, R=1)
    assert exact == exact_s
    assert abs(est_b - exact) <= 0.1 * exact + 1
    assert abs(est_s - exact) <= 0.1 * exact + 1
