"""Trainer fault tolerance: resume-after-failure, straggler flags, drift."""
import json
import pathlib
import shutil

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, DriftMonitor, TokenStream
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig


def _tiny_setup(tmp, steps, ckpt_every=4):
    mcfg = registry.get_smoke_config("qwen3-4b")
    dcfg = DataConfig(vocab=mcfg.vocab, batch=2, seq=32, seed=3)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp), optimizer="adamw", lr=1e-3,
                         log_every=100, monitor_drift=False)
    return mcfg, dcfg, tcfg


def test_loss_decreases(tmp_path):
    mcfg, dcfg, tcfg = _tiny_setup(tmp_path / "a", steps=12)
    out = Trainer(mcfg, dcfg, tcfg, log_fn=lambda s: None).run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_failure_recovery_bit_exact(tmp_path):
    """Kill after 6 steps; resume must reproduce the uninterrupted run."""
    key = jax.random.PRNGKey(42)

    # uninterrupted reference
    mcfg, dcfg, tcfg = _tiny_setup(tmp_path / "ref", steps=10, ckpt_every=3)
    ref = Trainer(mcfg, dcfg, tcfg, log_fn=lambda s: None).run(key)

    # interrupted run: stop at 6 (checkpoint lands at 6)
    mcfg, dcfg, tcfg = _tiny_setup(tmp_path / "int", steps=6, ckpt_every=3)
    Trainer(mcfg, dcfg, tcfg, log_fn=lambda s: None).run(key)
    # "restart the job": fresh Trainer with target steps=10 resumes from 6
    mcfg, dcfg, tcfg = _tiny_setup(tmp_path / "int", steps=10, ckpt_every=3)
    res = Trainer(mcfg, dcfg, tcfg, log_fn=lambda s: None).run(key)

    ref_leaves = jax.tree.leaves(ref["state"].params)
    res_leaves = jax.tree.leaves(res["state"].params)
    for a, b in zip(ref_leaves, res_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(ref["state"].step) == int(res["state"].step) == 10


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(k=6.0, min_history=8)
    flags = [m.observe(0.1 + 0.001 * i) for i in range(20)]
    assert not any(flags)
    assert m.observe(1.5)          # 15x median → flagged
    assert not m.observe(0.1)


def test_drift_monitor_detects_shift():
    dcfg = DataConfig(vocab=1024, batch=8, seq=64, seed=0, drift_window=64,
                      drift_rows=8, drift_width=64)
    mon = DriftMonitor(dcfg)
    stream = TokenStream(dcfg, drift_at=30)
    flags = []
    for i in range(45):
        flags.append(mon.observe(stream.next_batch()))
    pre = [f["drift"] for f in flags[10:30]]
    post = [f["drift"] for f in flags[30:38]]
    assert not any(pre), "false positives before the shift"
    assert any(post), "distribution shift not detected"


def test_data_stream_deterministic_and_sharded():
    d0 = DataConfig(vocab=128, batch=2, seq=16, seed=1, n_shards=2, shard_id=0)
    d1 = DataConfig(vocab=128, batch=2, seq=16, seed=1, n_shards=2, shard_id=1)
    a1, a2 = TokenStream(d0), TokenStream(d0)
    b = TokenStream(d1)
    x1, x2, y = a1.next_batch(), a2.next_batch(), b.next_batch()
    np.testing.assert_array_equal(x1, x2)     # deterministic
    assert (x1 != y).any()                     # shards differ


def test_microbatched_grads_match_full_batch():
    """grad accumulation (grad_microbatches=4) must reproduce the full-batch
    step (fp32 accumulation, same data)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.launch.inputs import make_batch
    from repro.optim.adam import adamw
    from repro.train.train_step import make_train_step

    base = dataclasses.replace(registry.get_smoke_config("qwen3-4b"),
                               param_dtype="float32")
    batch = make_batch(base, batch=8, seq=32, key=jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    outs = {}
    for n_micro in (1, 4):
        cfg = dataclasses.replace(base, grad_microbatches=n_micro)
        step, init = make_train_step(cfg, adamw(lr=1e-3))
        st = init(key)
        st, m = jax.jit(step)(st, batch)
        outs[n_micro] = (st.params, float(m["loss"]))

    # losses are per-microbatch means vs full mean over the same tokens
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)
