"""Decode-vs-forward consistency: serving one token at a time must reproduce
the parallel (train/prefill) forward pass logits at every position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.inputs import make_batch
from repro.models import model as model_lib
from repro.serve import kv_cache, serve_step as serve_lib

T = 12


def _decode_all(cfg, params, cache, tokens, step_fn):
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = step_fn(params, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-27b", "gemma3-4b",
                                  "deepseek-moe-16b", "deepseek-v3-671b",
                                  "zamba2-2.7b", "xlstm-125m"])
def test_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.n_experts:
        # (a) match decode's relaxed expert capacity: the train path *drops*
        # over-capacity tokens at cf=1.25, decode never should — use the
        # dropless regime on both sides for the consistency check;
        # (b) fp32: bf16 rounding differences between the two paths flip
        # discrete top-k routing decisions (semantically both are valid).
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  param_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = model_lib.init_model(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=2, seq=T, key=key)
    fwd_logits, _ = jax.jit(lambda p, b: model_lib.forward(p, cfg, b))(params, batch)

    cache = kv_cache.init_cache(cfg, B=2, s_max=T)
    step_fn = jax.jit(serve_lib.make_serve_step(cfg))
    dec_logits, cache = _decode_all(cfg, params, cache, batch["tokens"], step_fn)

    err = float(jnp.abs(dec_logits - fwd_logits).max())
    scale = float(jnp.abs(fwd_logits).max())
    assert err < 3e-2 * max(scale, 1.0), (arch, err, scale)  # bf16 cache roundtrip
    assert int(cache["length"]) == T


def test_decode_matches_forward_encdec():
    cfg = registry.get_smoke_config("whisper-large-v3")
    params = model_lib.init_model(cfg, jax.random.PRNGKey(2))
    batch = make_batch(cfg, batch=2, seq=T, key=jax.random.PRNGKey(3))
    fwd_logits, _ = jax.jit(lambda p, b: model_lib.forward(p, cfg, b))(params, batch)

    cache = kv_cache.init_cache(cfg, B=2, s_max=T)
    cache = serve_lib.encode_cross_cache(params, cfg, batch["frames"], cache)
    step_fn = jax.jit(serve_lib.make_serve_step(cfg))
    dec_logits, _ = _decode_all(cfg, params, cache, batch["tokens"], step_fn)

    err = float(jnp.abs(dec_logits - fwd_logits).max())
    scale = float(jnp.abs(fwd_logits).max())
    assert err < 2e-2 * max(scale, 1.0), (err, scale)


def test_sketch_decode_runs_and_prunes():
    """Sketch attention must (a) run, (b) equal plain decode when every block
    collides, (c) actually prune something on adversarial keys."""
    cfg = registry.get_smoke_config("gemma3-4b")
    params = model_lib.init_model(cfg, jax.random.PRNGKey(4))
    B, S = 2, kv_cache.SKETCH_BLOCK * 2  # 2 sketch blocks
    cache = kv_cache.init_cache(cfg, B=B, s_max=S, sketch=True)
    assert "block_sigs" in cache
    step = jax.jit(serve_lib.make_serve_step(cfg, sketch=True))
    tok = jax.random.randint(jax.random.PRNGKey(5), (B, 4), 0, cfg.vocab)
    logits = None
    for t in range(4):
        logits, cache = step(params, cache, tok[:, t:t + 1])
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["length"]) == 4
    # signatures accumulated into block 0
    assert bool(cache["block_sigs"][:, :, 0].any())
