"""Merge-based multi-worker cluster runtime (repro.serve.cluster):

  * hash partitioning is deterministic, order-independent and covering;
  * RACE cluster estimates are bit-exact vs a single engine (counter sums
    are exact) for K in {2, 4};
  * SW-AKDE cluster estimates are bit-exact vs a single engine while the
    window holds everything (the DGIM bucket-union of a partition
    canonicalises to the sequential structure until expiry);
  * S-ANN cluster queries equal a single engine fed the cluster's fixed
    logical-time interleaving (indices exact, distances <= 1e-5) — and the
    full state matches bitwise except the per-stream stamp clocks;
  * the merge cadence: a huge ``merge_every`` still serves every commit
    (query-time tail merge), a tiny one pre-merges — identical answers;
  * per-worker durability: a durable cluster recovers bit-identically.
"""
import numpy as np

import jax

from repro.serve.cluster import (
    ClusterKDEService, ClusterRACEService, ClusterRetrievalService,
    hash_partition,
)
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

_RACE_KW = dict(dim=8, L=6, W=32, ingest_chunk=64, seed=3)
# window >= stream: nothing expires -> the merged EH structure (and so the
# estimates) is bit-exact vs single-engine; expiry degrades to the usual
# estimate-level EH-merge guarantee (see test_distributed merge tests).
_KDE_KW = dict(dim=8, L=6, W=32, window=100_000, eh_eps=0.2, ingest_chunk=50)
# eta=0: keep prob n^0 = 1, so keep decisions are key-independent and the
# single-engine reference makes the same ones; stream < capacity, so no
# union eviction and slot ids line up exactly.
_SANN_KW = dict(dim=8, n_max=100, eta=0.0, r=0.4, c=2.0, w=1.0, L=6, k=3,
                ingest_chunk=64)


def _data(n=500, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


def _interleave(data: np.ndarray, K: int) -> np.ndarray:
    """The cluster's canonical logical-time order: sort the union by
    (per-worker local index, worker id) — what stamp-sorted fold-left
    merging reconstructs."""
    pid = hash_partition(data, K)
    locals_ = [[i for i in range(len(data)) if pid[i] == w]
               for w in range(K)]
    order = []
    for j in range(max(len(l) for l in locals_)):
        for w in range(K):
            if j < len(locals_[w]):
                order.append(locals_[w][j])
    return data[order]


def test_hash_partition_deterministic_covering_balanced():
    data = _data(n=2000, seed=1)
    pid = hash_partition(data, 4)
    np.testing.assert_array_equal(pid, hash_partition(data, 4))  # stable
    # order-independence: a permuted stream partitions row-for-row equally
    perm = np.random.default_rng(0).permutation(len(data))
    np.testing.assert_array_equal(pid[perm], hash_partition(data[perm], 4))
    assert pid.min() >= 0 and pid.max() < 4
    counts = np.bincount(pid, minlength=4)
    assert counts.sum() == len(data)
    assert counts.min() > len(data) // 10, f"badly skewed: {counts}"
    assert (hash_partition(data, 1) == 0).all()


def test_cluster_race_bit_exact_vs_single_engine():
    data = _data(seed=2)
    qs = data[:7] + 0.01
    for K in (2, 4):
        single = RACEService(RACEServiceConfig(**_RACE_KW))
        single.ingest(data)
        cl = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                                num_workers=K, merge_every=4)
        cl.ingest(data)
        np.testing.assert_array_equal(cl.query(qs), single.query(qs))
        np.testing.assert_array_equal(cl.kde(qs), single.kde(qs))
        assert cl.count == single.count == len(data)
        # turnstile deletes route to the hash owner and stay exact
        cl.delete(data[:5])
        single.delete(data[:5])
        np.testing.assert_array_equal(cl.query(qs), single.query(qs))
        cl.close()
        single.close()


def test_cluster_kde_bit_exact_vs_single_engine_no_expiry():
    data = _data(seed=3)
    qs = data[:7] + 0.01
    single = KDEService(KDEServiceConfig(**_KDE_KW))
    single.ingest(data)
    for K in (2, 4):
        cl = ClusterKDEService(KDEServiceConfig(**_KDE_KW),
                               num_workers=K, merge_every=4)
        cl.ingest(data)
        np.testing.assert_array_equal(cl.query(qs), single.query(qs))
        assert cl.steps == len(data)
        # normalised density uses the summed worker clocks
        np.testing.assert_allclose(
            cl.density(qs),
            np.asarray(single.query(qs)) / len(data), rtol=1e-6)
        cl.close()
    single.close()


def test_cluster_kde_grid_cache_used_and_invalidated():
    """cfg.cache_grid applies to the merged sketch too: the (L, W) grid
    table is built at most once per merged version (counted via the worker
    grid fn), any new merge invalidates it, and cached reads are bitwise
    equal to the uncached fused path."""
    data = _data(n=300, seed=10)
    qs = data[:5] + 0.01
    cached = ClusterKDEService(KDEServiceConfig(**_KDE_KW), num_workers=2,
                               merge_every=1)
    uncached = ClusterKDEService(
        KDEServiceConfig(**_KDE_KW, cache_grid=False), num_workers=2,
        merge_every=1)
    calls = []
    orig = cached.workers[0]._grid_fn
    cached.workers[0]._grid_fn = lambda st: (calls.append(1), orig(st))[1]

    cached.ingest(data[:200])
    uncached.ingest(data[:200])
    q1 = cached.query(qs)
    cached.query(qs)
    assert len(calls) == 1              # second batch served from the cache
    np.testing.assert_array_equal(q1, uncached.query(qs))

    cached.ingest(data[200:])           # new commits -> new merge -> stale
    uncached.ingest(data[200:])
    q2 = cached.query(qs)
    assert len(calls) == 2, "stale merged grid served after new commits"
    np.testing.assert_array_equal(q2, uncached.query(qs))
    assert not np.array_equal(q1, q2)
    cached.close()
    uncached.close()


def test_cluster_kde_density_denominator_is_summed_coverage():
    """Worker windows tick on local clocks, so the density denominator is
    the *sum of per-worker* min(t_w, window) — not min(sum t_w, window),
    which overestimates density by up to K once clocks pass the window."""
    kw = dict(_KDE_KW, window=300)
    data = _data(n=400, seed=9)
    qs = data[:5] + 0.01
    cl = ClusterKDEService(KDEServiceConfig(**kw), num_workers=2,
                           merge_every=1)
    cl.ingest(data)
    coverage = sum(min(w.steps, kw["window"]) for w in cl.workers)
    assert coverage == 400          # no worker clock reached the window
    np.testing.assert_allclose(cl.density(qs),
                               np.asarray(cl.query(qs)) / coverage,
                               rtol=1e-6)
    cl.close()


def test_cluster_sann_matches_single_engine_fixed_interleaving():
    data = _data(n=300, seed=4)
    qs = data[:9] + 0.01
    for K in (2, 4):
        cl = ClusterRetrievalService(RetrievalConfig(**_SANN_KW),
                                     num_workers=K, merge_every=4)
        cl.ingest(data)
        ref = RetrievalService(RetrievalConfig(**_SANN_KW))
        ref.ingest(_interleave(data, K))

        res_c = cl.query(qs)
        res_r = ref.query(qs)
        np.testing.assert_array_equal(np.asarray(res_c.index),
                                      np.asarray(res_r.index))
        np.testing.assert_allclose(np.asarray(res_c.distance),
                                   np.asarray(res_r.distance), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(res_c.found),
                                      np.asarray(res_r.found))
        # the merged state *is* the single-engine state, modulo the stamp
        # clocks (per-worker local time vs global interleaved time)
        merged = cl.merged_state()
        for name, (u, v) in zip(merged._fields, zip(merged, ref.state)):
            if name == "stamps":
                continue
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=f"field {name!r}")
        assert cl.stored == int(ref.state.n_stored)
        cl.close()
        ref.close()


def test_cluster_merge_cadence_query_time_tail():
    data = _data(n=400, seed=5)
    qs = data[:6] + 0.01
    # merge_every huge: the coordinator never pre-merges — queries must
    # still cover every commit via the query-time tail merge.
    lazy = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                              num_workers=2, merge_every=10_000)
    eager = ClusterRACEService(RACEServiceConfig(**_RACE_KW),
                               num_workers=2, merge_every=1)
    lazy.ingest(data)
    eager.ingest(data)
    assert lazy._merged_versions is None        # cadence never fired
    assert eager._merged_versions is not None   # pre-merged during ingest
    np.testing.assert_array_equal(lazy.query(qs), eager.query(qs))
    # after the query-time merge the cache is fresh until new commits
    assert lazy._merged_versions == lazy.versions
    lazy.close()
    eager.close()


def test_cluster_async_flush_matches_sync():
    data = _data(n=400, seed=6)
    qs = data[:6] + 0.01
    a = ClusterKDEService(KDEServiceConfig(**_KDE_KW), num_workers=2)
    b = ClusterKDEService(KDEServiceConfig(**_KDE_KW), num_workers=2)
    a.ingest(data)
    b.ingest_async(data)
    b.flush()
    for wa, wb in zip(a.workers, b.workers):
        for x, y in zip(jax.tree.leaves(wa.state), jax.tree.leaves(wb.state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(a.query(qs), b.query(qs))
    a.close()
    b.close()


def test_cluster_sampled_eta_internal_consistency():
    """eta > 0: workers sample independently (salted keys); the merged
    sketch must stay internally consistent — tables reference only live
    slots, n_stored matches the valid mask, and answers come from stored
    points."""
    kw = dict(_SANN_KW, eta=0.3, n_max=1000)
    data = _data(n=600, seed=7)
    cl = ClusterRetrievalService(RetrievalConfig(**kw), num_workers=2,
                                 merge_every=2)
    cl.ingest(data)
    st = cl.merged_state()
    tables = np.asarray(st.tables)
    valid = np.asarray(st.valid)
    live_refs = tables[tables >= 0]
    assert valid[live_refs].all(), "table entry points at a dead slot"
    assert int(st.n_stored) == int(valid.sum())
    # distinct salts -> workers made different keep decisions
    k0 = int(np.asarray(cl.workers[0].state.n_stored))
    k1 = int(np.asarray(cl.workers[1].state.n_stored))
    assert k0 > 0 and k1 > 0
    res = cl.query(data[:20] + 0.01)
    found = np.asarray(res.found)
    assert found.any()
    assert (np.asarray(res.distance)[found] <= kw["c"] * kw["r"] + 1e-5).all()
    cl.close()


def test_cluster_ingest_with_max_pending_matches_unbounded():
    """Admission control on the workers composes with the round-robin
    cluster submission: tiny per-worker bounds change only the pacing,
    never the per-worker chunk boundaries or the merged answers."""
    data = _data(n=400, seed=11)
    qs = data[:6] + 0.01
    chunk = _RACE_KW["ingest_chunk"]
    bounded = ClusterRACEService(
        RACEServiceConfig(**_RACE_KW, max_pending=chunk), num_workers=2)
    free = ClusterRACEService(RACEServiceConfig(**_RACE_KW), num_workers=2)
    bounded.ingest(data)
    free.ingest(data)
    np.testing.assert_array_equal(bounded.query(qs), free.query(qs))
    for wa, wb in zip(bounded.workers, free.workers):
        for x, y in zip(jax.tree.leaves(wa.state), jax.tree.leaves(wb.state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    bounded.close()
    free.close()


def test_cluster_sann_delete_reaches_near_copies_on_any_worker():
    """sann_delete is tolerance-based, but hash ownership is per bit
    pattern: a within-tol near-copy can live on any worker, so the cluster
    broadcast-deletes.  A perturbed delete value must still tombstone the
    stored original, exactly like the single-engine service."""
    kw = dict(_SANN_KW, n_max=1000)
    data = _data(n=200, seed=13)
    cl = ClusterRetrievalService(RetrievalConfig(**kw), num_workers=2,
                                 merge_every=1)
    cl.ingest(data)
    # different float bits (different hash owner with prob 1/2), within tol
    near = data[0] + np.float32(1e-7)
    assert not np.array_equal(near.view(np.uint32), data[0].view(np.uint32))
    before = int(cl.merged_state().n_stored)
    cl.delete(near)
    assert int(cl.merged_state().n_stored) == before - 1
    res = cl.query(data[:1])
    assert (float(np.asarray(res.distance)[0]) > 1e-4
            or not bool(np.asarray(res.found)[0]))
    cl.close()


def test_cluster_dir_pins_worker_count(tmp_path):
    """Reopening a durable cluster directory with a different worker count
    must fail loudly: hash ownership depends on the count, so a quiet
    reopen would drop the missing workers' data."""
    import pytest
    kw = RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path))
    cl = ClusterRACEService(kw, num_workers=4)
    cl.ingest(_data(n=100, seed=12))
    cl.close()
    with pytest.raises(RuntimeError, match="num_workers=4"):
        ClusterRACEService(kw, num_workers=2)
    reopened = ClusterRACEService(kw, num_workers=4)   # same count is fine
    assert reopened.recover() >= 0
    reopened.close()
    # single engine <-> cluster reuse of one directory is refused both ways
    with pytest.raises(RuntimeError, match="cluster durability directory"):
        RACEService(kw)
    single_dir = tmp_path / "single"
    solo = RACEService(RACEServiceConfig(**_RACE_KW,
                                         snapshot_dir=str(single_dir)))
    solo.ingest(_data(n=64, seed=13))
    solo.close()
    with pytest.raises(RuntimeError, match="single-engine durable state"):
        ClusterRACEService(
            RACEServiceConfig(**_RACE_KW, snapshot_dir=str(single_dir)),
            num_workers=2)


def test_cluster_recovery_bit_identity(tmp_path):
    data = _data(n=400, seed=8)
    qs = data[:6] + 0.01
    kw = RACEServiceConfig(**_RACE_KW, snapshot_dir=str(tmp_path),
                           snapshot_every=2)
    cl = ClusterRACEService(kw, num_workers=2, merge_every=4)
    cl.ingest(data)
    before = cl.query(qs)
    states = [w.state for w in cl.workers]
    cl.close()                      # "crash": WAL + snapshots on disk

    rec = ClusterRACEService(kw, num_workers=2, merge_every=4)
    rec.recover()
    for w, st in zip(rec.workers, states):
        for x, y in zip(jax.tree.leaves(w.state), jax.tree.leaves(st)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(rec.query(qs), before)
    rec.close()


def test_cluster_close_aggregates_all_worker_failures():
    """A failing worker close must not mask the others: every worker is
    still closed, ALL failures are reported in one exception naming the
    failed workers, and a retry after the partial failure is clean
    (idempotent close)."""
    import pytest

    svc = ClusterRACEService(RACEServiceConfig(**_RACE_KW), num_workers=3,
                             merge_every=4)
    svc.ingest(_data(n=100, seed=9))

    failed_once = {}

    def bomb(w):
        orig = svc.workers[w].close

        def c():
            if w not in failed_once:
                failed_once[w] = True
                raise OSError(f"worker {w} fd leak")
            orig()
        svc.workers[w].close = c

    bomb(0)
    bomb(2)
    with pytest.raises(RuntimeError) as ei:
        svc.close()
    msg = str(ei.value)
    assert "worker_0" in msg and "worker_2" in msg and "2 worker(s)" in msg
    assert isinstance(ei.value.__cause__, OSError)
    assert svc.workers[1]._closed, "healthy worker must still be closed"
    svc.close()                       # retry closes the stragglers cleanly
    assert all(w._closed for w in svc.workers)


def test_cluster_race_delta_merge_bitexact():
    """Incremental coordinator merges: after the first full fold, each
    refresh folds only the per-worker counter DELTAS since the last merge
    — int32 addition is associative/commutative (wrap included), so the
    result must equal the full `race_merge` fold bitwise, every round."""
    import functools

    from repro.core import race

    svc = ClusterRACEService(RACEServiceConfig(**_RACE_KW), num_workers=3,
                             merge_every=1)
    data = _data(n=500, seed=4)
    for i in range(5):
        svc.ingest(data[i * 100:(i + 1) * 100])
        merged = svc.merged_state()
        full = functools.reduce(
            race.race_merge, [w.snapshot()[0] for w in svc.workers])
        np.testing.assert_array_equal(np.asarray(merged.counts),
                                      np.asarray(full.counts))
        assert int(merged.n) == int(full.n)
    counters = svc.stats()["counters"]
    assert counters["delta_merges"] >= 3, counters
    assert counters["full_merges"] >= 1, counters

    # base invalidation (live-set / epoch change stands in): the next
    # refresh falls back to a FULL fold and still matches
    svc._delta_base = None
    full_before = counters["full_merges"]
    svc.ingest(data[:100])
    merged = svc.merged_state()
    full = functools.reduce(
        race.race_merge, [w.snapshot()[0] for w in svc.workers])
    np.testing.assert_array_equal(np.asarray(merged.counts),
                                  np.asarray(full.counts))
    assert svc.stats()["counters"]["full_merges"] == full_before + 1
    svc.close()


# Exact-EH regime: eh_eps=0.01 keeps every (row, cell) EH exact as long as
# no cell holds more than ~k/2 same-size buckets — normal data + k=4 SRP
# spreads the 100-point window to ~6 points/cell, far inside the bound, so
# cluster-vs-single comparisons below can demand bitwise equality.
_GC_KW = dict(dim=8, L=6, W=32, window=100, eh_eps=0.01, k=4,
              ingest_chunk=50, seed=5)


def _gauss(n, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def test_cluster_kde_global_clock_expiry_exact():
    """Global-clock cluster windows expire in STREAM time: fed per-point
    (timestamps == global positions), densities equal a single
    global-window engine bitwise both before AND after expiry; the
    local-clock cluster over-retains once the window saturates
    (eh_eps=0.01 keeps every EH bucket exact, so equality is exact)."""
    data = _gauss(150, seed=6)
    qs = _gauss(16, seed=7)
    single = KDEService(KDEServiceConfig(**_GC_KW))
    gc = ClusterKDEService(KDEServiceConfig(**_GC_KW), num_workers=3,
                           merge_every=1, global_clock=True)
    lc = ClusterKDEService(KDEServiceConfig(**_GC_KW), num_workers=3,
                           merge_every=1)
    for i in range(80):                   # pre-expiry (t <= window)
        for s in (single, gc, lc):
            s.ingest(data[i:i + 1])
    np.testing.assert_array_equal(gc.density(qs), single.density(qs))
    np.testing.assert_array_equal(lc.density(qs), single.density(qs))
    for i in range(80, 150):              # 50 points expire
        for s in (single, gc, lc):
            s.ingest(data[i:i + 1])
    np.testing.assert_array_equal(gc.density(qs), single.density(qs))
    assert not np.array_equal(lc.density(qs), single.density(qs)), \
        "local clocks (max t ~ 50 < window) must over-retain"
    assert gc.steps == single.steps == 150
    assert all(w.steps == 150 for w in gc.workers)
    single.close(); gc.close(); lc.close()


def test_kde_advance_clock_wal_recovery(tmp_path):
    """`advance_clock` is WAL-logged (KIND_CLOCK) between chunk records
    and replays in order: recovery reproduces the advanced clock and the
    post-advance densities bitwise."""
    kw = dict(_GC_KW, snapshot_dir=str(tmp_path), snapshot_every=1000)
    data = _gauss(90, seed=8)
    qs = _gauss(12, seed=9)
    svc = KDEService(KDEServiceConfig(**kw))
    svc.ingest(data[:60])
    svc.advance_clock(130)                # expires most of the first 60
    svc.ingest(data[60:])
    ref, steps = svc.density(qs), svc.steps
    assert steps == 160                   # max-monotone: 130 + 30
    svc.close()

    rec = KDEService(KDEServiceConfig(**kw))
    assert rec.recover() > 0
    assert rec.steps == steps
    np.testing.assert_array_equal(rec.density(qs), ref)
    # monotone: advancing backwards is a no-op
    rec.advance_clock(10)
    assert rec.steps == steps
    rec.close()


def test_cluster_kde_global_clock_durable_recovery(tmp_path):
    """A durable global-clock cluster recovers bit-identically: per-worker
    WALs interleave chunk and KIND_CLOCK records, and the coordinator's
    logical clock is restored from the replayed worker clocks."""
    cfg = KDEServiceConfig(**dict(_GC_KW, snapshot_dir=str(tmp_path),
                                  snapshot_every=1000))
    data = _gauss(120, seed=10)
    qs = _gauss(10, seed=11)
    svc = ClusterKDEService(cfg, num_workers=2, merge_every=1,
                            global_clock=True)
    for i in range(0, 120, 10):
        svc.ingest(data[i:i + 10])
    before, steps = svc.density(qs), svc.steps
    svc.close()

    rec = ClusterKDEService(cfg, num_workers=2, merge_every=1,
                            global_clock=True)
    assert rec.recover() > 0
    assert rec.steps == steps and rec._global_steps == steps
    np.testing.assert_array_equal(rec.density(qs), before)
    rec.close()
