"""Cross-request query micro-batching (repro.serve.engine.QueryBatcher +
_BatchedQueryMixin, DESIGN.md §13):

  * coalescing bit-identity — N concurrent clients through the admission
    scheduler get exactly the answers sequential per-query calls produce,
    for all three sketches, with mixed query kinds in one tick and with
    coalesced sizes that exercise the query_block remainder path;
  * snapshot consistency under churn — queries coalesced while a
    background ingest commits always read ONE committed prefix of the
    stream (never a torn state), and the SW-AKDE grid cache is never
    stale for a coalesced batch;
  * scheduler properties — seeded-rng fuzz of the pure `batch_plan`
    policy inside a simulated event loop (latency budget honored up to
    one in-flight tick, max_batch never exceeded except by a lone
    oversized request, FIFO/no starvation), plus thread-level checks
    that a slow query delays later arrivals by at most one execute and
    that empty (B=0) requests never force a fused tick;
  * lifecycle — close() drains (or fails, drain=False) every pending
    future and rejects new work; engine close() leaves sync queries on
    the direct path;
  * cluster — the coordinator pays one merged snapshot per tick, not one
    per concurrent client.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.cluster import ClusterKDEService
from repro.serve.engine import QueryBatcher, batch_plan
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.race_service import RACEService, RACEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

_RETR_KW = dict(dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=6, k=3,
                ingest_chunk=64)
_KDE_KW = dict(dim=8, L=6, W=32, window=150, eh_eps=0.2, ingest_chunk=50)
_RACE_KW = dict(dim=8, L=6, W=32, ingest_chunk=64, seed=3)
# A long wait budget forces real coalescing in the threaded tests (the
# scheduler holds the batch open until every client thread has enqueued).
_WAIT = dict(batch_queries=True, max_wait_us=200_000.0)


def _data(n=500, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


def _run_threads(calls):
    """Run ``calls`` (thunks) concurrently; return results in call order."""
    outs = [None] * len(calls)
    errs = []

    def work(i):
        try:
            outs[i] = calls[i]()
        except BaseException as e:  # pragma: no cover - surfaced below
            errs.append(e)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(len(calls))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs
    return outs


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Coalescing bit-identity (all three sketches, mixed kinds, remainder path)
# ---------------------------------------------------------------------------

def test_retrieval_coalesced_bit_identical_mixed_kinds():
    data = _data()
    ref = RetrievalService(RetrievalConfig(**_RETR_KW))
    svc = RetrievalService(RetrievalConfig(**_RETR_KW, **_WAIT))
    ref.ingest(data)
    svc.ingest(data)
    qs = [_data(n, seed=10 + n) + 0.01 for n in (1, 3, 7, 2, 5)]
    # mixed (c, r) and top-k requests coalesce into the same ticks
    expect = [ref.query(qs[0]), ref.query_topk(qs[1]), ref.query(qs[2]),
              ref.query_topk(qs[3]), ref.query(qs[4])]
    got = _run_threads([
        lambda: svc.query(qs[0]), lambda: svc.query_topk(qs[1]),
        lambda: svc.query(qs[2]), lambda: svc.query_topk(qs[3]),
        lambda: svc.query(qs[4])])
    for e, g in zip(expect, got):
        assert _trees_equal(e, g)
    st = svc.batcher.stats()
    assert st["queries"] == 5 and st["ticks"] < 5, st  # real coalescing
    svc.close(); ref.close()


def test_kde_and_race_coalesced_bit_identical():
    data = _data(seed=1)
    for make_ref, make_b in (
            (lambda: KDEService(KDEServiceConfig(**_KDE_KW)),
             lambda: KDEService(KDEServiceConfig(**_KDE_KW, **_WAIT))),
            (lambda: RACEService(RACEServiceConfig(**_RACE_KW)),
             lambda: RACEService(RACEServiceConfig(**_RACE_KW, **_WAIT)))):
        ref, svc = make_ref(), make_b()
        ref.ingest(data)
        svc.ingest(data)
        qs = [_data(n, seed=20 + n) for n in (2, 9, 1, 4)]
        # mixed raw-KDE and normalised-density kinds in one tick
        expect = [ref.query(qs[0]), ref.query(qs[1]),
                  _norm(ref, qs[2]), _norm(ref, qs[3])]
        got = _run_threads([
            lambda: svc.query(qs[0]), lambda: svc.query(qs[1]),
            lambda: _norm(svc, qs[2]), lambda: _norm(svc, qs[3])])
        for e, g in zip(expect, got):
            np.testing.assert_array_equal(np.asarray(e), np.asarray(g))
        assert svc.batcher.stats()["ticks"] < 4
        svc.close(); ref.close()


def _norm(svc, qs):
    return svc.density(qs) if hasattr(svc, "density") else svc.kde(qs)


def test_coalesced_remainder_and_padding_paths():
    """Coalesced totals that are not a multiple of query_block pad to the
    bucketed size (pow2 below the block, block multiples above); with
    query_block=3 a 1+3+2=6-row tick spans two blocks and a 1+1-row tick
    hits the pow2 pad — all bit-identical to unbatched calls."""
    kw = dict(_RETR_KW, query_block=3)
    data = _data(seed=2)
    ref = RetrievalService(RetrievalConfig(**kw))
    svc = RetrievalService(RetrievalConfig(**kw, **_WAIT))
    ref.ingest(data)
    svc.ingest(data)
    for sizes in ((1, 3, 2), (1, 1), (4, 5)):
        qs = [_data(n, seed=30 + n) + 0.01 for n in sizes]
        expect = [ref.query(q) for q in qs]
        got = _run_threads([lambda q=q: svc.query(q) for q in qs])
        for e, g in zip(expect, got):
            assert _trees_equal(e, g)
    svc.close(); ref.close()


def test_submit_query_futures_and_empty_batch():
    """submit_query works without batch_queries (async entry point is
    always on); B=0 requests resolve to the matching empty result and
    never force a fused tick."""
    data = _data(seed=3)
    svc = RetrievalService(RetrievalConfig(**_RETR_KW))
    svc.ingest(data)
    fut = svc.submit_query(data[:4] + 0.01)
    res = fut.result(timeout=30)
    assert _trees_equal(res, svc.query(data[:4] + 0.01))
    empty = svc.submit_query(np.zeros((0, 8), np.float32)).result(timeout=30)
    assert np.asarray(empty.index).shape == (0,)
    with pytest.raises(ValueError, match="unknown query kind"):
        svc.submit_query(data[:1], kind="nope")
    svc.close()


# ---------------------------------------------------------------------------
# Snapshot consistency under churn
# ---------------------------------------------------------------------------

def test_coalesced_queries_see_one_committed_prefix_under_churn():
    """Concurrent clients coalesced while a background ingest commits:
    every answer equals the unbatched answer after SOME committed prefix
    (never a torn state), and raw-KDE + density requests coalesced into
    one tick read the SAME snapshot (density == kde / prefix clock for
    one consistent prefix)."""
    from repro.core import swakde
    data = _data(n=400, seed=4)
    qs = data[:5] + 0.01
    # 2 concurrent 5-row clients per round: max_batch=10 fires the tick the
    # moment both have enqueued; the 2 ms budget bounds rounds where the
    # second client straggles in after the first tick fired.
    svc = KDEService(KDEServiceConfig(**_KDE_KW, batch_queries=True,
                                      max_batch=10, max_wait_us=2000.0))
    chunk = svc.cfg.ingest_chunk

    st = swakde.swakde_init(svc.sketch_cfg)
    prefix_res = [np.asarray(swakde.swakde_query_batch(
        st, svc.params, jnp.asarray(qs), svc.sketch_cfg))]
    for i in range(0, 400, chunk):
        st = swakde.swakde_update_chunk(st, svc.params,
                                        jnp.asarray(data[i:i + chunk]),
                                        svc.sketch_cfg)
        prefix_res.append(np.asarray(swakde.swakde_query_batch(
            st, svc.params, jnp.asarray(qs), svc.sketch_cfg)))
    denoms = [max(min(k * chunk, svc.cfg.window), 1)
              for k in range(len(prefix_res))]

    svc.ingest_async(data)
    done = False
    for _ in range(3_000):
        out, dens = _run_threads([lambda: svc.query(qs),
                                  lambda: svc.density(qs)])
        ks = [k for k, r in enumerate(prefix_res) if np.array_equal(out, r)]
        assert ks, "torn state through the batcher"
        kd = [k for k, r in enumerate(prefix_res)
              if np.array_equal(dens, r / denoms[k])]
        assert kd, "density saw no single committed prefix"
        if svc.version == len(prefix_res) - 1:
            done = True
            break
    assert done, "background ingest never finished"
    svc.flush()
    np.testing.assert_array_equal(svc.query(qs), prefix_res[-1])
    svc.close()


def test_grid_cache_shared_per_tick_and_never_stale():
    """One grid computation serves a whole coalesced tick; a commit between
    ticks invalidates it (version-keyed) so a coalesced batch can never
    read a stale grid."""
    data = _data(n=300, seed=5)
    qs = data[:4] + 0.01
    svc = KDEService(KDEServiceConfig(**_KDE_KW, **_WAIT))
    calls = []
    orig = svc._grid_fn
    svc._grid_fn = lambda st: (calls.append(1), orig(st))[1]

    svc.ingest(data[:200])
    _run_threads([lambda: svc.query(qs)] * 4)    # one tick, 4 clients
    assert len(calls) == 1, "grid recomputed within one coalesced tick"
    svc.ingest(data[200:])                       # commit → version bump
    out = _run_threads([lambda: svc.query(qs)] * 3)
    assert len(calls) == 2, "stale grid served to a post-commit tick"
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)
    for o in out:
        np.testing.assert_array_equal(o, ref.query(qs))
    svc.close(); ref.close()


# ---------------------------------------------------------------------------
# Scheduler properties: seeded-rng fuzz of batch_plan in a simulated loop
# ---------------------------------------------------------------------------

def _simulate(arrivals, sizes, max_batch, max_wait_us, exec_us):
    """Deterministic event-loop replay of the QueryBatcher tick loop over
    an offline arrival trace: returns per-request (dispatch_time, tick_id)
    plus per-tick records (dispatch_time, oldest_arrival, executor_free_at,
    rows).  Time only advances to the next arrival, the planned deadline,
    or the executor becoming free — exactly the points the real loop wakes
    at."""
    pending, dispatched, ticks = [], {}, []
    i, now, free_at = 0, 0.0, 0.0
    n = len(arrivals)
    while len(dispatched) < n:
        while i < n and arrivals[i] <= now:
            pending.append((arrivals[i], i))
            i += 1
        if not pending:
            now = arrivals[i]
            continue
        if now < free_at:            # executor busy: requests keep queueing
            now = min(free_at, arrivals[i]) if i < n else free_at
            continue
        take, wait = batch_plan([(a, sizes[j]) for a, j in pending], now,
                                max_batch, max_wait_us)
        if take == 0:
            nxt = now + wait
            if i < n and arrivals[i] < nxt:
                now = arrivals[i]
            else:
                now = nxt
            continue
        batch, pending = pending[:take], pending[take:]
        ticks.append((now, batch[0][0], free_at,
                      sum(sizes[j] for _, j in batch)))
        for _, j in batch:
            dispatched[j] = (now, len(ticks) - 1)
        free_at = now + exec_us
    return dispatched, ticks


def test_batch_plan_fuzz_deadline_cap_and_fifo():
    """Seeded-rng fuzz over Poisson-ish arrival traces:

      (a) no late dispatch: every tick fires by the time its oldest
          request's max_wait_us budget expires or the executor frees up,
          whichever is later — the scheduler never sleeps past a deadline
          while the executor is idle (no starvation);
      (b) with an instantaneous executor the per-request delay is bounded
          by max_wait_us outright;
      (c) no tick exceeds max_batch rows unless a single oversized
          request is alone responsible;
      (d) dispatch order is FIFO (a request never overtakes an earlier
          one), with B=0 requests admitted like any other (riding along,
          adding no rows)."""
    rng = np.random.default_rng(1234)
    for trial in range(40):
        n = int(rng.integers(1, 40))
        gaps = rng.exponential(rng.choice([20.0, 200.0, 2000.0]), n)
        arrivals = np.cumsum(gaps)
        sizes = rng.integers(0, 12, n).tolist()
        if rng.random() < 0.3:       # occasional oversized request
            sizes[int(rng.integers(n))] = 64
        max_batch = int(rng.integers(1, 33))
        max_wait = float(rng.choice([0.0, 50.0, 500.0]))
        exec_us = float(rng.choice([0.0, 100.0, 1000.0]))
        disp, ticks = _simulate(arrivals, sizes, max_batch, max_wait,
                                exec_us)
        assert len(disp) == n
        for t, oldest, free_at, rows in ticks:
            assert t <= max(oldest + max_wait, free_at) + 1e-6, (
                f"trial {trial}: tick at {t:.0f} slept past deadline "
                f"{oldest + max_wait:.0f} with executor free at "
                f"{free_at:.0f}")
            assert rows <= max(max_batch, max(sizes)), (
                f"trial {trial}: tick of {rows} rows > max_batch "
                f"{max_batch}")
        for j in range(n):
            if exec_us == 0.0:
                assert disp[j][0] <= arrivals[j] + max_wait + 1e-6, (
                    f"trial {trial}: request {j} waited "
                    f"{disp[j][0] - arrivals[j]:.0f}us > budget")
            if j:                    # FIFO: ticks are non-decreasing in j
                assert disp[j][1] >= disp[j - 1][1]


def test_batch_plan_unit_properties():
    # fires at the row cap, not before
    assert batch_plan([(0.0, 4), (1.0, 4)], 2.0, 8, 100.0)[0] == 2
    # row-capped prefix fires immediately (waiting adds no rows)
    take, _ = batch_plan([(0.0, 6), (1.0, 6)], 2.0, 8, 100.0)
    assert take == 1
    # under the cap and inside the budget: wait exactly the remainder
    take, wait = batch_plan([(10.0, 1)], 60.0, 8, 100.0)
    assert take == 0 and wait == pytest.approx(50.0)
    # deadline comes only from the OLDEST request — later arrivals never
    # push it out
    take, wait = batch_plan([(10.0, 1), (99.0, 1)], 60.0, 8, 100.0)
    assert take == 0 and wait == pytest.approx(50.0)
    assert batch_plan([(10.0, 1), (99.0, 1)], 111.0, 8, 100.0)[0] == 2
    # a lone oversized request is admitted (one-request progress)
    assert batch_plan([(0.0, 99)], 0.0, 8, 100.0)[0] == 1
    # zero-row requests coalesce without contributing rows
    assert batch_plan([(0.0, 0), (0.0, 8)], 0.0, 8, 100.0)[0] == 2


def test_slow_query_delays_by_at_most_one_execute():
    """A slow in-flight tick never blocks later arrivals indefinitely:
    they form the next tick and complete right after it."""
    gate = threading.Event()
    order = []

    def execute(reqs):
        order.append([k for k, _ in reqs])
        if order and order[0][0] == "slow" and len(order) == 1:
            gate.wait(timeout=30)
        return [r.shape[0] for _, r in reqs]

    b = QueryBatcher(execute, max_batch=8, max_wait_us=0.0)
    f_slow = b.submit("slow", np.zeros((1, 2), np.float32))
    time.sleep(0.05)                 # let the slow tick enter execute
    f_fast = b.submit("fast", np.zeros((2, 2), np.float32))
    assert not f_fast.done()         # queued behind exactly one execute
    gate.set()
    assert f_slow.result(timeout=30) == 1
    assert f_fast.result(timeout=30) == 2
    assert order[0] == ["slow"] and "fast" in order[1]
    b.close()


# ---------------------------------------------------------------------------
# Lifecycle: close() drains or fails, never hangs
# ---------------------------------------------------------------------------

def test_batcher_close_drains_pending_futures():
    gate = threading.Event()

    def execute(reqs):
        gate.wait(timeout=30)
        return [r.shape[0] for _, r in reqs]

    b = QueryBatcher(execute, max_batch=4, max_wait_us=1e6)
    futs = [b.submit("q", np.zeros((1, 2), np.float32)) for _ in range(3)]
    gate.set()
    b.close()                        # drain=True: queue served, then join
    assert [f.result(timeout=1) for f in futs] == [1, 1, 1]
    with pytest.raises(RuntimeError, match="closed"):
        b.submit("q", np.zeros((1, 2), np.float32))
    b.close()                        # idempotent


def test_batcher_close_no_drain_fails_futures():
    gate = threading.Event()

    def execute(reqs):
        gate.wait(timeout=30)
        return [r.shape[0] for _, r in reqs]

    b = QueryBatcher(execute, max_batch=1, max_wait_us=1e6)
    first = b.submit("q", np.zeros((1, 2), np.float32))
    time.sleep(0.05)                 # in-flight tick holds the executor
    queued = [b.submit("q", np.zeros((1, 2), np.float32)) for _ in range(3)]
    t = threading.Thread(target=b.close, kwargs=dict(drain=False))
    t.start()
    gate.set()
    t.join(timeout=30)
    assert not t.is_alive(), "close(drain=False) hung"
    assert first.result(timeout=1) == 1          # in-flight still completes
    for f in queued:
        with pytest.raises(RuntimeError, match="closed before serving"):
            f.result(timeout=1)


def test_close_no_drain_during_inline_tick_never_kills_scheduler():
    """Regression: close(drain=False) can empty the queue while the
    scheduler thread is parked (lock released) in its waits — the
    busy-wait behind an inline tick, or the timed plan wait.  The loop
    must re-check the queue and clamp its planned ``take`` afterwards;
    popping the stale prefix used to raise IndexError and kill the
    scheduler thread."""
    errors = []
    old_hook = threading.excepthook
    threading.excepthook = lambda args: errors.append(args)
    try:
        gate = threading.Event()
        entered = threading.Event()

        def execute(reqs):
            entered.set()
            gate.wait(timeout=30)
            return [r.shape[0] for _, r in reqs]

        b = QueryBatcher(execute, max_batch=8, max_wait_us=0.0)
        inline = threading.Thread(target=b.try_submit_inline, args=(
            "q", np.zeros((1, 2), np.float32)))
        inline.start()
        entered.wait(timeout=30)         # inline tick holds the _busy slot
        fut = b.submit("q", np.zeros((1, 2), np.float32))
        time.sleep(0.05)                 # scheduler parks on the busy-wait
        closer = threading.Thread(target=b.close, kwargs=dict(drain=False))
        closer.start()
        time.sleep(0.05)                 # close drains the queue, fails fut
        gate.set()                       # release the inline tick
        closer.join(timeout=30)
        inline.join(timeout=30)
        assert not closer.is_alive(), "close(drain=False) hung"
        with pytest.raises(RuntimeError, match="closed before serving"):
            fut.result(timeout=1)
        assert not errors, (
            f"scheduler thread died: {[e.exc_value for e in errors]}")
    finally:
        threading.excepthook = old_hook


def test_execute_failure_fails_the_whole_tick_then_recovers():
    boom = {"on": True}

    def execute(reqs):
        if boom["on"]:
            raise ValueError("scorer exploded")
        return [r.shape[0] for _, r in reqs]

    b = QueryBatcher(execute, max_batch=8, max_wait_us=0.0)
    f = b.submit("q", np.zeros((1, 2), np.float32))
    with pytest.raises(ValueError, match="scorer exploded"):
        f.result(timeout=30)
    boom["on"] = False               # scheduler thread survived the error
    assert b.submit("q", np.zeros((2, 2), np.float32)).result(timeout=30) == 2
    b.close()


def test_engine_close_keeps_sync_queries_on_direct_path():
    data = _data(n=120, seed=6)
    svc = RACEService(RACEServiceConfig(**_RACE_KW, **_WAIT))
    svc.ingest(data)
    batched = _run_threads([lambda: svc.query(data[:3])] * 2)
    svc.close()
    after = svc.query(data[:3])      # direct path, no scheduler
    np.testing.assert_array_equal(np.asarray(batched[0]), np.asarray(after))
    with pytest.raises(RuntimeError):
        svc.submit_query(data[:3])


# ---------------------------------------------------------------------------
# Cluster: one merged snapshot per coalesced tick
# ---------------------------------------------------------------------------

def test_cluster_one_merge_per_tick_not_per_client():
    """The coordinator's query-time merge is the expensive step; with the
    batcher, C concurrent clients cost ONE merged snapshot per tick, so
    query cost does not scale with client count."""
    kw = dict(dim=8, L=4, W=32, window=100_000, ingest_chunk=64, seed=5)
    data = _data(n=360, seed=7)
    svc = ClusterKDEService(
        KDEServiceConfig(**kw, **_WAIT), num_workers=2, merge_every=4)
    ref = ClusterKDEService(KDEServiceConfig(**kw), num_workers=2,
                            merge_every=4)
    svc.ingest(data)
    ref.ingest(data)

    snaps = []
    orig = svc.merged_snapshot
    svc.merged_snapshot = lambda: (snaps.append(1), orig())[1]
    qs = [_data(n, seed=40 + n) for n in (2, 5, 1, 3, 4, 2)]
    got = _run_threads([lambda q=q: svc.query(q) for q in qs])
    for q, g in zip(qs, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(ref.query(q)))
    st = svc.batcher.stats()
    assert st["queries"] == len(qs)
    assert len(snaps) == st["ticks"] < len(qs), (
        f"{len(snaps)} merged snapshots for {st['ticks']} ticks / "
        f"{len(qs)} clients")
    svc.close(); ref.close()


# ---------------------------------------------------------------------------
# Inline fast path: lone sync clients skip the scheduler round-trip
# ---------------------------------------------------------------------------

def test_lone_sync_query_takes_inline_fast_path_bit_identical():
    """In continuous-batching mode (max_wait_us=0) a sync query arriving
    at an idle scheduler executes inline on the caller thread — no
    enqueue/wakeup round-trip — and is bit-identical to both the queued
    path and a direct (unbatched) service.  Async `submit_query` always
    takes the queued path (its caller must not block on execute)."""
    data = _data(seed=31)
    qs = data[:7] + 0.01
    direct = RACEService(RACEServiceConfig(**_RACE_KW))
    svc = RACEService(RACEServiceConfig(**_RACE_KW, batch_queries=True,
                                        max_wait_us=0.0))
    direct.ingest(data)
    svc.ingest(data)

    for i in range(3):
        np.testing.assert_array_equal(np.asarray(svc.query(qs)),
                                      np.asarray(direct.query(qs)))
    st = svc.batcher.stats()
    assert st["inline_ticks"] == st["ticks"] == st["queries"] == 3, st

    fut = svc.submit_query(qs)       # async: scheduler path, never inline
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(direct.query(qs)))
    st = svc.batcher.stats()
    assert st["queries"] == 4 and st["inline_ticks"] == 3, st
    svc.close()
    direct.close()


def test_inline_fast_path_disabled_while_batch_open():
    """With a wait budget (max_wait_us > 0) the inline path must stay off
    — inlining would bypass open-batch coalescing; every sync query goes
    through the scheduler."""
    data = _data(n=200, seed=32)
    svc = RACEService(RACEServiceConfig(**_RACE_KW, **_WAIT))
    svc.ingest(data)
    _run_threads([lambda: svc.query(data[:3])] * 3)
    st = svc.batcher.stats()
    assert st["inline_ticks"] == 0 and st["queries"] == 3, st
    svc.close()
