"""Shared streaming-engine semantics (repro.serve.engine):

  * pipelined (double-buffered) ingest is bit-identical to sequential
    two-phase ingest and to the direct core chunk loop;
  * ``flush()`` after ``ingest_async()`` leaves exactly the state the
    synchronous ``ingest()`` path produces;
  * concurrent queries against a background ingest never observe a torn
    state — every result is valid for *some* committed prefix of the
    stream (the lock-consistency satellite: query paths snapshot state
    under the engine lock);
  * the SW-AKDE grid snapshot cache is reused between commits and
    invalidated by any commit (stale-cache regression);
  * background ingest failures surface on flush().
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sann, swakde
from repro.serve.kde_service import KDEService, KDEServiceConfig
from repro.serve.retrieval import RetrievalConfig, RetrievalService

_RETR_KW = dict(dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=6, k=3,
                ingest_chunk=64)
_KDE_KW = dict(dim=8, L=6, W=32, window=150, eh_eps=0.2, ingest_chunk=50)


def _states_equal(a, b):
    return all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _data(n=500, d=8, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, d)).astype(
        np.float32)


# ---------------------------------------------------------------------------
# Pipelined == sequential == async+flush
# ---------------------------------------------------------------------------

def test_retrieval_pipelined_sequential_async_identical():
    data = _data()
    svcs = [RetrievalService(RetrievalConfig(**_RETR_KW)),
            RetrievalService(RetrievalConfig(**_RETR_KW, pipelined=False)),
            RetrievalService(RetrievalConfig(**_RETR_KW))]
    svcs[0].ingest(data)
    svcs[1].ingest(data)
    svcs[2].ingest_async(data)
    svcs[2].flush()
    assert _states_equal(svcs[0].state, svcs[1].state)
    assert _states_equal(svcs[0].state, svcs[2].state)

    # ... and identical to the direct core chunk loop under the service's
    # per-chunk key schedule (fold_in(base, chunk seq) — re-derivable from
    # the sequence number alone, which is what crash recovery replays).
    ref = sann.sann_init(sann.SANNConfig(
        dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=6, k=3),
        jax.random.PRNGKey(0))[2]
    base = jax.random.fold_in(jax.random.PRNGKey(1), 0)
    for seq, i in enumerate(range(0, 500, 64)):
        sub = jax.random.fold_in(base, seq)
        ref = sann.sann_insert_batch(ref, svcs[0].params,
                                     jnp.asarray(data[i:i + 64]), sub,
                                     svcs[0].cfg)
    assert _states_equal(svcs[0].state, ref)


def test_kde_pipelined_sequential_async_identical():
    data = _data(seed=1)
    svcs = [KDEService(KDEServiceConfig(**_KDE_KW)),
            KDEService(KDEServiceConfig(**_KDE_KW, pipelined=False)),
            KDEService(KDEServiceConfig(**_KDE_KW))]
    svcs[0].ingest(data)
    svcs[1].ingest(data)
    svcs[2].ingest_async(data)
    svcs[2].flush()
    assert _states_equal(svcs[0].state, svcs[1].state)
    assert _states_equal(svcs[0].state, svcs[2].state)
    direct = swakde.swakde_stream(
        swakde.swakde_init(svcs[0].sketch_cfg), svcs[0].params,
        jnp.asarray(data), svcs[0].sketch_cfg)
    assert _states_equal(svcs[0].state, direct)
    # cached-grid reads == uncached fused engine reads, bit-for-bit
    qs = data[:7] + 0.01
    uncached = KDEService(KDEServiceConfig(**_KDE_KW, cache_grid=False))
    uncached.ingest(data)
    np.testing.assert_array_equal(svcs[0].query(qs), uncached.query(qs))


@pytest.mark.parametrize("depth", [2, 4])
def test_prepare_depth_bit_identical_and_prefix_consistent(depth):
    """Deep prepare lookahead (prepare_depth > 1) commits in submission
    order: the final state matches depth-1 ingest bit-for-bit, and
    mid-stream snapshots always equal the direct chunk loop after some
    committed prefix of the stream."""
    data = _data(n=600, seed=3)
    ref_svc = KDEService(KDEServiceConfig(**_KDE_KW))
    svc = KDEService(KDEServiceConfig(**_KDE_KW, prepare_depth=depth))
    ref_svc.ingest(data)
    # submit chunk-sized pieces to exercise the live-queue lookahead
    for i in range(0, 600, 50):
        svc.ingest_async(data[i:i + 50])
    # mid-stream snapshot: must be the state after some committed prefix
    st, _ = svc.snapshot()
    t_seen = int(jax.block_until_ready(st).t)
    assert t_seen % 50 == 0 and 0 <= t_seen <= 600
    prefix = swakde.swakde_stream(
        swakde.swakde_init(svc.sketch_cfg), svc.params,
        jnp.asarray(data[:t_seen]), svc.sketch_cfg)
    assert _states_equal(st, prefix)
    svc.flush()
    assert _states_equal(svc.state, ref_svc.state)
    svc.close(); ref_svc.close()


def test_empty_ingest_and_empty_query():
    svc = RetrievalService(RetrievalConfig(**_RETR_KW))
    svc.ingest(np.zeros((0, 8), np.float32))
    svc.flush()
    assert svc.version == 0
    res = svc.query(np.zeros((0, 8), np.float32))
    assert np.asarray(res.index).shape == (0,)
    kde = KDEService(KDEServiceConfig(**_KDE_KW))
    kde.ingest(np.zeros((0, 8), np.float32))
    assert kde.query(np.zeros((0, 8), np.float32)).shape == (0,)


# ---------------------------------------------------------------------------
# Concurrency: background ingest + queries see committed prefixes only
# ---------------------------------------------------------------------------

def test_kde_concurrent_queries_see_committed_prefixes():
    data = _data(n=400, seed=2)
    qs = data[:5] + 0.01
    svc = KDEService(KDEServiceConfig(**_KDE_KW))
    chunk = svc.cfg.ingest_chunk

    # expected query result after every committed prefix (0..n_chunks)
    st = swakde.swakde_init(svc.sketch_cfg)
    prefix_res = [np.asarray(swakde.swakde_query_batch(
        st, svc.params, jnp.asarray(qs), svc.sketch_cfg))]
    for i in range(0, 400, chunk):
        st = swakde.swakde_update_chunk(st, svc.params,
                                        jnp.asarray(data[i:i + chunk]),
                                        svc.sketch_cfg)
        prefix_res.append(np.asarray(swakde.swakde_query_batch(
            st, svc.params, jnp.asarray(qs), svc.sketch_cfg)))

    svc.ingest_async(data)
    done = False
    for _ in range(10_000):
        out = svc.query(qs)
        matches = [k for k, r in enumerate(prefix_res)
                   if np.array_equal(out, r)]
        assert matches, f"torn state: {out} matches no committed prefix"
        if svc.version == len(prefix_res) - 1:
            done = True
            break
    assert done, "background ingest never finished"
    svc.flush()
    np.testing.assert_array_equal(svc.query(qs), prefix_res[-1])


def test_retrieval_concurrent_queries_see_committed_prefixes():
    data = _data(n=320, seed=3)
    qs = data[:6] + 0.01
    svc = RetrievalService(RetrievalConfig(**_RETR_KW))
    chunk = svc._chunk

    st = sann.sann_init(sann.SANNConfig(
        dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=6, k=3),
        jax.random.PRNGKey(0))[2]
    base = jax.random.fold_in(jax.random.PRNGKey(1), 0)
    prefix_res = [jax.tree.map(np.asarray, sann.sann_query_batch(
        st, svc.params, jnp.asarray(qs), svc.cfg))]
    for seq, i in enumerate(range(0, 320, chunk)):
        sub = jax.random.fold_in(base, seq)
        st = sann.sann_insert_batch(st, svc.params,
                                    jnp.asarray(data[i:i + chunk]), sub,
                                    svc.cfg)
        prefix_res.append(jax.tree.map(np.asarray, sann.sann_query_batch(
            st, svc.params, jnp.asarray(qs), svc.cfg)))

    def matches(res, exp):
        return all(np.array_equal(np.asarray(a), b)
                   for a, b in zip(res, exp))

    svc.ingest_async(data)
    done = False
    for _ in range(10_000):
        res = svc.query(qs)
        ks = [k for k, exp in enumerate(prefix_res) if matches(res, exp)]
        assert ks, "torn state: query result matches no committed prefix"
        if svc.version == len(prefix_res) - 1:
            done = True
            break
    assert done, "background ingest never finished"
    svc.flush()
    assert matches(svc.query(qs), prefix_res[-1])


# ---------------------------------------------------------------------------
# Snapshot cache: reuse between commits, invalidation on commit
# ---------------------------------------------------------------------------

def test_grid_cache_reused_and_invalidated_on_commit():
    data = _data(n=300, seed=4)
    qs = data[:5]
    svc = KDEService(KDEServiceConfig(**_KDE_KW))
    calls = []
    orig = svc._grid_fn
    svc._grid_fn = lambda st: (calls.append(1), orig(st))[1]

    svc.ingest(data[:200])
    q1 = svc.query(qs)
    q1b = svc.query(qs)
    assert len(calls) == 1          # second batch served from the cache
    np.testing.assert_array_equal(q1, q1b)

    svc.ingest(data[200:])          # commit → must invalidate the cache
    q2 = svc.query(qs)
    assert len(calls) == 2, "stale grid cache served after a commit"
    direct = np.asarray(swakde.swakde_query_batch(
        svc.state, svc.params, jnp.asarray(qs), svc.sketch_cfg))
    np.testing.assert_array_equal(q2, direct)
    assert not np.array_equal(q1, q2)  # the new mass is visible

    # density() shares the same snapshot/cache machinery
    d2 = svc.density(qs)
    assert len(calls) == 2
    np.testing.assert_allclose(
        d2, direct / max(min(int(svc.state.t), svc.cfg.window), 1))


def test_delete_invalidates_snapshot_cache():
    data = _data(n=200, seed=5)
    svc = RetrievalService(RetrievalConfig(**_RETR_KW))
    svc.ingest(data)
    v = svc.version
    svc.delete(data[0])
    assert svc.version == v + 1
    res = svc.query(data[:1])
    # the deleted vector can no longer be returned at distance ~0
    assert float(np.asarray(res.distance)[0]) > 1e-4 or not bool(
        np.asarray(res.found)[0])


# ---------------------------------------------------------------------------
# Failure propagation
# ---------------------------------------------------------------------------

def test_background_ingest_error_surfaces_on_flush():
    svc = KDEService(KDEServiceConfig(**_KDE_KW))

    def boom(*_):
        raise ValueError("prepare exploded")

    svc._prepare = boom
    svc.ingest_async(_data(n=60, seed=6))
    with pytest.raises(RuntimeError, match="prepare exploded"):
        svc.flush()
    # fail-stop: the failed submission's remaining chunks were discarded,
    # not committed around the hole — the state is still a committed prefix
    assert svc.steps == 0
    # the engine recovers once the fault is gone: later ingests work again
    del svc._prepare            # restore the class method
    svc.ingest(_data(n=60, seed=7))
    assert svc.steps == 60


def test_max_pending_backpressure_blocks_and_releases():
    """Admission control: with ``max_pending`` set, ingest_async blocks
    once the queue holds that many uncommitted rows, and unblocks as the
    worker drains — the final state is unchanged (same chunks, same
    order)."""
    chunk = _KDE_KW["ingest_chunk"]
    data = _data(n=4 * chunk, seed=9)
    svc = KDEService(KDEServiceConfig(**_KDE_KW, max_pending=chunk))
    gate = threading.Event()
    orig_commit = svc._commit

    def slow_commit(state, prep):
        gate.wait(timeout=30)
        return orig_commit(state, prep)

    svc._commit = slow_commit
    t = threading.Thread(target=svc.ingest_async, args=(data,), daemon=True)
    t.start()
    # The submitter must stall: >= 1 chunk admitted, but not all 4 (the
    # commit gate is closed, so pending rows stay at the bound).
    t.join(timeout=1.0)
    assert t.is_alive(), "ingest_async ignored max_pending backpressure"
    assert svc._pending_rows <= chunk
    gate.set()                   # drain: submitter unblocks, chunks commit
    t.join(timeout=30)
    assert not t.is_alive()
    svc.flush()
    assert svc.steps == 4 * chunk
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    ref.ingest(data)
    assert _states_equal(svc.state, ref.state)


def test_batched_query_path_single_caller_bit_identical():
    """batch_queries=True routes sync queries through the admission
    scheduler (DESIGN.md §13); with a single caller every tick holds one
    request and the answers are bit-identical to the direct path —
    including the version-cached grid reads.  (Concurrent-coalescing
    semantics live in tests/test_serve_batching.py.)"""
    data = _data(n=200, seed=10)
    qs = data[:5] + 0.01
    ref = KDEService(KDEServiceConfig(**_KDE_KW))
    svc = KDEService(KDEServiceConfig(**_KDE_KW, batch_queries=True,
                                      max_wait_us=0.0))
    ref.ingest(data)
    svc.ingest(data)
    np.testing.assert_array_equal(svc.query(qs), ref.query(qs))
    np.testing.assert_array_equal(svc.density(qs), ref.density(qs))
    assert svc.batcher.stats()["queries"] == 2
    r_ref = RetrievalService(RetrievalConfig(**_RETR_KW))
    r_svc = RetrievalService(RetrievalConfig(**_RETR_KW, batch_queries=True,
                                             max_wait_us=0.0))
    r_ref.ingest(data)
    r_svc.ingest(data)
    a, b = r_svc.query(qs), r_ref.query(qs)
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    for s in (ref, svc, r_ref, r_svc):
        s.close()


def test_close_commits_queued_then_rejects_new_work():
    data = _data(n=200, seed=8)
    svc = KDEService(KDEServiceConfig(**_KDE_KW))
    svc.ingest_async(data)
    svc.close()                  # drains the queue, then stops the worker
    assert svc.steps == 200
    svc.close()                  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.ingest_async(data)
    assert svc.query(data[:3]).shape == (3,)   # queries keep working
