"""Multi-tenant sketch fleets (repro.core.fleet + repro.serve.tenant_fleet):

  * tenant-routed vmapped ingest and fused queries are BIT-IDENTICAL to a
    per-tenant loop of the existing single-sketch paths, for all three
    sketches — including RACE counter saturation territory, S-ANN
    ring-wrap/eviction and EH expiry at tenant boundaries;
  * the `sann_row_keys` schedule is prefix-stable (the property the padded
    fleet Bernoulli draws rely on);
  * the `TenantFleet` LRU hot set: spill → reactivate round-trips are
    bit-identical, durable fleets recover bit-identically from
    snapshot + tenant-tagged WAL + per-tenant spills;
  * hypothesis fuzz over mixed-tenant chunk compositions, skewed
    (single-hot-tenant) included.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fleet, race, sann, swakde
from repro.core.lsh import hash_points, init_pstable, init_srp
from repro.serve.tenant_fleet import TenantFleet, TenantFleetConfig


def _mixed(T, n, d, seed=0, probs=None):
    """One mixed chunk: xs (n, d) and per-point tenant ids drawn from
    ``probs`` (uniform by default)."""
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, d)).astype(np.float32)
    tids = rng.choice(T, size=n, p=probs).astype(np.int64)
    return xs, tids


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# core.fleet vs per-tenant oracle loops
# --------------------------------------------------------------------------


def test_race_fleet_bitexact_vs_per_tenant_loop():
    T, d, L, W = 4, 6, 5, 32
    params = init_srp(jax.random.PRNGKey(0), d, L, 3, W)
    stacked = fleet.fleet_broadcast(race.race_init(L, W), T)
    oracle = [race.race_init(L, W) for _ in range(T)]
    for chunk in range(3):
        xs, tids = _mixed(T, 70, d, seed=chunk)
        stacked = fleet.race_fleet_ingest(stacked, params,
                                          jnp.asarray(xs),
                                          jnp.asarray(tids, jnp.int32))
        for t in range(T):
            sub = jnp.asarray(xs[tids == t])
            if sub.shape[0]:
                oracle[t] = race.race_commit_chunk(
                    oracle[t], race.race_prepare_chunk(params, sub, W))
    _leaves_equal(stacked, fleet.fleet_stack(oracle))

    qs, qt = _mixed(T, 25, d, seed=99)
    got = fleet.race_fleet_query(stacked, params, jnp.asarray(qs),
                                 jnp.asarray(qt, jnp.int32))
    want = np.empty(25, np.float32)
    for t in range(T):
        m = qt == t
        if m.any():
            want[m] = np.asarray(
                race.race_query_batch(oracle[t], params, jnp.asarray(qs[m])))
    np.testing.assert_array_equal(np.asarray(got), want)

    kde = fleet.race_fleet_kde(stacked, params, jnp.asarray(qs),
                               jnp.asarray(qt, jnp.int32))
    for t in range(T):
        m = qt == t
        if m.any():
            want[m] = np.asarray(got)[m] / max(int(oracle[t].n), 1)
    np.testing.assert_array_equal(np.asarray(kde), want)


def test_swakde_fleet_bitexact_with_expiry_at_tenant_boundaries():
    """window < per-tenant stream: EH buckets expire at different clocks
    per tenant row; the vmapped commit must still match the per-tenant
    `swakde_update_chunk` loop bitwise."""
    T, d = 3, 5
    cfg = swakde.SWAKDEConfig(L=4, W=32, window=16, eh_eps=0.2)
    params = init_pstable(jax.random.PRNGKey(1), d, cfg.L, 2, 1.0, cfg.W)
    stacked = fleet.fleet_broadcast(swakde.swakde_init(cfg), T)
    oracle = [swakde.swakde_init(cfg) for _ in range(T)]
    # skew: tenant 0 hot — its window saturates and expires, tenant 2 cold
    for chunk in range(4):
        xs, tids = _mixed(T, 60, d, seed=10 + chunk,
                          probs=[0.7, 0.2, 0.1])
        cap = int(np.bincount(tids, minlength=T).max())
        stacked = fleet.swakde_fleet_ingest(
            stacked, params, jnp.asarray(xs), jnp.asarray(tids, jnp.int32),
            cfg, cap)
        for t in range(T):
            sub = jnp.asarray(xs[tids == t])
            if sub.shape[0]:
                oracle[t] = swakde.swakde_update_chunk(
                    oracle[t], params, sub, cfg)
    _leaves_equal(stacked, fleet.fleet_stack(oracle))
    assert int(oracle[0].t) > cfg.window, "tenant 0 must actually expire"

    qs, qt = _mixed(T, 20, d, seed=77)
    got = fleet.swakde_fleet_query(stacked, params, jnp.asarray(qs),
                                   jnp.asarray(qt, jnp.int32), cfg)
    kde = fleet.swakde_fleet_kde(stacked, params, jnp.asarray(qs),
                                 jnp.asarray(qt, jnp.int32), cfg)
    for t in range(T):
        m = qt == t
        if m.any():
            np.testing.assert_array_equal(
                np.asarray(got)[m],
                np.asarray(swakde.swakde_query_batch(
                    oracle[t], params, jnp.asarray(qs[m]), cfg)))
            denom = max(min(int(oracle[t].t), cfg.window), 1)
            np.testing.assert_array_equal(np.asarray(kde)[m],
                                          np.asarray(got)[m] / denom)


def test_sann_fleet_bitexact_with_ring_wrap():
    """eta > 0 (keys matter) and capacity 64 with ~90 kept points per
    tenant: the ring wraps and evicts.  The padded fleet draws must equal
    the unpadded per-tenant `sann_prepare_chunk` draws (prefix-stable
    `sann_row_keys`), so whole states — stamps, ring pointers, tables —
    match bitwise."""
    T, d = 3, 4
    base = sann.SANNConfig(dim=d, n_max=16, eta=0.3, r=0.5, c=2.0, w=1.0,
                           L=4, k=2)
    cfg, params, empty = sann.sann_init(base, jax.random.PRNGKey(2))
    stacked = fleet.fleet_broadcast(empty, T)
    oracle = [empty for _ in range(T)]
    key = jax.random.PRNGKey(3)
    for chunk in range(5):
        xs, tids = _mixed(T, 120, d, seed=20 + chunk)
        cap = int(np.bincount(tids, minlength=T).max())
        ck = jax.random.fold_in(key, chunk)
        keys = jnp.stack([jax.random.fold_in(ck, t) for t in range(T)])
        stacked = fleet.sann_fleet_ingest(
            stacked, params, jnp.asarray(xs), jnp.asarray(tids, jnp.int32),
            keys, cfg, cap)
        for t in range(T):
            sub = jnp.asarray(xs[tids == t])
            if sub.shape[0]:
                oracle[t] = sann.sann_commit_chunk(
                    oracle[t],
                    sann.sann_prepare_chunk(params, sub, keys[t], cfg), cfg)
    _leaves_equal(stacked, fleet.fleet_stack(oracle))
    assert any(int(s.n_seen) > cfg.capacity for s in oracle), \
        "stream must lap the ring"

    qs, qt = _mixed(T, 15, d, seed=55)
    res = fleet.sann_fleet_query(stacked, params, jnp.asarray(qs),
                                 jnp.asarray(qt, jnp.int32), cfg)
    ids, dists = fleet.sann_fleet_query_topk(
        stacked, params, jnp.asarray(qs), jnp.asarray(qt, jnp.int32), cfg,
        topk=8)
    for t in range(T):
        m = qt == t
        if not m.any():
            continue
        want = sann.sann_query_batch(oracle[t], params, jnp.asarray(qs[m]),
                                     cfg)
        for a, b in zip(res, want):
            np.testing.assert_array_equal(np.asarray(a)[m], np.asarray(b))
        wi, wd = sann.sann_query_topk_batch(oracle[t], params,
                                            jnp.asarray(qs[m]), cfg, topk=8)
        np.testing.assert_array_equal(np.asarray(ids)[m], np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(dists)[m], np.asarray(wd))


def test_sann_row_keys_prefix_stable():
    """The property the cap-padded fleet draws rest on: the first b keys
    of an n-key schedule equal the b-key schedule (NOT true of
    `jax.random.split`, whose threefry counters depend on n)."""
    key = jax.random.PRNGKey(7)
    full = sann.sann_row_keys(key, 64)
    for b in (1, 5, 17, 64):
        np.testing.assert_array_equal(np.asarray(full[:b]),
                                      np.asarray(sann.sann_row_keys(key, b)))


def test_route_chunk_groups_in_stream_order():
    tids = jnp.asarray([2, 0, 2, 1, 0, 2, 5, -1], jnp.int32)  # 5/-1 dropped
    r = fleet.route_chunk(tids, 3, 4)
    np.testing.assert_array_equal(np.asarray(r.counts), [2, 1, 3])
    assert np.asarray(r.take)[0, :2].tolist() == [1, 4]     # tenant 0 rows
    assert np.asarray(r.take)[1, :1].tolist() == [3]
    assert np.asarray(r.take)[2, :3].tolist() == [0, 2, 5]  # stream order
    np.testing.assert_array_equal(
        np.asarray(r.valid),
        np.arange(4)[None, :] < np.asarray(r.counts)[:, None])


# --------------------------------------------------------------------------
# serve.tenant_fleet: LRU hot set, spill/recover
# --------------------------------------------------------------------------


def _race_oracle(tf, streams):
    """Per-tenant single sketches built with the fleet's own params."""
    W = tf.cfg.W
    out = {}
    for t, chunks in streams.items():
        st = tf._empty
        for xs in chunks:
            st = race.race_commit_chunk(
                st, race.race_prepare_chunk(tf._params, jnp.asarray(xs), W))
        out[t] = st
    return out


def test_tenant_fleet_lru_spill_reactivate_bitexact():
    """6 tenants through 3 hot slots: every chunk evicts somebody; the
    reactivated rows must equal never-spilled single sketches bitwise."""
    d, T = 6, 6
    tf = TenantFleet(TenantFleetConfig(kind="race", dim=d, hot_slots=3,
                                       L=4, k=3, W=32, seed=11))
    streams = {t: [] for t in range(T)}
    rng = np.random.default_rng(42)
    for chunk in range(8):
        # rotate a window of 3 tenants so the hot set churns
        active = [(chunk + j) % T for j in range(3)]
        xs = rng.normal(size=(45, d)).astype(np.float32)
        tids = rng.choice(active, size=45)
        tf.ingest(xs, tids)
        for t in active:
            sub = xs[tids == t]
            if sub.shape[0]:
                streams[t].append(sub)
    assert tf.spills > 0, "LRU must actually spill"
    oracle = _race_oracle(tf, streams)
    qs = rng.normal(size=(30, d)).astype(np.float32)
    qt = rng.integers(0, T, size=30)
    got = np.asarray(tf.query(qs, qt))
    for t in range(T):
        m = qt == t
        if m.any():
            np.testing.assert_array_equal(
                got[m], np.asarray(race.race_query_batch(
                    oracle[t], tf._params, jnp.asarray(qs[m]))))
        # reactivate and compare the full row state
        tf._activate([t])
        _leaves_equal(fleet.fleet_row(tf._stacked, tf._slots[t]), oracle[t])
    tf.close()


def test_tenant_fleet_swakde_expiry_and_density():
    d, T = 5, 4
    tf = TenantFleet(TenantFleetConfig(kind="swakde", dim=d, hot_slots=2,
                                       L=4, k=2, W=32, window=16,
                                       eh_eps=0.2, seed=13))
    streams = {t: [] for t in range(T)}
    rng = np.random.default_rng(7)
    for chunk in range(6):
        active = [(chunk + j) % T for j in range(2)]
        xs = rng.normal(size=(40, d)).astype(np.float32)
        tids = rng.choice(active, size=40)
        tf.ingest(xs, tids)
        for t in active:
            sub = xs[tids == t]
            if sub.shape[0]:
                streams[t].append(sub)
    oracle = {}
    for t, chunks in streams.items():
        st = tf._empty
        for xs in chunks:
            st = swakde.swakde_update_chunk(st, tf._params,
                                            jnp.asarray(xs), tf._scfg)
        oracle[t] = st
    assert max(int(s.t) for s in oracle.values()) > tf._scfg.window
    qs = rng.normal(size=(20, d)).astype(np.float32)
    qt = rng.integers(0, T, size=20)
    got = np.asarray(tf.query(qs, qt))
    dens = np.asarray(tf.density(qs, qt))
    for t in range(T):
        m = qt == t
        if m.any():
            want = np.asarray(swakde.swakde_query_batch(
                oracle[t], tf._params, jnp.asarray(qs[m]), tf._scfg))
            np.testing.assert_array_equal(got[m], want)
            denom = max(min(int(oracle[t].t), tf._scfg.window), 1)
            np.testing.assert_array_equal(dens[m], want / denom)
    tf.close()


def test_tenant_fleet_sann_queries_and_split_ops():
    """A single ingest call touching more tenants than hot slots splits
    into multiple ops (stream-order) and still matches the oracle that
    replays the same op split with the fleet's key schedule."""
    d = 4
    tf = TenantFleet(TenantFleetConfig(kind="sann", dim=d, hot_slots=2,
                                       n_max=16, eta=0.3, r=0.5, c=2.0,
                                       w=1.0, L=4, k=2, seed=17))
    cfg = tf._sann_cfg
    rng = np.random.default_rng(3)
    oracle = {t: tf._empty for t in range(4)}
    for call in range(3):
        xs = rng.normal(size=(90, d)).astype(np.float32)
        tids = rng.integers(0, 4, size=90)
        # oracle replays the fleet's own op plan + key schedule
        seq0 = tf.seq
        plan = tf._plan_ops(tids)
        tf.ingest(xs, tids)
        for op, idx in enumerate(plan):
            ck = jax.random.fold_in(tf._base_key, seq0 + op)
            for t in np.unique(tids[idx]):
                sub = xs[idx][tids[idx] == t]
                key_t = jax.random.fold_in(ck, int(t))
                oracle[t] = sann.sann_commit_chunk(
                    oracle[t], sann.sann_prepare_chunk(
                        tf._params, jnp.asarray(sub), key_t, cfg), cfg)
    assert tf.splits > 0, "ops must actually split (4 tenants, 2 slots)"
    qs = rng.normal(size=(12, d)).astype(np.float32)
    qt = rng.integers(0, 4, size=12)
    res = tf.query(qs, qt)
    ids, dists = tf.query_topk(qs, qt, topk=6)
    for t in range(4):
        m = qt == t
        if not m.any():
            continue
        want = sann.sann_query_batch(oracle[t], tf._params,
                                     jnp.asarray(qs[m]), cfg)
        for a, b in zip(res, want):
            np.testing.assert_array_equal(np.asarray(a)[m], np.asarray(b))
        wi, wd = sann.sann_query_topk_batch(oracle[t], tf._params,
                                            jnp.asarray(qs[m]), cfg, topk=6)
        np.testing.assert_array_equal(np.asarray(ids)[m], np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(dists)[m], np.asarray(wd))
    tf.close()


def test_tenant_fleet_durable_recover_bitexact(tmp_path):
    """Crash-recover a durable fleet: snapshot + tenant-tagged WAL tail +
    per-tenant spills rebuild every tenant's state bit-identically —
    including tenants that were cold (spilled) at snapshot time."""
    d, T = 6, 6
    kw = dict(kind="race", dim=d, hot_slots=3, L=4, k=3, W=32, seed=19,
              snapshot_dir=str(tmp_path), snapshot_every=5)
    tf = TenantFleet(TenantFleetConfig(**kw))
    rng = np.random.default_rng(23)
    streams = {t: [] for t in range(T)}
    for chunk in range(11):
        active = [(chunk + j) % T for j in range(3)]
        xs = rng.normal(size=(30, d)).astype(np.float32)
        tids = rng.choice(active, size=30)
        tf.ingest(xs, tids)
        for t in active:
            sub = xs[tids == t]
            if sub.shape[0]:
                streams[t].append(sub)
    qs = rng.normal(size=(18, d)).astype(np.float32)
    qt = rng.integers(0, T, size=18)
    before = np.asarray(tf.query(qs, qt))
    seq = tf.seq
    tf.close()                                   # "crash" after close

    tf2 = TenantFleet(TenantFleetConfig(**kw))
    with pytest.raises(RuntimeError):
        tf2.ingest(qs, qt)                       # must recover first
    tf2.recover()
    assert tf2.seq == seq
    np.testing.assert_array_equal(np.asarray(tf2.query(qs, qt)), before)
    oracle = _race_oracle(tf2, streams)
    for t in range(T):
        tf2._activate([t])
        _leaves_equal(fleet.fleet_row(tf2._stacked, tf2._slots[t]),
                      oracle[t])
    assert tf2.known_tenants == set(range(T))
    tf2.close()


# --------------------------------------------------------------------------
# hypothesis fuzz over mixed-tenant compositions
# --------------------------------------------------------------------------

# Guarded import (NOT importorskip: that would skip the whole module,
# exactness tests above included) — the fuzz tests alone skip without it.
try:
    from hypothesis import given, strategies as st
except ImportError:                      # pragma: no cover
    given = None

_D, _T = 4, 3
_PARAMS = init_srp(jax.random.PRNGKey(29), _D, 4, 2, 16)
_SCFG = swakde.SWAKDEConfig(L=4, W=16, window=8, eh_eps=0.5)
_SPARAMS = init_pstable(jax.random.PRNGKey(31), _D, _SCFG.L, 2, 1.0,
                        _SCFG.W)

if given is not None:
    _comp = st.one_of(
        st.lists(st.integers(0, _T - 1), min_size=1, max_size=40),
        # skewed: one hot tenant + a trickle of others
        st.lists(st.sampled_from([0] * 8 + [1, 2]), min_size=1,
                 max_size=40),
        st.lists(st.just(1), min_size=1, max_size=40),  # single hot tenant
    )
else:                                    # pragma: no cover
    def test_fuzz_requires_hypothesis():
        pytest.skip("property tests need hypothesis")

    _comp = None


@pytest.mark.skipif(given is None, reason="needs hypothesis")
@(given(comp=_comp, seed=st.integers(0, 5)) if given else (lambda f: f))
def test_fuzz_race_fleet_matches_oracle(comp, seed):
    tids = np.asarray(comp, np.int64)
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(len(comp), _D)).astype(np.float32)
    stacked = fleet.fleet_broadcast(race.race_init(4, 16), _T)
    stacked = fleet.race_fleet_ingest(stacked, _PARAMS, jnp.asarray(xs),
                                      jnp.asarray(tids, jnp.int32))
    for t in range(_T):
        sub = xs[tids == t]
        st_o = race.race_init(4, 16)
        if sub.shape[0]:
            st_o = race.race_commit_chunk(
                st_o, race.race_prepare_chunk(_PARAMS, jnp.asarray(sub), 16))
        _leaves_equal(fleet.fleet_row(stacked, t), st_o)


@pytest.mark.skipif(given is None, reason="needs hypothesis")
@(given(comp=_comp, seed=st.integers(0, 5)) if given else (lambda f: f))
def test_fuzz_swakde_fleet_matches_oracle(comp, seed):
    tids = np.asarray(comp, np.int64)
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(len(comp), _D)).astype(np.float32)
    stacked = fleet.fleet_broadcast(swakde.swakde_init(_SCFG), _T)
    cap = int(np.bincount(tids, minlength=_T).max())
    stacked = fleet.swakde_fleet_ingest(
        stacked, _SPARAMS, jnp.asarray(xs), jnp.asarray(tids, jnp.int32),
        _SCFG, cap)
    for t in range(_T):
        sub = xs[tids == t]
        st_o = swakde.swakde_init(_SCFG)
        if sub.shape[0]:
            st_o = swakde.swakde_update_chunk(st_o, _SPARAMS,
                                              jnp.asarray(sub), _SCFG)
        _leaves_equal(fleet.fleet_row(stacked, t), st_o)
