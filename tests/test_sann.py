"""S-ANN streaming (c,r)-ANN guarantees (paper §3, Theorem 3.1/3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sann


def _poisson_planted(key, n, d, r):
    """Poisson-point-process-ish stream: dense uniform cloud, so every r-ball
    near the data support holds ~Poisson(m) points (the paper's syn-32)."""
    return jax.random.uniform(key, (n, d), minval=0.0, maxval=1.0)


def test_sampling_rate_concentrates():
    cfg = sann.SANNConfig(dim=8, n_max=2000, eta=0.25, r=0.5, c=2.0, L=4, k=2)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(0))
    xs = _poisson_planted(jax.random.PRNGKey(1), 2000, 8, 0.5)
    state = sann.sann_insert_stream(state, params, xs, jax.random.PRNGKey(2), cfg)
    expect = 2000 * cfg.keep_prob
    sd = np.sqrt(2000 * cfg.keep_prob * (1 - cfg.keep_prob))
    assert abs(int(state.n_stored) - expect) < 5 * sd
    assert int(state.n_seen) == 2000


def test_query_succeeds_on_dense_stream():
    """(c,r) contract: when r-balls are dense (m*p >> 1), queries near the
    data must return a point within c*r with high rate (Lemma 3.3)."""
    d, n = 4, 4000
    cfg = sann.SANNConfig(dim=d, n_max=n, eta=0.2, r=0.3, c=2.0, w=2.0,
                          L=8, k=4, bucket_cap=32)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(3))
    xs = _poisson_planted(jax.random.PRNGKey(4), n, d, cfg.r)
    state = sann.sann_insert_stream(state, params, xs, jax.random.PRNGKey(5), cfg)

    qs = jax.random.uniform(jax.random.PRNGKey(6), (50, d), minval=0.2, maxval=0.8)
    res = sann.sann_query_batch(state, params, qs, cfg)
    rate = float(res.found.mean())
    assert rate > 0.8, rate
    # returned distances honour the (c,r) contract
    found_d = np.asarray(res.distance)[np.asarray(res.found)]
    assert (found_d <= cfg.c * cfg.r + 1e-5).all()


def test_query_returns_null_far_from_data():
    d, n = 4, 1000
    cfg = sann.SANNConfig(dim=d, n_max=n, eta=0.2, r=0.3, c=2.0, w=2.0, L=8, k=4)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(7))
    xs = _poisson_planted(jax.random.PRNGKey(8), n, d, cfg.r)
    state = sann.sann_insert_stream(state, params, xs, jax.random.PRNGKey(9), cfg)
    q_far = jnp.full((d,), 50.0)
    res = sann.sann_query(state, params, q_far, cfg)
    assert not bool(res.found)
    assert int(res.index) == -1


def test_turnstile_delete_removes_neighbor():
    """§3.4: delete the planted neighbor; query must stop returning it."""
    d = 8
    cfg = sann.SANNConfig(dim=d, n_max=500, eta=0.0, r=0.5, c=2.0, w=2.0, L=8, k=3)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(10))
    q = jnp.full((d,), 0.5)
    planted = q + 0.1  # distance 0.1*sqrt(8) ~ 0.28 < r
    far = 10.0 + jax.random.uniform(jax.random.PRNGKey(11), (100, d))
    stream = jnp.concatenate([far[:50], planted[None], far[50:]])
    state = sann.sann_insert_stream(state, params, stream, jax.random.PRNGKey(12), cfg)

    res_before = sann.sann_query(state, params, q, cfg)
    assert bool(res_before.found)
    stored_before = int(state.n_stored)
    state = sann.sann_delete(state, params, planted, cfg)
    res_after = sann.sann_query(state, params, q, cfg)
    assert not bool(res_after.found)
    assert int(state.n_stored) == stored_before - 1  # exactly one tombstone


def test_batch_query_matches_single():
    d = 8
    cfg = sann.SANNConfig(dim=d, n_max=300, eta=0.1, r=0.4, c=2.0, w=2.0, L=6, k=3)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(13))
    xs = _poisson_planted(jax.random.PRNGKey(14), 300, d, cfg.r)
    state = sann.sann_insert_stream(state, params, xs, jax.random.PRNGKey(15), cfg)
    qs = xs[:8]
    batch = sann.sann_query_batch(state, params, qs, cfg)
    for i in range(8):
        single = sann.sann_query(state, params, qs[i], cfg)
        assert int(batch.index[i]) == int(single.index)
        assert bool(batch.found[i]) == bool(single.found)


def test_candidate_budget_is_3L():
    d = 4
    cfg = sann.SANNConfig(dim=d, n_max=500, eta=0.0, r=0.3, c=2.0, w=2.0, L=4, k=1,
                          bucket_cap=64)
    cfg, params, state = sann.sann_init(cfg, jax.random.PRNGKey(16))
    # all points identical → all collide → candidate list saturates at 3L
    xs = jnp.ones((200, d)) * 0.5
    state = sann.sann_insert_stream(state, params, xs, jax.random.PRNGKey(17), cfg)
    res = sann.sann_query(state, params, xs[0], cfg)
    assert int(res.n_candidates) <= 3 * cfg.L


def test_memory_is_sublinear_in_eta():
    """Fig. 5 claim: sketch bytes shrink as eta grows, and scale ~n^{1-eta}."""
    sizes = []
    for eta in (0.2, 0.5, 0.8):
        cfg = sann.SANNConfig(dim=128, n_max=100_000, eta=eta, r=0.5, c=2.0, L=16, k=8)
        sizes.append(sann.sann_bytes(cfg))
    assert sizes[0] > sizes[1] > sizes[2]
    # n^{1-eta} scaling (up to the constant-size floor)
    ratio = sizes[0] / sizes[1]
    assert ratio > 5  # 100k^{0.3} ~ 31.6x ideal; tables add overhead
