"""Multi-device semantics (subprocesses with 8 virtual CPU devices):
sharded step == single-device step; EP MoE == dense MoE; compressed DP
all-reduce ≈ exact with error feedback; sketch sharding
(repro.parallel.sketch_sharding) == single-device sketches bit-for-bit;
sketch merges are associative.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(body: str) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n" +
              textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=480)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.launch.inputs import make_batch
        from repro.launch.mesh import make_test_mesh
        from repro.optim.adam import adamw
        from repro.parallel import param_specs
        from repro.train.train_step import make_train_step
        import dataclasses

        cfg = dataclasses.replace(registry.get_smoke_config("qwen3-4b"),
                                  param_dtype="float32", seq_parallel=True)
        key = jax.random.PRNGKey(0)
        batch = make_batch(cfg, batch=8, seq=32, key=jax.random.PRNGKey(1))

        # single device
        step1, init1 = make_train_step(cfg, adamw(lr=1e-3), mesh=None)
        s1 = init1(key)
        s1, m1 = jax.jit(step1)(s1, batch)

        # 2x4 mesh with full sharding machinery
        mesh = make_test_mesh(2, 4)
        stepN, initN = make_train_step(cfg, adamw(lr=1e-3), mesh=mesh)
        sN = initN(key)
        p_sh = param_specs.param_shardings(sN.params, mesh)
        o_sh = param_specs.opt_state_shardings(sN.opt_state, p_sh, mesh)
        state_sh = type(sN)(params=p_sh, opt_state=o_sh,
                            step=NamedSharding(mesh, P()))
        b_sh = param_specs.batch_shardings(batch, mesh)
        sN = jax.device_put(sN, state_sh)
        batchN = jax.device_put(batch, b_sh)
        sN, mN = jax.jit(stepN, in_shardings=(state_sh, b_sh),
                         donate_argnums=(0,))(sN, batchN)

        print("loss1", float(m1["loss"]), "lossN", float(mN["loss"]))
        assert abs(float(m1["loss"]) - float(mN["loss"])) < 2e-4
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(sN.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        print("SHARDED_MATCH_OK")
    """)
    assert "SHARDED_MATCH_OK" in out


def test_moe_ep_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.models import moe
        from repro.parallel.sharding import make_ctx

        key = jax.random.PRNGKey(0)
        d, E, k = 32, 8, 2
        p = moe.init_moe(key, d, E, n_shared=1, d_ff_expert=16,
                         dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
        y_dense, aux_d = moe.moe_dense(p, x, topk=k, capacity_factor=64.0)

        mesh = make_test_mesh(2, 4)
        ctx = make_ctx(mesh)
        # place params per the EP layout (experts on model, FSDP on data)
        p_ep = dict(p)
        p_ep["w_gate"] = jax.device_put(p["w_gate"], NamedSharding(mesh, P("model", "data", None)))
        p_ep["w_up"] = jax.device_put(p["w_up"], NamedSharding(mesh, P("model", "data", None)))
        p_ep["w_down"] = jax.device_put(p["w_down"], NamedSharding(mesh, P("model", None, "data")))
        x_ep = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y_ep, aux_e = jax.jit(lambda p, x: moe.moe_ep(
            p, x, topk=k, capacity_factor=64.0, ctx=ctx))(p_ep, x_ep)

        # EP combines its psum in bf16 (§Perf hillclimb 2) — equivalence
        # holds to bf16 rounding of the routed-expert contribution
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                                   rtol=2e-2, atol=2e-2)
        assert float(jnp.abs(jnp.asarray(y_dense) - jnp.asarray(y_ep)).mean()) < 5e-3
        # aux is a per-shard load-balance estimate (Switch-style): close but
        # not identical to the global product
        np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=5e-2)
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out


def test_compressed_allreduce_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.optim import compress

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 130))

        def worker(g_local, e_local):
            (r, e2) = compress.compressed_pmean(
                {"w": g_local}, compress.ErrorState(err={"w": e_local}), "data")
            return r["w"], e2.err["w"]

        f = jax.jit(jax.shard_map(worker, mesh=mesh,
                                  in_specs=(P("data"), P("data")),
                                  out_specs=(P("data"), P("data")),
                                  check_vma=False))
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        err = jnp.zeros_like(g)
        # accumulate over steps: error feedback keeps the running sum honest
        tot_comp = jnp.zeros_like(g[0])
        tot_exact = jnp.zeros_like(g[0])
        for i in range(20):
            red, err = f(g * (1 + 0.01 * i), err)
            tot_comp = tot_comp + red[0]
            tot_exact = tot_exact + (g * (1 + 0.01 * i)).mean(0)
        one_step_err = float(jnp.abs(red[0] - (g * 1.19).mean(0)).max())
        accum_err = float(jnp.abs(tot_comp - tot_exact).max())
        rel = accum_err / float(jnp.abs(tot_exact).max())
        print("one-step", one_step_err, "accum rel", rel)
        assert rel < 0.02  # error feedback keeps long-run bias tiny
        exact_b, comp_b = compress.bytes_saved_per_step({"w": g[0]})
        assert comp_b < 0.3 * exact_b
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


# ---------------------------------------------------------------------------
# Sketch sharding (repro.parallel.sketch_sharding): 8 forced host devices,
# sharded state and query results must equal single-device bit-for-bit.
# ---------------------------------------------------------------------------

def test_sharded_race_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import lsh, race
        from repro.parallel import sketch_sharding as ss

        L, W, d = 16, 32, 12
        params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=3, n_buckets=W)
        xs = jax.random.normal(jax.random.PRNGKey(1), (300, d))
        qs = jax.random.normal(jax.random.PRNGKey(2), (7, d))

        ref = race.race_update_batch(race.race_init(L, W), params, xs)
        ref = race.race_update_batch(ref, params, xs[:50], sign=-1)  # turnstile

        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        st, p = ss.shard_race(race.race_init(L, W), params, ctx)
        st = ss.sharded_race_update_batch(st, p, xs, ctx)
        st = ss.sharded_race_update_batch(st, p, xs[:50], ctx, sign=-1)
        assert (np.asarray(st.counts) == np.asarray(ref.counts)).all()
        assert int(st.n) == int(ref.n)
        for mom in (0, 4):
            np.testing.assert_array_equal(
                np.asarray(ss.sharded_race_query_batch(st, p, qs, ctx, mom)),
                np.asarray(race.race_query_batch(ref, params, qs, mom)))
        print("RACE_SHARDED_OK")
    """)
    assert "RACE_SHARDED_OK" in out


def test_sharded_swakde_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import lsh, swakde
        from repro.parallel import sketch_sharding as ss

        d = 10
        cfg = swakde.SWAKDEConfig(L=8, W=32, window=120, eh_eps=0.15)
        params = lsh.init_srp(jax.random.PRNGKey(0), d, L=8, k=2, n_buckets=32)
        xs = jax.random.normal(jax.random.PRNGKey(1), (250, d))
        qs = jax.random.normal(jax.random.PRNGKey(2), (6, d))

        ref = swakde.swakde_init(cfg)
        for i in range(0, 250, 100):   # uneven final chunk on purpose
            ref = swakde.swakde_update_chunk(ref, params, xs[i:i+100], cfg)

        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        st, p = ss.shard_swakde(swakde.swakde_init(cfg), params, ctx)
        for i in range(0, 250, 100):
            st = ss.sharded_swakde_update_chunk(st, p, xs[i:i+100], cfg, ctx)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
            assert (np.asarray(a) == np.asarray(b)).all()
        np.testing.assert_array_equal(
            np.asarray(ss.sharded_swakde_query_batch(st, p, qs, cfg, ctx)),
            np.asarray(swakde.swakde_query_batch(ref, params, qs, cfg)))
        print("SWAKDE_SHARDED_OK")
    """)
    assert "SWAKDE_SHARDED_OK" in out


def test_sharded_sann_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sann
        from repro.parallel import sketch_sharding as ss

        d = 12
        cfg = sann.SANNConfig(dim=d, n_max=2000, eta=0.35, r=0.8, c=2.0,
                              w=1.6, L=16, k=4)
        cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(0))
        stream = jnp.asarray(np.random.default_rng(1).uniform(
            0, 1, (600, d)).astype(np.float32))
        key = jax.random.PRNGKey(2)
        qs = stream[:9] + 0.01

        ref = sann.sann_insert_batch(st0, params, stream, key, cfg)
        ref = sann.sann_delete(ref, params, stream[3], cfg)

        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        st, p = ss.shard_sann(st0, params, ctx)
        st = ss.sharded_sann_insert_batch(st, p, stream, key, cfg, ctx)
        st = ss.sharded_sann_delete(st, p, stream[3], cfg, ctx)
        for nm, a, b in zip(ref._fields, st, ref):
            assert (np.asarray(a) == np.asarray(b)).all(), nm

        r_ref = sann.sann_query_batch(ref, params, qs, cfg)
        r_sh = ss.sharded_sann_query_batch(st, p, qs, cfg, ctx)
        for nm, a, b in zip(r_ref._fields, r_sh, r_ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=nm)
        tk_ref = sann.sann_query_topk_batch(ref, params, qs, cfg, topk=10)
        tk_sh = ss.sharded_sann_query_topk_batch(st, p, qs, cfg, ctx, topk=10)
        np.testing.assert_array_equal(np.asarray(tk_sh[0]),
                                      np.asarray(tk_ref[0]))
        np.testing.assert_array_equal(np.asarray(tk_sh[1]),
                                      np.asarray(tk_ref[1]))
        print("SANN_SHARDED_OK")
    """)
    assert "SANN_SHARDED_OK" in out


def test_sharded_two_phase_matches_single_device():
    """Sharded prepare→commit (both phases under shard_map) is bit-identical
    to the fused sharded ingest and to single-device, for all three
    sketches; the sharded grid-estimate table (the KDE service's snapshot
    cache) matches the single-device table and its reads match the fused
    query path."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import lsh, race, sann, swakde
        from repro.parallel import sketch_sharding as ss

        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        d = 10
        xs = jax.random.normal(jax.random.PRNGKey(1), (230, d))

        # RACE: two chunks through sharded prepare -> commit
        L, W = 16, 32
        params = lsh.init_srp(jax.random.PRNGKey(0), d, L=L, k=2, n_buckets=W)
        ref = race.race_update_batch(race.race_init(L, W), params, xs[:130])
        ref = race.race_update_batch(ref, params, xs[130:], sign=-1)
        st, p = ss.shard_race(race.race_init(L, W), params, ctx)
        st = ss.sharded_race_commit_chunk(
            st, ss.sharded_race_prepare_chunk(p, xs[:130], W, ctx), ctx)
        st = ss.sharded_race_commit_chunk(
            st, ss.sharded_race_prepare_chunk(p, xs[130:], W, ctx), ctx,
            sign=-1)
        assert (np.asarray(st.counts) == np.asarray(ref.counts)).all()
        assert int(st.n) == int(ref.n)

        # SW-AKDE: prepare-ahead (both preps before any commit), then the
        # sharded grid table vs single-device + fused query reads
        cfg = swakde.SWAKDEConfig(L=8, W=32, window=120, eh_eps=0.15)
        params = lsh.init_srp(jax.random.PRNGKey(2), d, L=8, k=2,
                              n_buckets=32)
        ref = swakde.swakde_init(cfg)
        for i in range(0, 230, 100):   # uneven final chunk on purpose
            ref = swakde.swakde_update_chunk(ref, params, xs[i:i+100], cfg)
        st, p = ss.shard_swakde(swakde.swakde_init(cfg), params, ctx)
        preps = [ss.sharded_swakde_prepare_chunk(p, xs[i:i+100], cfg, ctx)
                 for i in range(0, 230, 100)]
        for prep in preps:
            st = ss.sharded_swakde_commit_chunk(st, prep, cfg, ctx)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
            assert (np.asarray(a) == np.asarray(b)).all()
        grid = ss.sharded_swakde_grid_estimates(st, cfg, ctx)
        np.testing.assert_array_equal(
            np.asarray(grid), np.asarray(swakde.swakde_grid_estimates(ref,
                                                                      cfg)))
        qs = xs[:7]
        np.testing.assert_array_equal(
            np.asarray(ss.sharded_swakde_query_from_grid(grid, p, qs, cfg,
                                                         ctx)),
            np.asarray(swakde.swakde_query_batch(ref, params, qs, cfg)))

        # S-ANN: sharded prepare -> commit under ring wrap vs single-device
        cfg = sann.SANNConfig(dim=d, n_max=600, eta=0.1, r=0.8, c=2.0,
                              w=1.6, L=16, k=4, capacity_slack=0.5)
        cfg, params, st0 = sann.sann_init(cfg, jax.random.PRNGKey(3))
        stream = jnp.asarray(np.random.default_rng(4).uniform(
            0, 1, (600, d)).astype(np.float32))
        ckeys = jax.random.split(jax.random.PRNGKey(5), 3)
        ref = st0
        for i, k in zip(range(0, 600, 200), ckeys):
            ref = sann.sann_insert_batch(ref, params, stream[i:i+200], k,
                                         cfg)
        st, p = ss.shard_sann(st0, params, ctx)
        for i, k in zip(range(0, 600, 200), ckeys):
            prep = ss.sharded_sann_prepare_chunk(p, stream[i:i+200], k, cfg,
                                                 ctx)
            st = ss.sharded_sann_commit_chunk(st, prep, cfg, ctx)
        for nm, a, b in zip(ref._fields, st, ref):
            assert (np.asarray(a) == np.asarray(b)).all(), nm
        print("TWO_PHASE_SHARDED_OK")
    """)
    assert "TWO_PHASE_SHARDED_OK" in out


def test_sharded_services_match_single_device():
    out = _run("""
        import numpy as np
        from repro.serve.retrieval import RetrievalConfig, RetrievalService
        from repro.serve.kde_service import KDEServiceConfig, KDEService

        rng = np.random.default_rng(0)
        emb = rng.normal(0, 1, (700, 24)).astype(np.float32)
        qs = emb[:5] + 0.01

        kw = dict(dim=24, n_max=5000, eta=0.4, r=0.6, c=2.0, ingest_chunk=256)
        r1 = RetrievalService(RetrievalConfig(**kw))
        r8 = RetrievalService(RetrievalConfig(**kw, num_shards=8))
        assert (r1.num_shards, r8.num_shards) == (1, 8)
        r1.ingest(emb); r8.ingest(emb)
        assert r1.stored == r8.stored
        q1, q8 = r1.query(qs), r8.query(qs)
        for nm, a, b in zip(q1._fields, q1, q8):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=nm)

        kk = dict(dim=24, L=16, W=64, window=500, ingest_chunk=256)
        k1 = KDEService(KDEServiceConfig(**kk))
        k8 = KDEService(KDEServiceConfig(**kk, num_shards=8))
        k1.ingest(emb); k8.ingest(emb)
        np.testing.assert_array_equal(k1.density(qs), k8.density(qs))
        print("SERVICES_SHARDED_OK")
    """)
    assert "SERVICES_SHARDED_OK" in out


# ---------------------------------------------------------------------------
# Merge APIs (multi-worker ingestion): single-device semantics, no mesh.
# ---------------------------------------------------------------------------

def test_race_merge_associative_commutative():
    import jax
    import numpy as np
    from repro.core import lsh, race

    params = lsh.init_srp(jax.random.PRNGKey(0), 8, L=6, k=2, n_buckets=16)
    xs = jax.random.normal(jax.random.PRNGKey(1), (240, 8))
    parts = [race.race_update_batch(race.race_init(6, 16), params, xs[i::3])
             for i in range(3)]
    a, b, c = parts

    def eq(x, y):
        return all((np.asarray(u) == np.asarray(v)).all()
                   for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)))

    assert eq(race.race_merge(race.race_merge(a, b), c),
              race.race_merge(a, race.race_merge(b, c)))
    assert eq(race.race_merge(a, b), race.race_merge(b, a))
    # merge of partition == one sketch over the whole stream (any order)
    whole = race.race_update_batch(race.race_init(6, 16), params, xs)
    merged = race.race_merge(race.race_merge(a, b), c)
    assert (np.asarray(merged.counts) == np.asarray(whole.counts)).all()
    assert int(merged.n) == int(whole.n)


def test_swakde_merge_semantics():
    """swakde_merge is the exact EH bucket-union merge: commutative bitwise,
    mass-preserving, associative at the estimate level (bucket *structure*
    may differ by one cascade level between groupings), and a merge of
    sketches over disjoint sub-streams estimates the sum of their windows."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import lsh, swakde

    d = 8
    # window < substream length, so cells hold buckets at the expiry
    # boundary (ts == t - 1 - window) — the case where the merge clock must
    # match the query clock (state.t - 1), not state.t.
    cfg = swakde.SWAKDEConfig(L=6, W=24, window=80, eh_eps=0.2)
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=6, k=2, n_buckets=24)
    xs = jax.random.normal(jax.random.PRNGKey(1), (300, d))
    qs = jax.random.normal(jax.random.PRNGKey(2), (5, d))

    # three workers over disjoint sub-streams, same shared clock length
    parts = [swakde.swakde_update_chunk(swakde.swakde_init(cfg), params,
                                        xs[i::3], cfg) for i in range(3)]
    a, b, c = parts

    ab = swakde.swakde_merge(a, b, cfg)
    ba = swakde.swakde_merge(b, a, cfg)
    for u, v in zip(jax.tree.leaves(ab), jax.tree.leaves(ba)):
        assert (np.asarray(u) == np.asarray(v)).all()   # commutative bitwise

    ab_c = swakde.swakde_merge(ab, c, cfg)
    a_bc = swakde.swakde_merge(a, swakde.swakde_merge(b, c, cfg), cfg)

    def mass(st):
        eh = cfg.eh_config()
        idx = np.arange(eh.slots)[None, :]
        ts, num = np.asarray(st.ts), np.asarray(st.num)
        # live at the query clock t - 1, the convention every reader uses
        live = (idx < num[..., None]) & (ts > int(st.t) - 1 - cfg.window)
        sizes = (1 << np.arange(eh.levels))[:, None]
        return (live * sizes).sum(axis=(-2, -1))

    np.testing.assert_array_equal(mass(ab_c), mass(a_bc))  # mass exact
    e1 = np.asarray(swakde.swakde_query_batch(ab_c, params, qs, cfg))
    e2 = np.asarray(swakde.swakde_query_batch(a_bc, params, qs, cfg))
    np.testing.assert_allclose(e1, e2, rtol=2 * cfg.kde_eps, atol=1.0)

    # identity: merging with an empty sketch changes no *live* state — the
    # merge may compact buckets `a` only expires lazily, so the invariant
    # is estimate equality (bitwise) + in-window mass, not raw num equality
    empty = swakde.swakde_init(cfg)._replace(t=a.t)
    ida = swakde.swakde_merge(a, empty, cfg)
    np.testing.assert_array_equal(
        np.asarray(swakde.swakde_query_batch(ida, params, qs, cfg)),
        np.asarray(swakde.swakde_query_batch(a, params, qs, cfg)))
    np.testing.assert_array_equal(mass(ida), mass(a))

    # disjoint-substream correctness: merged window ≈ sum of part windows
    est_m = np.asarray(swakde.swakde_query_batch(ab_c, params, qs, cfg))
    est_sum = sum(np.asarray(swakde.swakde_query_batch(p_, params, qs, cfg))
                  for p_ in parts)
    np.testing.assert_allclose(est_m, est_sum,
                               rtol=3 * cfg.kde_eps, atol=1.5)


def test_merge_fuzz_identity_and_associativity():
    """Property fuzz over randomized 3-way stream splits (PR-5 satellite):

      * RACE — empty merge is the bitwise identity; associativity holds
        bitwise for every random split;
      * SW-AKDE — empty merge is a live-state identity (estimates + mass);
        associativity is bit-exact at the estimate level while nothing has
        expired (window >= stream), and mass-exact always.
    """
    import jax
    import numpy as np
    from repro.core import lsh, race, swakde

    d = 6
    params = lsh.init_srp(jax.random.PRNGKey(0), d, L=4, k=2, n_buckets=16)
    cfg = swakde.SWAKDEConfig(L=4, W=16, window=100_000, eh_eps=0.25)
    qs = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (4, d)))

    def eq(x, y):
        return all((np.asarray(u) == np.asarray(v)).all()
                   for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)))

    for seed in range(4):
        rng = np.random.default_rng(seed)
        xs = rng.normal(0, 1, (180, d)).astype(np.float32)
        pid = rng.integers(0, 3, len(xs))        # randomized 3-way split

        # --- RACE: bitwise identity + associativity ------------------------
        parts = [race.race_update_batch(race.race_init(4, 16), params,
                                        xs[pid == w]) for w in range(3)]
        a, b, c = parts
        assert eq(race.race_merge(a, race.race_init(4, 16)), a)
        assert eq(race.race_merge(race.race_init(4, 16), a), a)
        assert eq(race.race_merge(race.race_merge(a, b), c),
                  race.race_merge(a, race.race_merge(b, c)))

        # --- SW-AKDE: live-state identity + estimate associativity ---------
        sparts = [swakde.swakde_update_chunk(swakde.swakde_init(cfg), params,
                                             xs[pid == w], cfg)
                  for w in range(3)]
        sa, sb, sc = sparts
        est = lambda st: np.asarray(
            swakde.swakde_query_batch(st, params, qs, cfg))
        empty = swakde.swakde_init(cfg)._replace(t=sa.t)
        np.testing.assert_array_equal(est(swakde.swakde_merge(sa, empty,
                                                              cfg)), est(sa))
        lhs = swakde.swakde_merge(swakde.swakde_merge(sa, sb, cfg), sc, cfg)
        rhs = swakde.swakde_merge(sa, swakde.swakde_merge(sb, sc, cfg), cfg)
        # no expiry -> the bucket-union canonicalises: estimates bit-exact
        np.testing.assert_array_equal(est(lhs), est(rhs))
        # ... and equal to one sketch over the whole stream
        whole = swakde.swakde_update_chunk(swakde.swakde_init(cfg), params,
                                           xs, cfg)
        np.testing.assert_array_equal(est(lhs), est(whole))


def test_merge_counter_saturation():
    """Counter-overflow audit (PR-5 satellite): every merge combines its
    stream counters through core.util.saturating_add — near-INT32_MAX
    inputs clamp instead of wrapping negative."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import lsh, race, sann
    from repro.core.util import _INT32_MAX

    big = int(_INT32_MAX) - 5
    a = race.RACEState(counts=jnp.zeros((2, 4), jnp.int32),
                       n=jnp.int32(big))
    b = race.RACEState(counts=jnp.ones((2, 4), jnp.int32),
                       n=jnp.int32(100))
    m = race.race_merge(a, b)
    assert int(m.n) == int(_INT32_MAX)          # clamped, not wrapped
    assert int(race.race_merge(b, a).n) == int(_INT32_MAX)

    cfg, params, empty = sann.sann_init(
        sann.SANNConfig(dim=4, n_max=100, eta=0.5, r=0.5, c=2.0, w=1.0,
                        L=2, k=2), jax.random.PRNGKey(0))
    xs = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    sa = sann.sann_insert_batch(empty, params, xs[:16],
                                jax.random.PRNGKey(2), cfg)
    sb = sann.sann_insert_batch(empty, params, xs[16:],
                                jax.random.PRNGKey(3), cfg)
    sa = sa._replace(n_seen=jnp.int32(big))
    merged = sann.sann_merge(sa, sb, params, cfg)
    assert int(merged.n_seen) == int(_INT32_MAX)
    # sanity: un-saturated counters still add exactly
    assert int(sann.sann_merge(
        sa._replace(n_seen=jnp.int32(16)), sb, params, cfg).n_seen) == 32


def test_sann_merge_disjoint_streams():
    """`sann_merge` semantics at the core level: two workers ingest
    disjoint halves of a stream under a shared per-point key schedule; the
    merged sketch equals a single sketch fed the canonical stamp
    interleaving — bitwise (except the stamp clocks) without eviction, and
    with eviction (union > capacity) the live point set, tombstone
    consistency and nearest-neighbor answers still match."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import sann

    def ingest_perpoint(state, params, rows, keys, cfg):
        for r, k in zip(rows, keys):
            state = sann.sann_insert(state, params, jnp.asarray(r), k, cfg)
        return state

    def run_case(n_points, cfg_kw, expect_evict):
        cfg, params, empty = sann.sann_init(
            sann.SANNConfig(**cfg_kw), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        data = rng.uniform(0, 1, (n_points, cfg.dim)).astype(np.float32)
        master = jax.random.PRNGKey(5)
        gkeys = [jax.random.fold_in(master, i) for i in range(n_points)]
        pid = np.arange(n_points) % 2            # round-robin split: the
        # canonical (local idx, worker) interleaving == original order
        wa = ingest_perpoint(empty, params, data[pid == 0],
                             [gkeys[i] for i in range(n_points)
                              if pid[i] == 0], cfg)
        wb = ingest_perpoint(empty, params, data[pid == 1],
                             [gkeys[i] for i in range(n_points)
                              if pid[i] == 1], cfg)
        ref = ingest_perpoint(empty, params, data, gkeys, cfg)
        m = sann.sann_merge(wa, wb, params, cfg)

        live = lambda st: sorted(
            map(tuple, np.asarray(st.points)[np.asarray(st.valid)].tolist()))
        assert live(m) == live(ref), "live point sets differ"
        # tombstone consistency: every table entry points at a live slot
        tb = np.asarray(m.tables)
        assert np.asarray(m.valid)[tb[tb >= 0]].all()
        assert int(m.n_stored) == int(np.asarray(m.valid).sum())
        assert int(m.n_seen) == n_points

        qs = jnp.asarray(data[:8] + 0.01)
        rm = sann.sann_query_batch(m, params, qs, cfg)
        rr = sann.sann_query_batch(ref, params, qs, cfg)
        np.testing.assert_allclose(np.asarray(rm.distance),
                                   np.asarray(rr.distance), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(rm.found),
                                      np.asarray(rr.found))
        if not expect_evict:
            # no eviction: full bitwise equality except the stamp clocks
            for name, (u, v) in zip(m._fields, zip(m, ref)):
                if name == "stamps":
                    continue
                np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                              err_msg=f"field {name!r}")
            np.testing.assert_array_equal(np.asarray(rm.index),
                                          np.asarray(rr.index))
        else:
            assert int(m.n_stored) == cfg.capacity, "union must evict"

    # eta=0, stream < capacity: exact case
    run_case(200, dict(dim=6, n_max=100, eta=0.0, r=0.4, c=2.0, w=1.0,
                       L=4, k=2, bucket_cap=4), expect_evict=False)
    # eta=0, stream > capacity: union eviction + tombstones (capacity =
    # max(64, 4 * 16^1.0) = 64 < 300 kept)
    run_case(300, dict(dim=6, n_max=16, eta=0.0, r=0.4, c=2.0, w=1.0,
                       L=4, k=2, bucket_cap=4, capacity_slack=1.0),
             expect_evict=True)


def test_sann_merge_fold_associative_stored_set():
    """K-way fold associativity at the stored-set level, *with* eviction:
    folding sann_merge in any grouping over 3 workers keeps the same live
    point set (the newest-capacity rule commutes with folding), matching
    the newest-capacity of the canonical interleaved union."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import sann

    cfg, params, empty = sann.sann_init(sann.SANNConfig(
        dim=6, n_max=16, eta=0.0, r=0.4, c=2.0, w=1.0, L=4, k=2,
        bucket_cap=4, capacity_slack=1.0), jax.random.PRNGKey(0))
    assert cfg.capacity == 64
    rng = np.random.default_rng(1)
    data = rng.uniform(0, 1, (270, 6)).astype(np.float32)   # > capacity
    key = jax.random.PRNGKey(7)
    parts = [sann.sann_insert_batch(empty, params, jnp.asarray(data[w::3]),
                                    key, cfg) for w in range(3)]
    a, b, c = parts
    m = lambda x, y: sann.sann_merge(x, y, params, cfg)
    lhs = m(m(a, b), c)
    rhs = m(a, m(b, c))
    live = lambda st: sorted(
        map(tuple, np.asarray(st.points)[np.asarray(st.valid)].tolist()))
    assert int(lhs.n_stored) == cfg.capacity        # eviction happened
    assert live(lhs) == live(rhs)
    # and both equal the newest-capacity of the stamp-interleaved union:
    # stamps are the per-worker local indices, ties broken worker-first,
    # so the newest 64 points of the (local_idx, worker) order survive.
    order = []
    for j in range(90):
        for w in range(3):
            order.append(data[w::3][j])
    expect = sorted(map(tuple, np.asarray(order[-cfg.capacity:],
                                          np.float32).tolist()))
    assert live(lhs) == expect


def test_sharded_sann_merge_matches_single_device():
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.core import sann
        from repro.parallel import sketch_sharding as ss

        cfg, params, empty = sann.sann_init(sann.SANNConfig(
            dim=8, n_max=500, eta=0.2, r=0.4, c=2.0, w=1.0, L=8, k=2,
            bucket_cap=4), jax.random.PRNGKey(0))
        xs = jax.random.normal(jax.random.PRNGKey(1), (256, 8))
        a = sann.sann_insert_batch(empty, params, xs[:128],
                                   jax.random.PRNGKey(2), cfg)
        b = sann.sann_insert_batch(empty, params, xs[128:],
                                   jax.random.PRNGKey(3), cfg)
        single = sann.sann_merge(a, b, params, cfg)

        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        a8, params8 = ss.shard_sann(a, params, ctx)
        b8, _ = ss.shard_sann(b, params, ctx)
        merged8 = ss.sharded_sann_merge(a8, b8, params8, cfg, ctx)
        for name, (u, v) in zip(single._fields, zip(single, merged8)):
            np.testing.assert_array_equal(
                np.asarray(u), np.asarray(v), err_msg=name)
        print("SANN_MERGE_SHARDED_OK")
    """)
    assert "SANN_MERGE_SHARDED_OK" in out


def test_sharded_sann_recovery_matches_single_run():
    """Durability composes with sharding: a table-sharded durable service
    crash-recovers onto the mesh (`_place_state` re-shards the
    host-restored snapshot) bit-identically to the uninterrupted sharded
    run."""
    out = _run("""
        import tempfile
        import numpy as np, jax
        from repro.serve.retrieval import RetrievalConfig, RetrievalService

        kw = dict(dim=8, n_max=1000, eta=0.2, r=0.4, c=2.0, w=1.0, L=8,
                  k=3, ingest_chunk=64, num_shards=8)
        data = np.random.default_rng(0).uniform(
            0, 1, (400, 8)).astype(np.float32)
        with tempfile.TemporaryDirectory() as d:
            ref = RetrievalService(RetrievalConfig(**kw))
            ref.ingest(data)
            a = RetrievalService(RetrievalConfig(
                **kw, snapshot_dir=d, snapshot_every=3))
            a.ingest(data)     # crash point: WAL + snapshots on disk
            a.close()
            b = RetrievalService(RetrievalConfig(
                **kw, snapshot_dir=d, snapshot_every=3))
            replayed = b.recover()
            assert replayed < -(-400 // 64), "snapshot must be used"
            for name, (u, v) in zip(ref.state._fields,
                                    zip(b.state, ref.state)):
                np.testing.assert_array_equal(
                    np.asarray(u), np.asarray(v), err_msg=name)
            qs = np.asarray(data[:6] + 0.01, np.float32)
            rb, rr = b.query(qs), ref.query(qs)
            for x, y in zip(rb, rr):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            b.close()
        print("SHARDED_RECOVERY_OK")
    """)
    assert "SHARDED_RECOVERY_OK" in out


def test_sharded_fleet_matches_single_device():
    """Tenant-axis fleet sharding: a stacked [T] fleet split over 8 shards
    (T/8 whole sketches per device, params replicated, routing local)
    ingests a mixed chunk and answers mixed-tenant queries bit-identically
    to the unsharded vmapped fleet."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import fleet, lsh, race, sann, swakde
        from repro.parallel import sketch_sharding as ss

        T, d = 16, 10
        ctx = ss.make_sketch_ctx(ss.make_sketch_mesh(8))
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.normal(size=(200, d)).astype(np.float32))
        tids = jnp.asarray(rng.integers(0, T, size=200), jnp.int32)
        qs = jnp.asarray(rng.normal(size=(30, d)).astype(np.float32))
        qt = jnp.asarray(rng.integers(0, T, size=30), jnp.int32)

        # RACE fleet
        params = lsh.init_srp(jax.random.PRNGKey(0), d, L=6, k=3,
                              n_buckets=32)
        ref = fleet.race_fleet_ingest(
            fleet.fleet_broadcast(race.race_init(6, 32), T), params, xs,
            tids)
        st, p = ss.shard_fleet(
            fleet.fleet_broadcast(race.race_init(6, 32), T), params, ctx)
        st = ss.sharded_race_fleet_ingest(st, p, xs, tids, ctx)
        assert (np.asarray(st.counts) == np.asarray(ref.counts)).all()
        assert (np.asarray(st.n) == np.asarray(ref.n)).all()
        np.testing.assert_array_equal(
            np.asarray(ss.sharded_race_fleet_query(st, p, qs, qt, ctx)),
            np.asarray(fleet.race_fleet_query(ref, params, qs, qt)))

        # SW-AKDE fleet (window < per-tenant stream: expiry crosses shards)
        cfg = swakde.SWAKDEConfig(L=4, W=32, window=8, eh_eps=0.2)
        sp = lsh.init_pstable(jax.random.PRNGKey(1), d, 4, 2, 1.0, 32)
        cap = int(np.bincount(np.asarray(tids), minlength=T).max())
        sref = fleet.swakde_fleet_ingest(
            fleet.fleet_broadcast(swakde.swakde_init(cfg), T), sp, xs,
            tids, cfg, cap)
        sst, spp = ss.shard_fleet(
            fleet.fleet_broadcast(swakde.swakde_init(cfg), T), sp, ctx)
        sst = ss.sharded_swakde_fleet_ingest(sst, spp, xs, tids, cfg, cap,
                                             ctx)
        for a, b in zip(jax.tree.leaves(sst), jax.tree.leaves(sref)):
            assert (np.asarray(a) == np.asarray(b)).all()
        np.testing.assert_array_equal(
            np.asarray(ss.sharded_swakde_fleet_query(sst, spp, qs, qt, cfg,
                                                     ctx)),
            np.asarray(fleet.swakde_fleet_query(sref, sp, qs, qt, cfg)))

        # S-ANN fleet (eta > 0: the per-tenant chunk keys shard with the
        # tenant axis; inf/-1 top-k padding must survive the psum combine)
        scfg = sann.SANNConfig(dim=d, n_max=16, eta=0.3, r=0.5, c=2.0,
                               w=1.0, L=4, k=2)
        scfg, spar, sempty = sann.sann_init(scfg, jax.random.PRNGKey(2))
        keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(5), t)
                          for t in range(T)])
        aref = fleet.sann_fleet_ingest(
            fleet.fleet_broadcast(sempty, T), spar, xs, tids, keys, scfg,
            cap)
        ast, app = ss.shard_fleet(fleet.fleet_broadcast(sempty, T), spar,
                                  ctx)
        ast = ss.sharded_sann_fleet_ingest(ast, app, xs, tids, keys, scfg,
                                           cap, ctx)
        for a, b in zip(jax.tree.leaves(ast), jax.tree.leaves(aref)):
            assert (np.asarray(a) == np.asarray(b)).all()
        ids, dists = ss.sharded_sann_fleet_query_topk(ast, app, qs, qt,
                                                      scfg, ctx, topk=8)
        wi, wd = fleet.sann_fleet_query_topk(aref, spar, qs, qt, scfg,
                                             topk=8)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(dists), np.asarray(wd))
        res = ss.sharded_sann_fleet_query(ast, app, qs, qt, scfg, ctx)
        want = fleet.sann_fleet_query(aref, spar, qs, qt, scfg)
        for a, b in zip(res, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("FLEET_SHARDED_OK")
    """)
    assert "FLEET_SHARDED_OK" in out
