"""Multi-device semantics (subprocesses with 8 virtual CPU devices):
sharded step == single-device step; EP MoE == dense MoE; compressed DP
all-reduce ≈ exact with error feedback.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(body: str) -> str:
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n" +
              textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=480)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.launch.inputs import make_batch
        from repro.launch.mesh import make_test_mesh
        from repro.optim.adam import adamw
        from repro.parallel import param_specs
        from repro.train.train_step import make_train_step
        import dataclasses

        cfg = dataclasses.replace(registry.get_smoke_config("qwen3-4b"),
                                  param_dtype="float32", seq_parallel=True)
        key = jax.random.PRNGKey(0)
        batch = make_batch(cfg, batch=8, seq=32, key=jax.random.PRNGKey(1))

        # single device
        step1, init1 = make_train_step(cfg, adamw(lr=1e-3), mesh=None)
        s1 = init1(key)
        s1, m1 = jax.jit(step1)(s1, batch)

        # 2x4 mesh with full sharding machinery
        mesh = make_test_mesh(2, 4)
        stepN, initN = make_train_step(cfg, adamw(lr=1e-3), mesh=mesh)
        sN = initN(key)
        p_sh = param_specs.param_shardings(sN.params, mesh)
        o_sh = param_specs.opt_state_shardings(sN.opt_state, p_sh, mesh)
        state_sh = type(sN)(params=p_sh, opt_state=o_sh,
                            step=NamedSharding(mesh, P()))
        b_sh = param_specs.batch_shardings(batch, mesh)
        sN = jax.device_put(sN, state_sh)
        batchN = jax.device_put(batch, b_sh)
        sN, mN = jax.jit(stepN, in_shardings=(state_sh, b_sh),
                         donate_argnums=(0,))(sN, batchN)

        print("loss1", float(m1["loss"]), "lossN", float(mN["loss"]))
        assert abs(float(m1["loss"]) - float(mN["loss"])) < 2e-4
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(sN.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        print("SHARDED_MATCH_OK")
    """)
    assert "SHARDED_MATCH_OK" in out


def test_moe_ep_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.models import moe
        from repro.parallel.sharding import make_ctx

        key = jax.random.PRNGKey(0)
        d, E, k = 32, 8, 2
        p = moe.init_moe(key, d, E, n_shared=1, d_ff_expert=16,
                         dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
        y_dense, aux_d = moe.moe_dense(p, x, topk=k, capacity_factor=64.0)

        mesh = make_test_mesh(2, 4)
        ctx = make_ctx(mesh)
        # place params per the EP layout (experts on model, FSDP on data)
        p_ep = dict(p)
        p_ep["w_gate"] = jax.device_put(p["w_gate"], NamedSharding(mesh, P("model", "data", None)))
        p_ep["w_up"] = jax.device_put(p["w_up"], NamedSharding(mesh, P("model", "data", None)))
        p_ep["w_down"] = jax.device_put(p["w_down"], NamedSharding(mesh, P("model", None, "data")))
        x_ep = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y_ep, aux_e = jax.jit(lambda p, x: moe.moe_ep(
            p, x, topk=k, capacity_factor=64.0, ctx=ctx))(p_ep, x_ep)

        # EP combines its psum in bf16 (§Perf hillclimb 2) — equivalence
        # holds to bf16 rounding of the routed-expert contribution
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                                   rtol=2e-2, atol=2e-2)
        assert float(jnp.abs(jnp.asarray(y_dense) - jnp.asarray(y_ep)).mean()) < 5e-3
        # aux is a per-shard load-balance estimate (Switch-style): close but
        # not identical to the global product
        np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=5e-2)
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out


def test_compressed_allreduce_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.optim import compress

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 130))

        def worker(g_local, e_local):
            (r, e2) = compress.compressed_pmean(
                {"w": g_local}, compress.ErrorState(err={"w": e_local}), "data")
            return r["w"], e2.err["w"]

        f = jax.jit(jax.shard_map(worker, mesh=mesh,
                                  in_specs=(P("data"), P("data")),
                                  out_specs=(P("data"), P("data")),
                                  check_vma=False))
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        err = jnp.zeros_like(g)
        # accumulate over steps: error feedback keeps the running sum honest
        tot_comp = jnp.zeros_like(g[0])
        tot_exact = jnp.zeros_like(g[0])
        for i in range(20):
            red, err = f(g * (1 + 0.01 * i), err)
            tot_comp = tot_comp + red[0]
            tot_exact = tot_exact + (g * (1 + 0.01 * i)).mean(0)
        one_step_err = float(jnp.abs(red[0] - (g * 1.19).mean(0)).max())
        accum_err = float(jnp.abs(tot_comp - tot_exact).max())
        rel = accum_err / float(jnp.abs(tot_exact).max())
        print("one-step", one_step_err, "accum rel", rel)
        assert rel < 0.02  # error feedback keeps long-run bias tiny
        exact_b, comp_b = compress.bytes_saved_per_step({"w": g[0]})
        assert comp_b < 0.3 * exact_b
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out
